//! The fixture corpus: known-bad snippets each rule must flag (with
//! expectations pinned by `amlint-fixture: expect <rule>` markers in the
//! fixture itself) and known-good files each rule must pass clean.

use std::collections::BTreeSet;
use std::path::PathBuf;

use amlint::{drift, lexer, rules};

/// Registry used by the lock-rule fixtures.
const FIXTURE_REGISTRY: [&str; 3] = ["tx", "workers", "metrics"];

fn fixture(rel: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(rel);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()))
}

/// `(line, rule)` pairs declared by `amlint-fixture: expect <rule>`
/// markers.
fn expectations(src: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in src.lines().enumerate() {
        if let Some(rest) = line.split("amlint-fixture: expect ").nth(1) {
            let rule: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            out.push((i + 1, rule));
        }
    }
    assert!(!out.is_empty(), "fixture declares no expectations");
    out
}

/// Run all four file-local rules (simd as a non-kernels file) and
/// return `(line, rule)` findings.
fn lint(src: &str) -> Vec<(usize, String)> {
    let toks = lexer::lex(src);
    let mut findings = Vec::new();
    rules::rule_panic("fixture.rs", &toks, &mut findings);
    rules::rule_safety("fixture.rs", &toks, &mut findings);
    rules::rule_simd("fixture.rs", &toks, false, &mut findings);
    rules::rule_locks("fixture.rs", &toks, &FIXTURE_REGISTRY, &mut findings);
    let mut got: Vec<(usize, String)> =
        findings.into_iter().map(|f| (f.line, f.rule.to_string())).collect();
    got.sort();
    got
}

#[test]
fn bad_panic_fixture_flags_exactly_the_marked_lines() {
    let src = fixture("bad/panic.rs");
    assert_eq!(lint(&src), expectations(&src));
}

#[test]
fn bad_locks_fixture_flags_exactly_the_marked_lines() {
    let src = fixture("bad/locks.rs");
    assert_eq!(lint(&src), expectations(&src));
}

#[test]
fn bad_safety_fixture_flags_exactly_the_marked_lines() {
    let src = fixture("bad/safety.rs");
    assert_eq!(lint(&src), expectations(&src));
}

#[test]
fn bad_simd_fixture_flags_exactly_the_marked_lines() {
    let src = fixture("bad/simd.rs");
    assert_eq!(lint(&src), expectations(&src));
}

#[test]
fn good_fixtures_pass_byte_for_byte() {
    for rel in ["good/clean.rs", "good/annotated.rs"] {
        let src = fixture(rel);
        let got = lint(&src);
        assert!(got.is_empty(), "{rel} should be clean, got {got:?}");
    }
}

#[test]
fn bad_store_io_fixture_flags_exactly_the_marked_lines() {
    // linted as a `store/` file: the unsafe-in-store check is active
    let src = fixture("bad/store_io.rs");
    let toks = lexer::lex(&src);
    let mut findings = Vec::new();
    rules::rule_store_io("store/paged.rs", &toks, true, &mut findings);
    let mut got: Vec<(usize, String)> =
        findings.into_iter().map(|f| (f.line, f.rule.to_string())).collect();
    got.sort();
    assert_eq!(got, expectations(&src));
}

#[test]
fn good_store_io_fixture_passes_in_and_out_of_store() {
    let src = fixture("good/store_io.rs");
    let toks = lexer::lex(&src);
    for in_store in [true, false] {
        let mut findings = Vec::new();
        rules::rule_store_io("fixture.rs", &toks, in_store, &mut findings);
        assert!(findings.is_empty(), "in_store={in_store}: {findings:?}");
    }
}

#[test]
fn kernel_simd_fixture_clean_inside_kernels_dir_only() {
    let src = fixture("good/kernels_simd.rs");
    let toks = lexer::lex(&src);
    let mut findings = Vec::new();
    rules::rule_simd("search/kernels/x86.rs", &toks, true, &mut findings);
    rules::rule_safety("search/kernels/x86.rs", &toks, &mut findings);
    assert!(findings.is_empty(), "{findings:?}");
    // the same file outside `search/kernels/` is a containment violation
    let mut outside = Vec::new();
    rules::rule_simd("search/distance.rs", &toks, false, &mut outside);
    assert!(!outside.is_empty());
}

#[test]
fn drift_fixture_flags_every_planted_inconsistency() {
    let wire = fixture("bad/drift/wire.rs");
    let persist = fixture("bad/drift/persist.rs");
    let plan = fixture("bad/drift/plan.rs");
    let obs = fixture("bad/drift/obs.rs");
    let readme = fixture("bad/drift/README.md");
    // ERR_BAD_FRAME is asserted somewhere; ERR_UNTESTED, ERR_GAPPED,
    // FT_EXPLAIN, and M_QUALITY_RECALL are not
    let test_idents: BTreeSet<String> = ["ERR_BAD_FRAME".to_string()].into();
    let mut findings = Vec::new();
    drift::check(
        &drift::DriftInput {
            wire: &wire,
            persist: &persist,
            plan: &plan,
            // a server that never reports its kernel backend
            server: "fn start() {}",
            obs: &obs,
            readme: &readme,
            test_idents: &test_idents,
        },
        &mut findings,
    );
    let messages: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
    let expect_contains = [
        "no `kernel_backend` STATS field",         // backend unobservable
        "code 3 is unassigned",                    // gapped codes
        "`ERR_UNTESTED` (code 2) is not asserted", // untested code
        "`ERR_GAPPED` (code 4) is not asserted",
        "`ERR_UNTESTED` (code 2) has no README",   // wrong code cell in table
        "`ERR_GAPPED` (code 4) has no README",     // missing row
        "`ERR_REMOVED`, which does not exist",     // stale constant
        "no `version >= 5` feature gate",          // bumped without gating
        "`version >= 9` is outside 2..=5",         // gate beyond VERSION
        "`version != SHARD_MANIFEST_VERSION` not found", // plan hardcodes 3
        "README formats table has no `| v4 |` row",
        "README formats table has no `| v5 |` row",
        "README `| v1 |` row says \"current\" but VERSION is 5",
        "README `| v3 |` row must mention the shard manifest",
        "no `TRACED_VERSION: u8` constant found",  // traced layout unpinned
        "`FT_EXPLAIN` (frame type 0x0C) is not asserted", // unpinned frame id
        "`EXPLAIN` and `0x0C`",                    // no README frame-table row
        "no `FT_EXPLAIN_REPLY: u8` constant found", // reply constant deleted
        "`amsearch_undocumented_total` (`M_UNDOCUMENTED`) has no README row",
        "quality family `amsearch_quality_recall` (`M_QUALITY_RECALL`) is not pinned",
    ];
    for needle in expect_contains {
        assert!(
            messages.iter().any(|m| m.contains(needle)),
            "expected a finding containing {needle:?}; got:\n{}",
            messages.join("\n")
        );
    }
    assert_eq!(
        findings.len(),
        expect_contains.len(),
        "unexpected extra drift findings:\n{}",
        messages.join("\n")
    );
}

#[test]
fn clean_drift_inputs_produce_no_findings() {
    // the good half of the drift fixture: the real repo's own files,
    // which `amlint::run` checks end-to-end in lib.rs tests
    let root = amlint::find_root(PathBuf::from(env!("CARGO_MANIFEST_DIR")).as_path())
        .expect("repo root");
    let findings = amlint::run(&root).expect("run");
    let drift_only: Vec<_> = findings.iter().filter(|f| f.rule == "drift").collect();
    assert!(drift_only.is_empty(), "{drift_only:?}");
}
