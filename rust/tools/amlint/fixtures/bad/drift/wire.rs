// amlint fixture: rule 3 (drift), wire side. ERR_UNTESTED never shows
// up in a test assertion, and the codes skip 3.  TRACED_VERSION is
// gone entirely, FT_EXPLAIN is neither asserted nor documented, and
// the EXPLAIN_REPLY constant was deleted without a trace.
pub const ERR_BAD_FRAME: u16 = 1;
pub const ERR_UNTESTED: u16 = 2;
pub const ERR_GAPPED: u16 = 4;
pub const FT_EXPLAIN: u8 = 0x0C;
