// amlint fixture: rule 3 (drift), wire side. ERR_UNTESTED never shows
// up in a test assertion, and the codes skip 3.
pub const ERR_BAD_FRAME: u16 = 1;
pub const ERR_UNTESTED: u16 = 2;
pub const ERR_GAPPED: u16 = 4;
