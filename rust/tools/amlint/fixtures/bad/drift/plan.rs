// amlint fixture: rule 3 (drift), plan side. The manifest check ignores
// the shared SHARD_MANIFEST_VERSION constant.
fn load_manifest(version: u32) {
    if version != 3 {
        return;
    }
}
