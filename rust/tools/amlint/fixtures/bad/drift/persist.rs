// amlint fixture: rule 3 (drift), persist side. VERSION was bumped to 5
// but no `version >= 5` gate exists, and one gate reaches beyond it.
const VERSION: u32 = 5;
pub(crate) const SHARD_MANIFEST_VERSION: u32 = 3;

fn load(version: u32) {
    if version == 0 || version == SHARD_MANIFEST_VERSION || version > VERSION {
        return;
    }
    let _ = version >= 2;
    let _ = version >= 9;
}
