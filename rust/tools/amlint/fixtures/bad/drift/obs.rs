// amlint fixture: rule 3 (drift), observability side.  One family has
// no README row, and the quality family is not pinned by any test.
pub const M_REQUESTS: &str = "amsearch_requests_total";
pub const M_UNDOCUMENTED: &str = "amsearch_undocumented_total";
pub const M_QUALITY_RECALL: &str = "amsearch_quality_recall";
