// amlint fixture: rule 4 (SAFETY audit). Not compiled — read as data by
// tests/fixtures.rs; expected findings come from the
// `amlint-fixture: expect` markers.

pub fn write_slot(p: *mut u32) {
    unsafe { *p = 1 } // amlint-fixture: expect safety
}

// SAFETY: stale comment separated by a blank line — does not count

pub fn write_slot_again(p: *mut u32) {
    unsafe { *p = 2 } // amlint-fixture: expect safety
}

// SAFETY: the pointer is derived from a live &mut and never aliased;
// a multi-line justification directly above the item counts.
pub unsafe fn documented(p: *mut u32) {
    // SAFETY: caller contract forwarded from `documented`
    unsafe { *p = 3 } // ok
}

struct Token(*const u8);
unsafe impl Send for Token {} // amlint-fixture: expect safety
// SAFETY: Token is a value type; the pointer is never dereferenced
unsafe impl Sync for Token {} // ok
