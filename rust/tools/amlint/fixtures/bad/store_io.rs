// amlint fixture: rule 6 (storage-I/O hygiene). Not compiled — read as
// data by tests/fixtures.rs with `in_store = true` (a `store/` file);
// expected findings come from the `amlint-fixture: expect` markers.

pub fn map_the_file(file: &File) -> Mmap { // amlint-fixture: expect store_io
    MmapOptions::new().map(file) // amlint-fixture: expect store_io
}

pub fn patch_in_place(p: *mut f32) {
    // SAFETY: a justification does not excuse unsafe inside store/
    unsafe { *p = 1.0 } // amlint-fixture: expect store_io
}

pub fn fire_and_forget(file: &File, buf: &mut [u8], off: u64) {
    let _ = file.read_exact_at(buf, off); // amlint-fixture: expect store_io
}

pub fn flush_best_effort(mut out: BufWriter<File>) {
    let _ = out.flush(); // amlint-fixture: expect store_io
}

pub fn multi_line_discard(file: &File) {
    let _ = file // amlint-fixture: expect store_io
        .sync_all();
}

pub fn bound_result_is_fine(file: &File, buf: &mut [u8]) -> io::Result<usize> {
    file.read_exact_at(buf, 0)?;
    file.read(buf)
}

pub fn non_io_discard_is_fine(handle: JoinHandle<()>) {
    let _ = handle.join();
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let _ = file.read_exact(&mut buf);
    }
}
