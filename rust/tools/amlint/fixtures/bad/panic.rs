// amlint fixture: rule 1 (panic-freedom). Not compiled — read as data
// by tests/fixtures.rs, which derives the expected findings from the
// expectation markers on the lines below.

pub fn serve(x: Option<u32>) -> u32 {
    let a = x.unwrap(); // amlint-fixture: expect panic
    let b = x.expect("present"); // amlint-fixture: expect panic
    if a == 0 {
        unreachable!("a is never zero"); // amlint-fixture: expect panic
    }
    match b {
        0 => panic!("no"), // amlint-fixture: expect panic
        n => n,
    }
}

pub fn lookalikes(x: Option<u32>) -> u32 {
    // none of these are findings
    let s = "call unwrap() or panic!() today";
    let _ = s;
    let _ = std::panic::catch_unwind(|| 1);
    x.unwrap_or(7)
}

mod outer {
    #[cfg(test)]
    mod nested_tests {
        // tricky case: unwrap inside a *nested* #[cfg(test)] module —
        // must NOT be flagged
        fn helper(x: Option<u32>) -> u32 {
            x.unwrap()
        }

        #[test]
        fn t() {
            assert_eq!(helper(Some(1)), 1);
        }
    }

    pub fn still_serving(x: Option<u32>) -> u32 {
        x.unwrap() // amlint-fixture: expect panic
    }
}

pub fn annotated(x: Option<u32>) -> u32 {
    // amlint: allow(panic, reason = "fixture: annotated site is exempt")
    x.unwrap()
}
