//! Known-bad SIMD fixture: raw intrinsics leaking outside
//! `rust/src/search/kernels/` and `#[target_feature]` functions missing
//! parts of their contract.  Linted with `in_kernels = false`.

use std::arch::x86_64::*; // amlint-fixture: expect simd

pub fn leaked_intrinsic(a: &[f32]) -> f32 {
    let v = _mm_setzero_ps(); // amlint-fixture: expect simd
    a[0]
}

// SAFETY: callers check is_x86_feature_detected!("avx2") first.
#[target_feature(enable = "avx2")] // amlint-fixture: expect simd
fn not_declared_unsafe(a: &[f32]) -> f32 {
    a[0]
}

#[target_feature(enable = "avx2")] // amlint-fixture: expect simd
unsafe fn no_safety_comment(a: &[f32]) -> f32 { // amlint-fixture: expect safety
    a[0]
}

// SAFETY: the comment forgets to name the detected feature.
#[target_feature(enable = "avx2")] // amlint-fixture: expect simd
unsafe fn wrong_feature_named(a: &[f32]) -> f32 {
    a[0]
}
