// amlint fixture: rule 2 (lock discipline). Not compiled — read as data
// by tests/fixtures.rs with registry ["tx", "workers", "metrics"];
// expected findings come from the `amlint-fixture: expect` markers.

pub fn out_of_order(&self) {
    let m = self.metrics.lock().unwrap_or_default();
    let t = self.tx.lock().unwrap_or_default(); // amlint-fixture: expect lock_order
}

pub fn blocking_under_guard(&self) {
    let guard = self.tx.lock().unwrap_or_default();
    guard.send(1); // amlint-fixture: expect lock_blocking
}

pub fn lock_in_closure(&self) {
    // tricky case: a guard acquired inside a closure body still counts
    self.items.iter().for_each(|w| {
        let g = self.tx.lock().unwrap_or_default();
        g.send(w); // amlint-fixture: expect lock_blocking
    });
    self.out.send(1); // not flagged: the closure guard died at its block
}

pub fn undeclared(&self) {
    let g = self.secret.lock().unwrap_or_default(); // amlint-fixture: expect lock_registry
}

pub fn fine(&self) {
    let t = self.tx.lock().unwrap_or_default();
    let w = self.workers.lock().unwrap_or_default(); // in declared order: ok
    drop(t);
    drop(w);
    self.out.send(1); // ok: both guards dropped
}
