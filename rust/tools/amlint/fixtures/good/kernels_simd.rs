//! Known-good kernel-module fixture: intrinsics are containment-legal
//! here (linted with `in_kernels = true`), and the `#[target_feature]`
//! function carries the full contract — `unsafe`, plus a `// SAFETY:`
//! comment above the attribute stack naming the runtime check.

use std::arch::x86_64::*;

pub(crate) fn sum_sse2(a: &[f32]) -> f32 {
    let mut acc = _mm_setzero_ps();
    for chunk in a.chunks_exact(4) {
        // SAFETY: `chunks_exact(4)` guarantees 4 readable floats at
        // `chunk.as_ptr()`; sse2 is baseline on x86_64.
        let v = unsafe { _mm_loadu_ps(chunk.as_ptr()) };
        acc = _mm_add_ps(acc, v);
    }
    fold(acc)
}

// SAFETY: requires avx2 — the dispatch layer constructs this backend
// only after a one-time `is_x86_feature_detected!("avx2")` probe.
#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sum_avx2(a: &[f32]) -> f32 {
    let mut acc = _mm256_setzero_ps();
    for chunk in a.chunks_exact(8) {
        // SAFETY: `chunks_exact(8)` guarantees 8 readable floats; the
        // avx instructions are gated by this fn's `target_feature`.
        let v = unsafe { _mm256_loadu_ps(chunk.as_ptr()) };
        acc = _mm256_add_ps(acc, v);
    }
    fold8(acc)
}
