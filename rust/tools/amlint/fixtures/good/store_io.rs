// amlint fixture: rule 6's escape hatch and the patterns it must not
// flag. Linted as a `store/` file (`in_store = true`) and must come
// back clean.

pub fn checked_pread(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    file.read_exact_at(buf, off)
}

pub fn durable_write(file: &File, bytes: &[u8]) -> io::Result<()> {
    file.write_all(bytes)?;
    file.sync_all()
}

pub fn best_effort_reply(mut s: TcpStream, frame: &[u8]) {
    // amlint: allow(store_io, reason = "error reply to a dying peer is best-effort")
    let _ = s.write_all(frame);
}

pub fn documented_exception(file: &File) {
    // amlint: allow(store_io, reason = "fixture: annotated mmap escape hatch")
    let _m = MmapOptions::new().map(file);
}

pub fn lookalikes_pass(s: &str) -> bool {
    // `mmap` in a comment or string literal is data, not code
    s == "mmap"
}

pub fn non_io_discards(handle: JoinHandle<()>, stream: &TcpStream) {
    let _ = handle.join();
    let _ = stream.set_nodelay(true);
}
