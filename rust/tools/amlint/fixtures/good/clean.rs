// amlint fixture: a file every rule must pass byte-for-byte. Registry
// for the lock rule: ["tx", "workers", "metrics"].

pub fn serve(x: Option<u32>) -> u32 {
    x.unwrap_or_default().max(1)
}

pub fn strings_and_comments() -> &'static str {
    // unwrap() and panic! in comments are not code
    /* neither in /* nested */ block comments: x.unwrap() */
    "panic!(\"in a string\") and r#\"x.unwrap()\"# are literals"
}

pub fn ordered_locks(&self) {
    let t = self.tx.lock().unwrap_or_default();
    let w = self.workers.lock().unwrap_or_default();
    drop(w);
    drop(t);
    let m = self.metrics.lock().unwrap_or_default();
    *m += 1;
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap_and_block() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        let g = self.tx.lock().unwrap();
        g.send(1);
    }
}
