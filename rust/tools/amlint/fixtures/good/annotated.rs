// amlint fixture: every rule's escape hatch, used correctly. Registry
// for the lock rule: ["tx", "workers", "metrics"].

pub fn checked_invariant(x: Option<u32>) -> u32 {
    // amlint: allow(panic, reason = "x is Some: filled two lines above")
    x.unwrap()
}

pub fn same_line(x: Option<u32>) -> u32 {
    x.unwrap() // amlint: allow(panic, reason = "fixture: same-line form")
}

pub fn handoff(&self) {
    let guard = self.tx.lock().unwrap_or_default();
    // amlint: allow(lock_blocking, reason = "bounded channel; send cannot wedge")
    guard.send(1);
}

pub fn deliberate_inversion(&self) {
    let m = self.metrics.lock().unwrap_or_default();
    // amlint: allow(lock_order, reason = "fixture: documented inversion")
    let t = self.tx.lock().unwrap_or_default();
}

pub fn scratch_mutex(&self) {
    // amlint: allow(lock_registry, reason = "fixture: local scratch lock")
    let g = self.scratch.lock().unwrap_or_default();
}

pub fn raw(p: *mut u32) {
    // SAFETY: p points into a live, exclusively-owned allocation
    unsafe { *p = 1 }
}
