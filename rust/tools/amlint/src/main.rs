//! CLI driver: `cargo run -p amlint [--release] [-- --root <dir>]`.
//! Prints one `file:line: rule: message` per finding and exits 1 if any
//! were found, 0 on a clean tree.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let mut root: Option<PathBuf> = None;
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--help" | "-h" => {
                println!(
                    "amlint: repo-specific static analysis for amsearch\n\
                     usage: amlint [--root <repo-root>]\n\
                     rules: panic, lock_order, lock_blocking, lock_registry, \
                     safety, simd, store_io, drift\n\
                     suppress per-site with: // amlint: allow(<rule>, reason = \"...\")"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("amlint: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    let root = match root {
        Some(r) => r,
        None => {
            let start = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match amlint::find_root(&start) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "amlint: no repo root (rust/src + README.md) at or above \
                         {} — pass --root",
                        start.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };
    match amlint::run(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("amlint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!("amlint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("{e}");
            ExitCode::from(2)
        }
    }
}
