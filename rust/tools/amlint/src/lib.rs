//! `amlint` — repo-specific static analysis for the `amsearch` serving
//! stack.  Six rule classes (see [`rules`] and [`drift`]):
//!
//! 1. panic-freedom in the serving path (`panic`),
//! 2. lock discipline against a declared mutex registry (`lock_order`,
//!    `lock_blocking`, `lock_registry`),
//! 3. protocol/format drift between constants, tests, and README
//!    (`drift`),
//! 4. `// SAFETY:` comments on every `unsafe` (`safety`),
//! 5. SIMD containment: raw intrinsics only inside
//!    `rust/src/search/kernels/`, `#[target_feature]` fns `unsafe` with
//!    a `// SAFETY:` naming the runtime check (`simd`),
//! 6. storage-I/O hygiene: no mmap in serving code, no `unsafe` inside
//!    `store/`, no `let _ =` discards of `io::Result` (`store_io`).
//!
//! Zero dependencies, like the rest of the workspace: a hand-rolled
//! lexer ([`lexer`]) feeds a token-level rule engine.  Findings are
//! suppressed per-site with `// amlint: allow(<rule>, reason = "...")`
//! on the line above (or the same line as) the offending code; the
//! reason string is mandatory and must be non-empty.

pub mod drift;
pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

pub use rules::Finding;

/// Top-level `rust/src` directories where the panic rule applies (the
/// serving path: a panicking handler thread breaks the
/// exactly-one-response guarantee and poisons shared mutexes).
pub const PANIC_DIRS: [&str; 8] =
    ["net", "coordinator", "cluster", "search", "index", "quant", "obs", "store"];

/// The declared mutex registries: for each file, its mutexes in
/// acquisition order.  A mutex may only be taken while holding mutexes
/// that appear strictly earlier in its file's list; taking a mutex that
/// is not listed at all is a `lock_registry` finding.
///
/// Paths are relative to `rust/src`.  Names are the receiver identifier
/// at the lock site (`self.shared.metrics.lock()` registers as
/// `metrics`; `lock_unpoisoned(&self.tx)` registers as `tx`).
pub const LOCK_REGISTRIES: [(&str, &[&str]); 3] = [
    // accept-thread handle, handler-pool receiver, pipelining window,
    // per-connection writer
    ("net/server.rs", &["accept", "rx", "m", "stream"]),
    // batch funnel receiver, submit sender, batcher handle, worker
    // handles, shadow-worker handle, metrics
    (
        "coordinator/server.rs",
        &["batch_rx", "tx", "batcher", "workers", "shadow_worker", "metrics"],
    ),
    // request receiver, submit sender, worker handles, shadow-worker
    // handle, metrics, cached index info
    (
        "cluster/router.rs",
        &["req_rx", "tx", "workers", "shadow_worker", "metrics", "index_info"],
    ),
];

/// Recursively collect `*.rs` files under `dir`, as paths relative to
/// `dir`, sorted for deterministic output.
fn rs_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, prefix: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let path = entry.path();
            let rel = prefix.join(entry.file_name());
            if path.is_dir() {
                walk(&path, &rel, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(rel);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, Path::new(""), &mut out)?;
    out.sort();
    Ok(out)
}

fn read(root: &Path, rel: &str) -> Result<String, String> {
    std::fs::read_to_string(root.join(rel))
        .map_err(|e| format!("amlint: cannot read {rel}: {e}"))
}

/// Run every rule over the repo rooted at `root` (the directory holding
/// `rust/` and `README.md`).  Returns findings sorted by file then
/// line; an empty list means the tree is clean.
pub fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let src_root = root.join("rust/src");
    let mut findings = Vec::new();
    let mut test_idents: BTreeSet<String> = BTreeSet::new();
    let mut sources: Vec<(String, String)> = Vec::new();

    for rel in rs_files(&src_root).map_err(|e| format!("amlint: walk rust/src: {e}"))? {
        let rel_str = rel.to_string_lossy().replace('\\', "/");
        let text = read(root, &format!("rust/src/{rel_str}"))?;
        sources.push((rel_str, text));
    }
    for (rel_str, text) in &sources {
        let toks = lexer::lex(text);
        let display = format!("rust/src/{rel_str}");
        let top = rel_str.split('/').next().unwrap_or("");
        if PANIC_DIRS.contains(&top) {
            rules::rule_panic(&display, &toks, &mut findings);
            rules::rule_store_io(&display, &toks, top == "store", &mut findings);
        }
        rules::rule_safety(&display, &toks, &mut findings);
        let in_kernels = rel_str.starts_with("search/kernels/");
        rules::rule_simd(&display, &toks, in_kernels, &mut findings);
        if let Some((_, registry)) =
            LOCK_REGISTRIES.iter().find(|(f, _)| f == rel_str)
        {
            rules::rule_locks(&display, &toks, registry, &mut findings);
        }
        test_idents.extend(rules::idents_in_test_regions(&toks));
    }

    // integration tests are all test code: every ident counts
    let tests_root = root.join("rust/tests");
    if tests_root.is_dir() {
        for rel in
            rs_files(&tests_root).map_err(|e| format!("amlint: walk rust/tests: {e}"))?
        {
            let rel_str = rel.to_string_lossy().replace('\\', "/");
            let text = read(root, &format!("rust/tests/{rel_str}"))?;
            for t in lexer::lex(&text) {
                if t.kind == lexer::Kind::Ident {
                    test_idents.insert(t.text);
                }
            }
        }
    }

    let find = |path: &str| -> &str {
        sources
            .iter()
            .find(|(rel, _)| rel == path)
            .map(|(_, text)| text.as_str())
            .unwrap_or("")
    };
    let readme = read(root, "README.md")?;
    drift::check(
        &drift::DriftInput {
            wire: find("net/wire.rs"),
            persist: find("index/persist.rs"),
            plan: find("cluster/plan.rs"),
            server: find("coordinator/server.rs"),
            obs: find("obs/prom.rs"),
            readme: &readme,
            test_idents: &test_idents,
        },
        &mut findings,
    );

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}

/// Locate the repo root: walk up from `start` looking for a directory
/// that contains both `rust/src` and `README.md`.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust/src").is_dir() && dir.join("README.md").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registries_cover_declared_files_only() {
        for (file, registry) in LOCK_REGISTRIES {
            assert!(!registry.is_empty(), "{file} registry is empty");
            let unique: BTreeSet<&str> = registry.iter().copied().collect();
            assert_eq!(unique.len(), registry.len(), "{file} registry has duplicates");
        }
    }

    #[test]
    fn repo_is_clean() {
        // the linter's own acceptance test: zero unannotated findings on
        // the live tree (mirrors `cargo run -p amlint` in CI)
        let here = Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(here).expect("repo root above tools/amlint");
        let findings = run(&root).expect("lint run");
        assert!(
            findings.is_empty(),
            "repo has {} unannotated findings:\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
