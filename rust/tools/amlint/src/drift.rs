//! Rule 3: protocol/format drift.  Cross-file checks that keep the wire
//! protocol and the persisted-index format constants in lockstep with
//! the tests and the README:
//!
//! * every `ERR_*` code in `net/wire.rs` is unique, contiguous from 1,
//!   asserted in at least one test, and documented in a README table
//!   row carrying the matching numeric code;
//! * every `ERR_*` name mentioned in the README actually exists (no
//!   stale constants surviving a rename);
//! * `index/persist.rs` rejects future versions (`version > VERSION`),
//!   reserves the shard-manifest number (`version ==
//!   SHARD_MANIFEST_VERSION`), and every `version >= N` feature gate
//!   satisfies `2 <= N <= VERSION`, with a gate for the current
//!   `VERSION` present (bumping the constant without gating the new
//!   field is drift);
//! * `cluster/plan.rs` pins its manifest check to
//!   `SHARD_MANIFEST_VERSION`;
//! * the README formats table has a `| vN |` row for every version
//!   1..=`VERSION`, the current row says "current", and the
//!   shard-manifest row says "shard";
//! * `coordinator/server.rs` exposes the selected distance-kernel
//!   backend (`kernel_backend`) through STATS and the README documents
//!   the `kernel.backend` row name;
//! * every `M_*` metric-name constant in `obs/prom.rs` is unique,
//!   `amsearch_`-prefixed, and documented in the README — renaming an
//!   exported Prometheus family silently breaks dashboards, so names
//!   only move when the docs move with them;
//! * the `amsearch_quality_*` families additionally need a test pin:
//!   the online recall estimator's exported names are what the e2e
//!   pins and the CI cluster smoke assert against, and at least one
//!   quality family must exist at all;
//! * `net/wire.rs` keeps a `TRACED_VERSION` constant for the SEARCH
//!   layout carrying a trace id, a test asserts its value, and the
//!   README documents the `trace_id` field;
//! * `net/wire.rs` keeps `FT_EXPLAIN` / `FT_EXPLAIN_REPLY` frame-type
//!   constants, a test asserts their ids, and the README frame table
//!   carries a row with the matching `0xNN` id for each.

use std::collections::BTreeSet;

use crate::lexer::{lex, Kind, Tok};
use crate::rules::Finding;

fn code(toks: &[Tok]) -> Vec<&Tok> {
    toks.iter().filter(|t| t.kind != Kind::Comment).collect()
}

/// Parse an integer literal, decimal or `0x` hex (frame type ids are
/// conventionally written in hex), with `_` separators stripped.
fn parse_int(text: &str) -> Option<u64> {
    let t = text.replace('_', "");
    match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => t.parse::<u64>().ok(),
    }
}

/// `const <name>: <ty> = <int literal>;` declarations whose name starts
/// with `prefix`, as `(name, value, line)`.
fn int_consts(toks: &[Tok], prefix: &str, ty: &str) -> Vec<(String, u64, usize)> {
    let c = code(toks);
    let mut out = Vec::new();
    for i in 0..c.len() {
        if c[i].text != "const" || i + 6 >= c.len() {
            continue;
        }
        let name = &c[i + 1];
        if name.kind != Kind::Ident || !name.text.starts_with(prefix) {
            continue;
        }
        if c[i + 2].text != ":" || c[i + 3].text != ty || c[i + 4].text != "=" {
            continue;
        }
        let lit = &c[i + 5];
        if lit.kind != Kind::Lit || c[i + 6].text != ";" {
            continue;
        }
        if let Some(v) = parse_int(&lit.text) {
            out.push((name.text.clone(), v, name.line));
        }
    }
    out
}

/// `const <name>: &str = "<value>";` declarations whose name starts
/// with `prefix`, as `(name, value, line)` with the quotes stripped.
fn str_consts(toks: &[Tok], prefix: &str) -> Vec<(String, String, usize)> {
    let c = code(toks);
    let mut out = Vec::new();
    for i in 0..c.len() {
        if c[i].text != "const" || i + 7 >= c.len() {
            continue;
        }
        let name = &c[i + 1];
        if name.kind != Kind::Ident || !name.text.starts_with(prefix) {
            continue;
        }
        if c[i + 2].text != ":"
            || c[i + 3].text != "&"
            || c[i + 4].text != "str"
            || c[i + 5].text != "="
        {
            continue;
        }
        let lit = &c[i + 6];
        if lit.kind != Kind::Lit || !lit.text.starts_with('"') || c[i + 7].text != ";" {
            continue;
        }
        out.push((name.text.clone(), lit.text.trim_matches('"').to_string(), name.line));
    }
    out
}

/// Does the code token stream contain `pattern` as a consecutive
/// sequence of token texts?
fn has_seq(toks: &[Tok], pattern: &[&str]) -> bool {
    let c = code(toks);
    c.windows(pattern.len())
        .any(|w| w.iter().zip(pattern).all(|(t, p)| t.text == *p))
}

/// All `version >= <int>` gates in the stream, as `(value, line)`.
fn ge_gates(toks: &[Tok]) -> Vec<(u64, usize)> {
    let c = code(toks);
    let mut out = Vec::new();
    for w in c.windows(4) {
        if w[0].text == "version" && w[1].text == ">" && w[2].text == "=" {
            if let Ok(v) = w[3].text.parse::<u64>() {
                out.push((v, w[3].line));
            }
        }
    }
    out
}

/// Inputs to the drift rule: the relevant sources plus the set of
/// identifiers appearing in test code anywhere in the workspace.
pub struct DriftInput<'a> {
    /// `rust/src/net/wire.rs` source.
    pub wire: &'a str,
    /// `rust/src/index/persist.rs` source.
    pub persist: &'a str,
    /// `rust/src/cluster/plan.rs` source.
    pub plan: &'a str,
    /// `rust/src/coordinator/server.rs` source.
    pub server: &'a str,
    /// `rust/src/obs/prom.rs` source.
    pub obs: &'a str,
    /// `README.md` contents.
    pub readme: &'a str,
    /// Idents inside `#[cfg(test)]` regions of `rust/src` plus all
    /// idents of `rust/tests/*.rs`.
    pub test_idents: &'a BTreeSet<String>,
}

/// Run every drift check, appending findings.
pub fn check(input: &DriftInput<'_>, out: &mut Vec<Finding>) {
    let wire_toks = lex(input.wire);
    let persist_toks = lex(input.persist);
    let plan_toks = lex(input.plan);
    let wire_file = "rust/src/net/wire.rs";
    let persist_file = "rust/src/index/persist.rs";
    let plan_file = "rust/src/cluster/plan.rs";
    let readme_file = "README.md";
    let push = |out: &mut Vec<Finding>, file: &str, line: usize, message: String| {
        out.push(Finding { file: file.to_string(), line, rule: "drift", message });
    };

    // --- wire error codes ---------------------------------------------
    let errs = int_consts(&wire_toks, "ERR_", "u16");
    if errs.is_empty() {
        push(out, wire_file, 1, "no `ERR_*: u16` constants found".into());
    }
    let mut seen = BTreeSet::new();
    for (name, v, line) in &errs {
        if !seen.insert(*v) {
            push(out, wire_file, *line, format!("`{name}` reuses error code {v}"));
        }
    }
    for want in 1..=errs.len() as u64 {
        if !seen.contains(&want) {
            push(
                out,
                wire_file,
                1,
                format!(
                    "error codes are not contiguous from 1: {} constants but \
                     code {want} is unassigned",
                    errs.len()
                ),
            );
        }
    }
    for (name, v, line) in &errs {
        if !input.test_idents.contains(name) {
            push(
                out,
                wire_file,
                *line,
                format!("`{name}` (code {v}) is not asserted by any test"),
            );
        }
        let cell = format!("| {v} |");
        let documented = input
            .readme
            .lines()
            .any(|l| l.contains(name.as_str()) && l.contains(&cell));
        if !documented {
            push(
                out,
                wire_file,
                *line,
                format!(
                    "`{name}` (code {v}) has no README error-table row \
                     containing both the name and `{cell}`"
                ),
            );
        }
    }
    // stale ERR_* mentions in the README
    let known: BTreeSet<&str> = errs.iter().map(|(n, _, _)| n.as_str()).collect();
    for (ln, line) in input.readme.lines().enumerate() {
        let mut rest = line;
        while let Some(pos) = rest.find("ERR_") {
            let word: String = rest[pos..]
                .chars()
                .take_while(|c| c.is_ascii_uppercase() || *c == '_' || c.is_ascii_digit())
                .collect();
            if word.len() > 4 && !known.contains(word.as_str()) {
                push(
                    out,
                    readme_file,
                    ln + 1,
                    format!("README mentions `{word}`, which does not exist in net/wire.rs"),
                );
            }
            rest = &rest[pos + word.len().max(4)..];
        }
    }

    // --- kernel dispatch STATS row ------------------------------------
    // the server reports its selected distance-kernel backend; the
    // README must document the exact `kernel.backend` row name
    let server_file = "rust/src/coordinator/server.rs";
    if !input.server.contains("kernel_backend") {
        push(
            out,
            server_file,
            1,
            "no `kernel_backend` STATS field in coordinator/server.rs — the \
             selected distance-kernel backend must stay observable"
                .into(),
        );
    } else if !input.readme.lines().any(|l| l.contains("kernel.backend")) {
        push(
            out,
            readme_file,
            1,
            "server STATS exposes `kernel.backend` but the README never \
             documents that row"
                .into(),
        );
    }

    // --- observability: metric families and traced wire version ------
    // exported Prometheus family names are an external contract (the
    // README table is what dashboards are built from), and the traced
    // SEARCH layout is a wire contract old peers must keep rejecting
    // deterministically
    let obs_file = "rust/src/obs/prom.rs";
    let obs_toks = lex(input.obs);
    let metrics = str_consts(&obs_toks, "M_");
    if metrics.is_empty() {
        push(out, obs_file, 1, "no `M_*: &str` metric-name constants found".into());
    }
    let mut metric_names = BTreeSet::new();
    for (name, value, line) in &metrics {
        if !value.starts_with("amsearch_") {
            push(
                out,
                obs_file,
                *line,
                format!("`{name}` metric `{value}` is not `amsearch_`-prefixed"),
            );
        }
        if !metric_names.insert(value.as_str()) {
            push(out, obs_file, *line, format!("`{name}` reuses metric name `{value}`"));
        }
        if !input.readme.lines().any(|l| l.contains(value.as_str())) {
            push(
                out,
                obs_file,
                *line,
                format!(
                    "metric family `{value}` (`{name}`) has no README row — \
                     exported names must stay documented"
                ),
            );
        }
    }
    // quality families are additionally pinned by tests: the online
    // recall estimator's exported names are what the e2e quality pins
    // and the CI cluster smoke assert against
    let mut quality_seen = false;
    for (name, value, line) in &metrics {
        if !value.starts_with("amsearch_quality_") {
            continue;
        }
        quality_seen = true;
        if !input.test_idents.contains(name) {
            push(
                out,
                obs_file,
                *line,
                format!("quality family `{value}` (`{name}`) is not pinned by any test"),
            );
        }
    }
    if !metrics.is_empty() && !quality_seen {
        push(
            out,
            obs_file,
            1,
            "no `amsearch_quality_*` metric families found — the online \
             recall estimate must stay exported"
                .into(),
        );
    }
    match int_consts(&wire_toks, "TRACED_VERSION", "u8").first() {
        None => push(
            out,
            wire_file,
            1,
            "no `TRACED_VERSION: u8` constant found — the SEARCH layout \
             carrying a trace id must keep a distinct pinned wire version"
                .into(),
        ),
        Some((_, v, line)) => {
            if !input.test_idents.contains("TRACED_VERSION") {
                push(
                    out,
                    wire_file,
                    *line,
                    format!("`TRACED_VERSION` (version {v}) is not asserted by any test"),
                );
            }
            if !input.readme.lines().any(|l| l.contains("trace_id")) {
                push(
                    out,
                    readme_file,
                    1,
                    "wire speaks a traced SEARCH layout but the README never \
                     documents the `trace_id` field"
                        .into(),
                );
            }
        }
    }

    // --- explain frame type ids ---------------------------------------
    // EXPLAIN/EXPLAIN_REPLY are an admin wire contract: the type ids
    // must stay asserted by a test and documented in the README frame
    // table, or old peers stop parsing introspection replies
    for (name, label) in [("FT_EXPLAIN", "EXPLAIN"), ("FT_EXPLAIN_REPLY", "EXPLAIN_REPLY")] {
        let found = int_consts(&wire_toks, name, "u8");
        match found.iter().find(|(n, _, _)| n == name) {
            None => push(
                out,
                wire_file,
                1,
                format!(
                    "no `{name}: u8` constant found — the explain frame type \
                     ids must stay pinned"
                ),
            ),
            Some((_, v, line)) => {
                if !input.test_idents.contains(name) {
                    push(
                        out,
                        wire_file,
                        *line,
                        format!("`{name}` (frame type 0x{v:02X}) is not asserted by any test"),
                    );
                }
                let cell = format!("0x{v:02X}");
                let documented = input
                    .readme
                    .lines()
                    .any(|l| l.contains(label) && l.contains(&cell));
                if !documented {
                    push(
                        out,
                        wire_file,
                        *line,
                        format!(
                            "`{name}` has no README frame-table row containing \
                             both `{label}` and `{cell}`"
                        ),
                    );
                }
            }
        }
    }

    // --- persist format versions --------------------------------------
    let version = int_consts(&persist_toks, "VERSION", "u32")
        .iter()
        .find(|(n, _, _)| n == "VERSION")
        .map(|&(_, v, _)| v);
    let shard = int_consts(&persist_toks, "SHARD_MANIFEST_VERSION", "u32")
        .first()
        .map(|&(_, v, _)| v);
    match (version, shard) {
        (Some(version), Some(shard)) => {
            if !has_seq(&persist_toks, &["version", ">", "VERSION"]) {
                push(
                    out,
                    persist_file,
                    1,
                    "load gate `version > VERSION` (reject future formats) not found"
                        .into(),
                );
            }
            if !has_seq(&persist_toks, &["version", "=", "=", "SHARD_MANIFEST_VERSION"]) {
                push(
                    out,
                    persist_file,
                    1,
                    "load gate reserving `SHARD_MANIFEST_VERSION` not found".into(),
                );
            }
            let gates = ge_gates(&persist_toks);
            for (v, line) in &gates {
                if *v < 2 || *v > version {
                    push(
                        out,
                        persist_file,
                        *line,
                        format!(
                            "feature gate `version >= {v}` is outside 2..={version} \
                             (VERSION)"
                        ),
                    );
                }
            }
            if !gates.iter().any(|(v, _)| *v == version) {
                push(
                    out,
                    persist_file,
                    1,
                    format!(
                        "VERSION is {version} but no `version >= {version}` feature \
                         gate exists — bumped the constant without gating the new \
                         fields?"
                    ),
                );
            }
            if !has_seq(&plan_toks, &["version", "!", "=", "SHARD_MANIFEST_VERSION"]) {
                push(
                    out,
                    plan_file,
                    1,
                    "shard-manifest check `version != SHARD_MANIFEST_VERSION` not found"
                        .into(),
                );
            }
            // README formats table
            for v in 1..=version {
                let cell = format!("| v{v} |");
                match input.readme.lines().find(|l| l.contains(&cell)) {
                    None => push(
                        out,
                        readme_file,
                        1,
                        format!("README formats table has no `{cell}` row"),
                    ),
                    Some(row) => {
                        let is_current = row.to_lowercase().contains("current");
                        if v == version && !is_current {
                            push(
                                out,
                                readme_file,
                                1,
                                format!("README `{cell}` row must say \"current\""),
                            );
                        }
                        if v != version && is_current {
                            push(
                                out,
                                readme_file,
                                1,
                                format!(
                                    "README `{cell}` row says \"current\" but VERSION \
                                     is {version}"
                                ),
                            );
                        }
                        if v == shard && !row.to_lowercase().contains("shard") {
                            push(
                                out,
                                readme_file,
                                1,
                                format!(
                                    "README `{cell}` row must mention the shard \
                                     manifest"
                                ),
                            );
                        }
                    }
                }
            }
        }
        _ => push(
            out,
            persist_file,
            1,
            "could not parse `VERSION` / `SHARD_MANIFEST_VERSION` constants".into(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE_OK: &str = r#"
        pub const ERR_A: u16 = 1;
        pub const ERR_B: u16 = 2;
        pub const TRACED_VERSION: u8 = 2;
        pub const FT_EXPLAIN: u8 = 0x0C;
        pub const FT_EXPLAIN_REPLY: u8 = 0x0D;
    "#;
    const OBS_OK: &str = r#"
        pub const M_REQUESTS: &str = "amsearch_requests_total";
        pub const M_LATENCY: &str = "amsearch_latency_ns";
        pub const M_QUALITY_RECALL: &str = "amsearch_quality_recall";
    "#;
    const TESTS_OK: &[&str] = &[
        "ERR_A",
        "ERR_B",
        "TRACED_VERSION",
        "FT_EXPLAIN",
        "FT_EXPLAIN_REPLY",
        "M_QUALITY_RECALL",
    ];
    const PERSIST_OK: &str = r#"
        const VERSION: u32 = 4;
        pub(crate) const SHARD_MANIFEST_VERSION: u32 = 3;
        fn load(version: u32) {
            if version == 0 || version == SHARD_MANIFEST_VERSION || version > VERSION {}
            let _ = version >= 2;
            let _ = version >= 4;
        }
    "#;
    const PLAN_OK: &str = "fn f(version: u32) { if version != SHARD_MANIFEST_VERSION {} }";
    const SERVER_OK: &str =
        "fn start() { let kernel_backend = factory.index.kernel_backend(); }";
    const README_OK: &str = r#"
| code | name | meaning |
|---|---|---|
| 1 | `ERR_A` | a |
| 2 | `ERR_B` | b |

| id | frame | meaning |
|---|---|---|
| `0x0C` | EXPLAIN | replay one query |
| `0x0D` | EXPLAIN_REPLY | introspection report |

| metric | meaning |
|---|---|
| `amsearch_quality_recall` | online recall estimate |

| version | notes |
|---|---|
| v1 | base |
| v2 | top-k |
| v3 | shard manifest |
| v4 | quant (current) |

STATS reports the selected backend under `kernel.backend`.

| metric | meaning |
|---|---|
| `amsearch_requests_total` | requests |
| `amsearch_latency_ns` | latency |

A v2 SEARCH frame appends a `trace_id` trailer.
"#;

    fn run(wire: &str, persist: &str, plan: &str, readme: &str, tests: &[&str]) -> Vec<Finding> {
        run_full(wire, persist, plan, SERVER_OK, OBS_OK, readme, tests)
    }

    fn run_with_server(
        wire: &str,
        persist: &str,
        plan: &str,
        server: &str,
        readme: &str,
        tests: &[&str],
    ) -> Vec<Finding> {
        run_full(wire, persist, plan, server, OBS_OK, readme, tests)
    }

    fn run_full(
        wire: &str,
        persist: &str,
        plan: &str,
        server: &str,
        obs: &str,
        readme: &str,
        tests: &[&str],
    ) -> Vec<Finding> {
        let test_idents: BTreeSet<String> = tests.iter().map(|s| s.to_string()).collect();
        let mut out = Vec::new();
        check(
            &DriftInput { wire, persist, plan, server, obs, readme, test_idents: &test_idents },
            &mut out,
        );
        out
    }

    #[test]
    fn clean_tree_passes() {
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, README_OK, TESTS_OK);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn untested_and_undocumented_codes_flagged() {
        let got = run(
            WIRE_OK,
            PERSIST_OK,
            PLAN_OK,
            README_OK,
            &["ERR_A", "TRACED_VERSION", "FT_EXPLAIN", "FT_EXPLAIN_REPLY", "M_QUALITY_RECALL"],
        );
        assert_eq!(got.len(), 1);
        assert!(got[0].message.contains("ERR_B"));
        assert!(got[0].message.contains("not asserted"));
        let readme_missing = README_OK.replace("| 2 | `ERR_B` | b |\n", "");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme_missing, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("error-table row"));
    }

    #[test]
    fn stale_readme_constant_flagged() {
        let readme = format!("{README_OK}\nAlso see `ERR_GONE`.\n");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("ERR_GONE"));
    }

    #[test]
    fn duplicate_and_gapped_codes_flagged() {
        let wire = "pub const ERR_A: u16 = 1;\npub const ERR_B: u16 = 1;";
        let got = run(wire, PERSIST_OK, PLAN_OK, README_OK, TESTS_OK);
        assert!(got.iter().any(|f| f.message.contains("reuses")));
        assert!(got.iter().any(|f| f.message.contains("contiguous")));
    }

    #[test]
    fn version_bump_without_gate_flagged() {
        let persist = PERSIST_OK.replace("VERSION: u32 = 4", "VERSION: u32 = 5");
        let got = run(WIRE_OK, &persist, PLAN_OK, README_OK, TESTS_OK);
        assert!(
            got.iter().any(|f| f.message.contains("no `version >= 5` feature gate")),
            "{got:?}"
        );
    }

    #[test]
    fn gate_beyond_version_flagged() {
        let persist = PERSIST_OK.replace("version >= 4", "version >= 9");
        let got = run(WIRE_OK, &persist, PLAN_OK, README_OK, TESTS_OK);
        assert!(got.iter().any(|f| f.message.contains("outside 2..=4")), "{got:?}");
    }

    #[test]
    fn kernel_stats_row_checked() {
        let got = run_with_server(
            WIRE_OK,
            PERSIST_OK,
            PLAN_OK,
            "fn start() {}",
            README_OK,
            TESTS_OK,
        );
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("kernel_backend"));
        let readme = README_OK.replace("kernel.backend", "kernel backend");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("kernel.backend"));
    }

    #[test]
    fn readme_version_rows_checked() {
        let readme = README_OK.replace("| v4 | quant (current) |", "| v4 | quant |");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert!(got.iter().any(|f| f.message.contains("must say \"current\"")), "{got:?}");
        let readme = README_OK.replace("| v3 | shard manifest |", "| v3 | reserved (current) |");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert!(got.iter().any(|f| f.message.contains("shard")), "{got:?}");
        assert!(got.iter().any(|f| f.message.contains("but VERSION")), "{got:?}");
    }

    #[test]
    fn metric_families_checked() {
        let tests = TESTS_OK;
        // undocumented family
        let readme = README_OK.replace("| `amsearch_latency_ns` | latency |\n", "");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, tests);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("amsearch_latency_ns"));
        assert!(got[0].message.contains("README"));
        // un-prefixed name
        let obs = OBS_OK.replace("\"amsearch_latency_ns\"", "\"latency_ns\"");
        let got = run_full(WIRE_OK, PERSIST_OK, PLAN_OK, SERVER_OK, &obs, README_OK, tests);
        assert!(
            got.iter().any(|f| f.message.contains("not `amsearch_`-prefixed")),
            "{got:?}"
        );
        // duplicated name
        let obs = OBS_OK.replace("\"amsearch_latency_ns\"", "\"amsearch_requests_total\"");
        let got = run_full(WIRE_OK, PERSIST_OK, PLAN_OK, SERVER_OK, &obs, README_OK, tests);
        assert!(got.iter().any(|f| f.message.contains("reuses metric name")), "{got:?}");
        // constants vanished entirely (e.g. the module was renamed)
        let got = run_full(WIRE_OK, PERSIST_OK, PLAN_OK, SERVER_OK, "", README_OK, tests);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("no `M_*"));
    }

    #[test]
    fn traced_wire_version_checked() {
        // constant removed
        let wire = WIRE_OK.replace("pub const TRACED_VERSION: u8 = 2;\n", "");
        let got = run(&wire, PERSIST_OK, PLAN_OK, README_OK, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("TRACED_VERSION"));
        // constant present but no test pins its value
        let got = run(
            WIRE_OK,
            PERSIST_OK,
            PLAN_OK,
            README_OK,
            &["ERR_A", "ERR_B", "FT_EXPLAIN", "FT_EXPLAIN_REPLY", "M_QUALITY_RECALL"],
        );
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("not asserted"));
        // README stops documenting the trailer field
        let readme = README_OK.replace("`trace_id` trailer", "an id trailer");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("trace_id"));
    }

    #[test]
    fn explain_frame_ids_checked() {
        // constant removed
        let wire = WIRE_OK.replace("pub const FT_EXPLAIN_REPLY: u8 = 0x0D;\n", "");
        let got = run(&wire, PERSIST_OK, PLAN_OK, README_OK, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("no `FT_EXPLAIN_REPLY: u8`"));
        // constant present but no test pins its id
        let got = run(
            WIRE_OK,
            PERSIST_OK,
            PLAN_OK,
            README_OK,
            &["ERR_A", "ERR_B", "TRACED_VERSION", "FT_EXPLAIN_REPLY", "M_QUALITY_RECALL"],
        );
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("`FT_EXPLAIN` (frame type 0x0C) is not asserted"));
        // id renumbered without moving the README frame-table row
        let wire = WIRE_OK.replace("FT_EXPLAIN_REPLY: u8 = 0x0D", "FT_EXPLAIN_REPLY: u8 = 0x0E");
        let got = run(&wire, PERSIST_OK, PLAN_OK, README_OK, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("`EXPLAIN_REPLY` and `0x0E`"), "{got:?}");
        // README frame row dropped entirely
        let readme = README_OK.replace("| `0x0C` | EXPLAIN | replay one query |\n", "");
        let got = run(WIRE_OK, PERSIST_OK, PLAN_OK, &readme, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("`EXPLAIN` and `0x0C`"), "{got:?}");
    }

    #[test]
    fn quality_families_checked() {
        // family exists but no test pins its constant
        let got = run(
            WIRE_OK,
            PERSIST_OK,
            PLAN_OK,
            README_OK,
            &["ERR_A", "ERR_B", "TRACED_VERSION", "FT_EXPLAIN", "FT_EXPLAIN_REPLY"],
        );
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(
            got[0].message.contains("`amsearch_quality_recall` (`M_QUALITY_RECALL`) is not pinned"),
            "{got:?}"
        );
        // every quality family vanished while other metrics remain
        let obs = OBS_OK
            .replace("pub const M_QUALITY_RECALL: &str = \"amsearch_quality_recall\";\n", "");
        let readme =
            README_OK.replace("| `amsearch_quality_recall` | online recall estimate |\n", "");
        let got = run_full(WIRE_OK, PERSIST_OK, PLAN_OK, SERVER_OK, &obs, &readme, TESTS_OK);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("no `amsearch_quality_*`"), "{got:?}");
    }
}
