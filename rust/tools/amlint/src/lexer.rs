//! A minimal hand-rolled Rust lexer: just enough token structure for the
//! rule engine — identifiers, punctuation, literals, comments, lifetimes
//! — with line numbers, and with strings/comments properly consumed so a
//! `panic!` inside a string literal never looks like code.
//!
//! Deliberately not a full Rust lexer: float-literal edge cases may split
//! into several `Lit` tokens and shebang/frontmatter is not handled.
//! Neither affects any rule: rules only match identifier/punctuation
//! sequences outside comments and literals.

/// Token kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword.
    Ident,
    /// Single punctuation character.
    Punct,
    /// String / char / byte / numeric literal.
    Lit,
    /// Line or block comment (text retained for annotation parsing).
    Comment,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
}

/// One lexed token.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token kind.
    pub kind: Kind,
    /// Raw token text.
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a token stream.  Unknown bytes become `Punct` tokens;
/// the lexer never fails.
pub fn lex(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let text_of = |a: usize, b: usize| -> String { chars[a..b].iter().collect() };
    let count_lines = |a: usize, b: usize| -> usize {
        chars[a..b].iter().filter(|&&c| c == '\n').count()
    };
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Comment, text: text_of(i, j), line });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Comment, text: text_of(i, j), line: start_line });
            i = j;
            continue;
        }
        // raw strings: r"..." / r#"..."# / br#"..."#
        if c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                j += 1;
                // scan for `"` followed by `hashes` hash marks
                'raw: while j < n {
                    if chars[j] == '"' {
                        let mut k = j + 1;
                        let mut seen = 0usize;
                        while k < n && seen < hashes && chars[k] == '#' {
                            seen += 1;
                            k += 1;
                        }
                        if seen == hashes {
                            j = k;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                let start_line = line;
                line += count_lines(i, j);
                toks.push(Tok { kind: Kind::Lit, text: text_of(i, j), line: start_line });
                i = j;
                continue;
            }
            // not a raw string: fall through to ident handling
        }
        // plain / byte strings
        if c == '"' || (c == 'b' && i + 1 < n && chars[i + 1] == '"') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                    continue;
                }
                if chars[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let j = j.min(n);
            let start_line = line;
            line += count_lines(i, j);
            toks.push(Tok { kind: Kind::Lit, text: text_of(i, j), line: start_line });
            i = j;
            continue;
        }
        // lifetime vs char literal
        if c == '\'' {
            let next_is_ident =
                i + 1 < n && (chars[i + 1].is_alphanumeric() || chars[i + 1] == '_');
            let closes = i + 2 < n && chars[i + 2] == '\'';
            if next_is_ident && !closes {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                toks.push(Tok { kind: Kind::Lifetime, text: text_of(i, j), line });
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                    continue;
                }
                if chars[j] == '\'' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let j = j.min(n);
            toks.push(Tok { kind: Kind::Lit, text: text_of(i, j), line });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: text_of(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let d = chars[j];
                if d == '.' {
                    // stop at `..` / method calls on numbers; continue
                    // through a decimal point followed by a digit
                    if j + 1 < n && chars[j + 1].is_ascii_digit() {
                        j += 1;
                        continue;
                    }
                    break;
                }
                if d.is_alphanumeric() || d == '_' {
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok { kind: Kind::Lit, text: text_of(i, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_lits() {
        let got = kinds("let x = 42;");
        assert_eq!(
            got,
            vec![
                (Kind::Ident, "let".into()),
                (Kind::Ident, "x".into()),
                (Kind::Punct, "=".into()),
                (Kind::Lit, "42".into()),
                (Kind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn panics_inside_strings_are_literals() {
        let toks = lex(r#"let s = "panic!(x.unwrap())";"#);
        assert!(toks.iter().all(|t| t.kind != Kind::Ident || t.text != "panic"));
        assert!(toks.iter().any(|t| t.kind == Kind::Lit && t.text.contains("panic")));
    }

    #[test]
    fn comments_are_retained_with_lines() {
        let toks = lex("// one\nlet x = 1; // two\n/* three\nspans */ let y = 2;");
        let comments: Vec<(usize, &str)> = toks
            .iter()
            .filter(|t| t.kind == Kind::Comment)
            .map(|t| (t.line, t.text.as_str()))
            .collect();
        assert_eq!(comments.len(), 3);
        assert_eq!(comments[0], (1, "// one"));
        assert_eq!(comments[1].0, 2);
        assert_eq!(comments[2].0, 3);
        // the ident after the multi-line block comment is on line 4
        let y = toks.iter().find(|t| t.text == "y").expect("y");
        assert_eq!(y.line, 4);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* b */ c */ fn f() {}");
        assert_eq!(toks[0].kind, Kind::Comment);
        assert_eq!(toks[1].text, "fn");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let lits: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lit)
            .map(|t| t.text.as_str())
            .collect();
        assert!(lits.contains(&"'x'"));
    }

    #[test]
    fn raw_strings_consume_hashes() {
        let toks = lex(r##"let s = r#"has "quotes" and unwrap()"#; let t = 1;"##);
        assert!(toks.iter().any(|t| t.kind == Kind::Lit && t.text.contains("quotes")));
        assert!(toks.iter().any(|t| t.text == "t"));
        assert!(!toks.iter().any(|t| t.kind == Kind::Ident && t.text == "unwrap"));
    }

    #[test]
    fn range_expressions_do_not_eat_idents() {
        let got = kinds("for i in 0..n_shards {}");
        assert!(got.contains(&(Kind::Lit, "0".into())));
        assert!(got.contains(&(Kind::Ident, "n_shards".into())));
    }

    #[test]
    fn unterminated_string_does_not_hang_or_panic() {
        let toks = lex("let s = \"open");
        assert_eq!(toks.last().map(|t| t.kind), Some(Kind::Lit));
    }
}
