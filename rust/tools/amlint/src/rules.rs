//! The repo-specific rule classes, implemented over the token stream
//! from [`crate::lexer`]:
//!
//! 1. `panic` — no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/
//!    `unimplemented!` outside `#[cfg(test)]` code in serving-path
//!    files, unless tagged `// amlint: allow(panic, reason = "...")`.
//! 2. `lock_order` / `lock_blocking` / `lock_registry` — a declared
//!    per-file registry of mutexes with a partial acquisition order;
//!    flags out-of-order nesting, blocking calls made while a guard is
//!    held, and locks on mutexes missing from the registry.
//! 3. drift — cross-file; lives in [`crate::drift`].
//! 4. `safety` — every `unsafe` must carry a `// SAFETY:` comment in
//!    the contiguous comment block directly above it (or on its line).
//! 5. `simd` — raw `std::arch` intrinsics stay inside
//!    `rust/src/search/kernels/`, and every `#[target_feature]` fn is
//!    `unsafe` with a `// SAFETY:` comment naming the runtime check.
//! 6. `store_io` — storage-I/O hygiene on the serving path: no
//!    memory-mapped I/O anywhere (paging goes through the checked
//!    `pread` reader), no `unsafe` at all inside `store/`, and no
//!    `let _ =` discards of `io::Result`-returning read/write/flush
//!    calls.
//!
//! The lock rules are intra-procedural and textual: a guard is tracked
//! from its acquisition token to the end of its enclosing block (`let` /
//! `if let` / `while let` / `match` bindings), to the end of its
//! statement (un-bound temporaries), or to an explicit `drop(guard)`.
//! That over-approximates guard lifetimes (a `let`-bound value that is
//! not actually a guard is still tracked), which can only produce
//! findings to annotate, never silently missed ones.

use std::collections::BTreeSet;

use crate::lexer::{Kind, Tok};

/// Methods that panic on the error/none case.
const PANIC_METHODS: [&str; 4] = ["unwrap", "unwrap_err", "expect", "expect_err"];
/// Macros that unconditionally panic.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
/// Calls that can block indefinitely while a guard is held.  `Condvar`
/// waits are deliberately absent: they atomically release the guard.
const BLOCKING_CALLS: [&str; 9] = [
    "send",
    "recv",
    "recv_timeout",
    "write_all",
    "write",
    "flush",
    "read",
    "read_exact",
    "read_to_end",
];

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the repo root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule name (`panic`, `lock_order`, `lock_blocking`,
    /// `lock_registry`, `safety`, `simd`, `store_io`, `drift`).
    pub rule: &'static str,
    /// Human-readable description.
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Indices of non-comment tokens, in stream order.
fn code_indices(toks: &[Tok]) -> Vec<usize> {
    (0..toks.len()).filter(|&i| toks[i].kind != Kind::Comment).collect()
}

/// Token-index ranges (over the code-index list) covered by
/// `#[cfg(test)]` / `#[test]` items, nested braces included.
fn test_regions(toks: &[Tok], code: &[usize]) -> Vec<(usize, usize)> {
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if t(i).text == "#" && i + 1 < code.len() && t(i + 1).text == "[" {
            // collect the attribute's tokens up to the matching `]`
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut inner = String::new();
            while j < code.len() && depth > 0 {
                match t(j).text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {}
                }
                if depth > 0 {
                    inner.push_str(&t(j).text);
                }
                j += 1;
            }
            if inner == "cfg(test)" || inner == "test" {
                // skip any further attributes on the same item
                let mut k = j;
                while k + 1 < code.len() && t(k).text == "#" && t(k + 1).text == "[" {
                    let mut d = 1usize;
                    k += 2;
                    while k < code.len() && d > 0 {
                        match t(k).text.as_str() {
                            "[" => d += 1,
                            "]" => d -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                }
                // the item body is the first `{` before any `;`
                while k < code.len() && t(k).text != "{" && t(k).text != ";" {
                    k += 1;
                }
                if k < code.len() && t(k).text == "{" {
                    let mut d = 1usize;
                    let mut e = k + 1;
                    while e < code.len() && d > 0 {
                        match t(e).text.as_str() {
                            "{" => d += 1,
                            "}" => d -= 1,
                            _ => {}
                        }
                        e += 1;
                    }
                    regions.push((i, e));
                    i = e;
                    continue;
                }
            }
            i = j;
            continue;
        }
        i += 1;
    }
    regions
}

fn in_regions(ci: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(s, e)| s <= ci && ci < e)
}

/// All identifiers appearing inside `#[cfg(test)]` / `#[test]` regions.
/// The drift rule uses this to check that every wire error code is
/// exercised by at least one test assertion.
pub fn idents_in_test_regions(toks: &[Tok]) -> BTreeSet<String> {
    let code = code_indices(toks);
    let regions = test_regions(toks, &code);
    let mut out = BTreeSet::new();
    for (ci, &ti) in code.iter().enumerate() {
        if toks[ti].kind == Kind::Ident && in_regions(ci, &regions) {
            out.insert(toks[ti].text.clone());
        }
    }
    out
}

/// Parse one comment for `amlint: allow(<rule>, reason = "...")`.
/// The reason string must be non-empty.
pub fn allow_in_comment(text: &str) -> Option<&str> {
    let rest = text.split("amlint:").nth(1)?;
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?.trim_start();
    let rule_end = rest.find(|c: char| !c.is_alphanumeric() && c != '_')?;
    let (rule, rest) = rest.split_at(rule_end);
    let rest = rest.trim_start().strip_prefix(',')?.trim_start();
    let rest = rest.strip_prefix("reason")?.trim_start();
    let rest = rest.strip_prefix('=')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    let close = rest.find('"')?;
    let reason = &rest[..close];
    let tail = rest[close + 1..].trim_start();
    if reason.trim().is_empty() || !tail.starts_with(')') || rule.is_empty() {
        return None;
    }
    Some(rule)
}

/// Lines covered by an `allow(rule, ...)` annotation: the annotation's
/// own line plus the next line that carries any code token (so the
/// annotation sits directly above the code it excuses).
fn allowed_lines(toks: &[Tok], rule: &str) -> BTreeSet<usize> {
    let code_lines: BTreeSet<usize> = toks
        .iter()
        .filter(|t| t.kind != Kind::Comment)
        .map(|t| t.line)
        .collect();
    let mut out = BTreeSet::new();
    for t in toks {
        if t.kind != Kind::Comment {
            continue;
        }
        if allow_in_comment(&t.text) == Some(rule) {
            out.insert(t.line);
            if let Some(&next) = code_lines.range(t.line + 1..).next() {
                out.insert(next);
            }
        }
    }
    out
}

/// Rule 1: panic-freedom in the serving path.
pub fn rule_panic(file: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let code = code_indices(toks);
    let regions = test_regions(toks, &code);
    let allowed = allowed_lines(toks, "panic");
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };
    for ci in 0..code.len() {
        let tok = t(ci);
        if tok.kind != Kind::Ident {
            continue;
        }
        let name = tok.text.as_str();
        let flagged = if PANIC_METHODS.contains(&name) {
            ci > 0
                && t(ci - 1).text == "."
                && ci + 1 < code.len()
                && t(ci + 1).text == "("
        } else if PANIC_MACROS.contains(&name) {
            // a macro invocation, not a method/path segment of that name
            ci + 1 < code.len()
                && t(ci + 1).text == "!"
                && (ci == 0 || (t(ci - 1).text != "." && t(ci - 1).text != ":"))
        } else {
            false
        };
        if !flagged || in_regions(ci, &regions) || allowed.contains(&tok.line) {
            continue;
        }
        let what = if PANIC_METHODS.contains(&name) {
            format!("`.{name}()`")
        } else {
            format!("`{name}!`")
        };
        out.push(Finding {
            file: file.to_string(),
            line: tok.line,
            rule: "panic",
            message: format!(
                "{what} in serving-path code — return an error or tag \
                 `// amlint: allow(panic, reason = \"...\")`"
            ),
        });
    }
}

/// Lines covered by outer `#[...]` attributes.  Comment-block walks
/// treat these as transparent: a `// SAFETY:` comment above a
/// `#[target_feature]` / `#[inline]` stack still covers the `unsafe fn`
/// below it.
fn attribute_lines(toks: &[Tok], code: &[usize]) -> BTreeSet<usize> {
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };
    let mut out = BTreeSet::new();
    let mut ci = 0usize;
    while ci < code.len() {
        if t(ci).text == "#" && ci + 1 < code.len() && t(ci + 1).text == "[" {
            out.insert(t(ci).line);
            let mut depth = 1usize;
            let mut j = ci + 2;
            while j < code.len() && depth > 0 {
                match t(j).text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {}
                }
                out.insert(t(j).line);
                j += 1;
            }
            ci = j;
            continue;
        }
        ci += 1;
    }
    out
}

/// Rule 4: every `unsafe` must carry a `// SAFETY:` comment directly
/// above it (contiguous comment block; blank lines end the block,
/// attribute lines are transparent) or on its own line.
pub fn rule_safety(file: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let mut comment_lines: std::collections::BTreeMap<usize, Vec<&str>> =
        std::collections::BTreeMap::new();
    for t in toks {
        if t.kind == Kind::Comment {
            comment_lines.entry(t.line).or_default().push(&t.text);
        }
    }
    let allowed = allowed_lines(toks, "safety");
    let code = code_indices(toks);
    let attrs = attribute_lines(toks, &code);
    for &i in &code {
        let tok = &toks[i];
        if tok.kind != Kind::Ident || tok.text != "unsafe" {
            continue;
        }
        let has_safety = |lines: &[&str]| lines.iter().any(|c| c.contains("SAFETY:"));
        let mut ok = comment_lines
            .get(&tok.line)
            .is_some_and(|c| has_safety(c));
        // walk the contiguous comment block directly above, stepping
        // over attribute-only lines (`#[target_feature(...)]`)
        let mut l = tok.line.saturating_sub(1);
        while l > 0 {
            match comment_lines.get(&l) {
                Some(c) => {
                    if has_safety(c) {
                        ok = true;
                        break;
                    }
                    l -= 1;
                }
                None if attrs.contains(&l) => l -= 1,
                None => break,
            }
        }
        if !ok && !allowed.contains(&tok.line) {
            out.push(Finding {
                file: file.to_string(),
                line: tok.line,
                rule: "safety",
                message: "`unsafe` without a `// SAFETY:` comment directly above"
                    .to_string(),
            });
        }
    }
}

/// Identifier prefixes that mark raw SIMD intrinsics or vector types
/// (x86 `_mm*` / `__m*`, NEON loads and lane ops).
const INTRINSIC_PREFIXES: [&str; 8] =
    ["_mm", "__m", "float32x", "vld1", "vaddq", "vsubq", "vmulq", "vgetq"];

/// Rule 5: SIMD containment.  Raw `std::arch` / `core::arch` use may
/// only appear under `rust/src/search/kernels/` (everything else goes
/// through the `Kernels` dispatch handle, which is selected once per
/// index), and every `#[target_feature(enable = "X")]` function —
/// kernels included — must be declared `unsafe` and carry a
/// `// SAFETY:` comment directly above the attribute naming the `X`
/// runtime check its callers perform.
pub fn rule_simd(file: &str, toks: &[Tok], in_kernels: bool, out: &mut Vec<Finding>) {
    let code = code_indices(toks);
    let allowed = allowed_lines(toks, "simd");
    let attrs = attribute_lines(toks, &code);
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };
    let mut comment_lines: std::collections::BTreeMap<usize, Vec<&str>> =
        std::collections::BTreeMap::new();
    for tk in toks {
        if tk.kind == Kind::Comment {
            comment_lines.entry(tk.line).or_default().push(&tk.text);
        }
    }

    if !in_kernels {
        for ci in 0..code.len() {
            let tok = t(ci);
            if tok.kind != Kind::Ident || allowed.contains(&tok.line) {
                continue;
            }
            let name = tok.text.as_str();
            let arch_path = name == "arch"
                && ci >= 3
                && t(ci - 1).text == ":"
                && t(ci - 2).text == ":"
                && (t(ci - 3).text == "std" || t(ci - 3).text == "core");
            let intrinsic = INTRINSIC_PREFIXES.iter().any(|p| name.starts_with(p));
            if !arch_path && !intrinsic {
                continue;
            }
            let what = if arch_path {
                "`std::arch`/`core::arch` use".to_string()
            } else {
                format!("raw SIMD intrinsic `{name}`")
            };
            out.push(Finding {
                file: file.to_string(),
                line: tok.line,
                rule: "simd",
                message: format!(
                    "{what} outside `rust/src/search/kernels/` — vector code \
                     goes through the `Kernels` dispatch layer, or tag \
                     `// amlint: allow(simd, reason = \"...\")`"
                ),
            });
        }
    }

    // `#[target_feature(...)]` contract, enforced in every file
    let mut ci = 0usize;
    while ci < code.len() {
        if !(t(ci).text == "#" && ci + 1 < code.len() && t(ci + 1).text == "[") {
            ci += 1;
            continue;
        }
        let attr_line = t(ci).line;
        let mut depth = 1usize;
        let mut j = ci + 2;
        let mut inner: Vec<&Tok> = Vec::new();
        while j < code.len() && depth > 0 {
            match t(j).text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                _ => {}
            }
            if depth > 0 {
                inner.push(t(j));
            }
            j += 1;
        }
        if inner.first().map(|tk| tk.text.as_str()) != Some("target_feature") {
            ci = j;
            continue;
        }
        let features: Vec<String> = inner
            .iter()
            .filter(|tk| tk.kind == Kind::Lit && tk.text.starts_with('"'))
            .map(|tk| tk.text.trim_matches('"').to_string())
            .collect();
        // collect the contiguous comment block above (and on) the
        // attribute line; attribute lines in a stack are transparent
        let mut block = String::new();
        let grab = |l: usize, block: &mut String| -> bool {
            match comment_lines.get(&l) {
                Some(cs) => {
                    for c in cs {
                        block.push_str(c);
                        block.push('\n');
                    }
                    true
                }
                None => false,
            }
        };
        grab(attr_line, &mut block);
        let mut l = attr_line.saturating_sub(1);
        while l > 0 {
            if grab(l, &mut block) || attrs.contains(&l) {
                l -= 1;
            } else {
                break;
            }
        }
        // skip any further attributes, then look for `unsafe` ... `fn`
        let mut k = j;
        while k + 1 < code.len() && t(k).text == "#" && t(k + 1).text == "[" {
            let mut d = 1usize;
            k += 2;
            while k < code.len() && d > 0 {
                match t(k).text.as_str() {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                k += 1;
            }
        }
        let mut saw_unsafe = false;
        let mut is_fn = false;
        while k < code.len() {
            match t(k).text.as_str() {
                "unsafe" => saw_unsafe = true,
                "fn" => {
                    is_fn = true;
                    break;
                }
                "{" | ";" | "}" => break,
                _ => {}
            }
            k += 1;
        }
        if is_fn && !allowed.contains(&attr_line) {
            if !saw_unsafe {
                out.push(Finding {
                    file: file.to_string(),
                    line: attr_line,
                    rule: "simd",
                    message: format!(
                        "`#[target_feature(enable = \"{}\")]` fn must be declared \
                         `unsafe` so callers inherit the CPU-feature contract",
                        features.join("\", \"")
                    ),
                });
            }
            if !block.contains("SAFETY:")
                || features.iter().any(|f| !block.contains(f.as_str()))
            {
                out.push(Finding {
                    file: file.to_string(),
                    line: attr_line,
                    rule: "simd",
                    message: format!(
                        "`#[target_feature]` needs a `// SAFETY:` comment directly \
                         above naming the `{}` runtime check its callers perform",
                        features.join("`, `")
                    ),
                });
            }
        }
        ci = j;
    }
}

/// I/O methods whose `io::Result` must not be silently discarded on
/// the serving path.  `let _ = stream.write_all(..)` defeats rustc's
/// `#[must_use]` on `Result`; this rule closes that loophole (a bare
/// `stream.write_all(..);` statement is already an `unused_must_use`
/// error under the workspace's `-D warnings` CI).
const IO_CALLS: [&str; 10] = [
    "write_all",
    "write",
    "flush",
    "read",
    "read_exact",
    "read_to_end",
    "read_exact_at",
    "read_at",
    "sync_all",
    "sync_data",
];

/// Identifiers that mark memory-mapped I/O (libc `mmap`, the memmap
/// crates).  The paged store deliberately reads with checked `pread`
/// calls instead: a memory-mapped file truncated underneath the
/// process turns every later page fault into SIGBUS, which no Rust
/// error path can catch.
const MMAP_IDENTS: [&str; 7] =
    ["mmap", "mmap64", "munmap", "Mmap", "MmapMut", "MmapOptions", "memmap2"];

/// Rule 6: storage-I/O hygiene on the serving path.  Three checks:
/// memory-mapped I/O is forbidden in serving code (paging goes through
/// the checked `pread` reader in `store/paged.rs`); the `store/` tree
/// itself must stay free of `unsafe` (its whole value is that paging
/// needs none); and `let _ =` must not discard the `io::Result` of a
/// read/write/flush call — that pattern turns torn writes and short
/// reads into silent corruption.  Test regions are exempt; sites are
/// excused with `// amlint: allow(store_io, reason = "...")`.
pub fn rule_store_io(file: &str, toks: &[Tok], in_store: bool, out: &mut Vec<Finding>) {
    let code = code_indices(toks);
    let regions = test_regions(toks, &code);
    let allowed = allowed_lines(toks, "store_io");
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };

    for ci in 0..code.len() {
        let tok = t(ci);
        if tok.kind != Kind::Ident
            || in_regions(ci, &regions)
            || allowed.contains(&tok.line)
        {
            continue;
        }
        let name = tok.text.as_str();
        if in_store && name == "unsafe" {
            out.push(Finding {
                file: file.to_string(),
                line: tok.line,
                rule: "store_io",
                message: "`unsafe` inside `store/` — the paged reader is pure \
                          safe `pread` code by design; move unsafe elsewhere or \
                          tag `// amlint: allow(store_io, reason = \"...\")`"
                    .to_string(),
            });
        } else if MMAP_IDENTS.contains(&name) {
            out.push(Finding {
                file: file.to_string(),
                line: tok.line,
                rule: "store_io",
                message: format!(
                    "memory-mapped I/O (`{name}`) in the serving path — paging \
                     goes through the checked `pread` reader in \
                     `store/paged.rs`, or tag \
                     `// amlint: allow(store_io, reason = \"...\")`"
                ),
            });
        }
    }

    // `let _ = <expr containing an io call>;` — walk each discard
    // statement to its terminating `;` and look for `.call(` receivers
    let mut ci = 0usize;
    while ci < code.len() {
        let is_discard = t(ci).text == "let"
            && ci + 2 < code.len()
            && t(ci + 1).text == "_"
            && t(ci + 2).text == "=";
        if !is_discard {
            ci += 1;
            continue;
        }
        let stmt_line = t(ci).line;
        let exempt = in_regions(ci, &regions) || allowed.contains(&stmt_line);
        let mut depth = 0isize;
        let mut j = ci + 3;
        let mut io_hit: Option<String> = None;
        while j < code.len() {
            let tj = t(j);
            match tj.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                ";" if depth <= 0 => break,
                _ => {}
            }
            if io_hit.is_none()
                && tj.kind == Kind::Ident
                && IO_CALLS.contains(&tj.text.as_str())
                && j > 0
                && t(j - 1).text == "."
                && j + 1 < code.len()
                && t(j + 1).text == "("
            {
                io_hit = Some(tj.text.clone());
            }
            j += 1;
        }
        if let Some(call) = io_hit {
            if !exempt {
                out.push(Finding {
                    file: file.to_string(),
                    line: stmt_line,
                    rule: "store_io",
                    message: format!(
                        "`let _ =` discards the `io::Result` of `.{call}()` — \
                         handle or propagate it, or tag \
                         `// amlint: allow(store_io, reason = \"...\")`"
                    ),
                });
            }
        }
        ci = j;
    }
}

/// How long a tracked guard lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// Until brace depth drops below this value.
    Block(usize),
    /// Until the next `;` at the acquisition depth.
    Statement,
}

#[derive(Debug, Clone)]
struct Guard {
    name: String,
    rank: Option<usize>,
    scope: Scope,
    binding: Option<String>,
}

/// Rule 2: lock discipline against a declared registry.  `registry`
/// lists the file's mutexes in acquisition order (a lock may only be
/// taken while holding locks that appear strictly earlier).
pub fn rule_locks(
    file: &str,
    toks: &[Tok],
    registry: &[&str],
    out: &mut Vec<Finding>,
) {
    let code = code_indices(toks);
    let regions = test_regions(toks, &code);
    let allow_order = allowed_lines(toks, "lock_order");
    let allow_blocking = allowed_lines(toks, "lock_blocking");
    let allow_registry = allowed_lines(toks, "lock_registry");
    let rank_of = |name: &str| registry.iter().position(|&r| r == name);
    let t = |ci: usize| -> &Tok { &toks[code[ci]] };

    let mut depth = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt_start = 0usize;
    let mut ci = 0usize;
    while ci < code.len() {
        let tok = t(ci);
        match tok.text.as_str() {
            "{" if tok.kind == Kind::Punct => {
                depth += 1;
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            "}" if tok.kind == Kind::Punct => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| match g.scope {
                    Scope::Block(d) => d <= depth,
                    // a block close also ends any tail-expression
                    // temporary (no `;` follows a tail expression)
                    Scope::Statement => false,
                });
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            ";" if tok.kind == Kind::Punct => {
                guards.retain(|g| g.scope != Scope::Statement);
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            _ => {}
        }
        // explicit `drop(guard)`
        if tok.kind == Kind::Ident
            && tok.text == "drop"
            && ci + 2 < code.len()
            && t(ci + 1).text == "("
            && t(ci + 2).kind == Kind::Ident
        {
            let victim = t(ci + 2).text.clone();
            guards.retain(|g| g.binding.as_deref() != Some(victim.as_str()));
        }
        // acquisition: `<recv> . lock (` or `lock_unpoisoned( ... <name> )`
        let mut acquired: Option<String> = None;
        if tok.kind == Kind::Ident
            && tok.text == "lock"
            && ci >= 2
            && t(ci - 1).text == "."
            && t(ci - 2).kind == Kind::Ident
            && ci + 1 < code.len()
            && t(ci + 1).text == "("
        {
            acquired = Some(t(ci - 2).text.clone());
        }
        if tok.kind == Kind::Ident
            && tok.text == "lock_unpoisoned"
            && ci + 1 < code.len()
            && t(ci + 1).text == "("
        {
            // the mutex name is the last top-level ident in the arguments
            let mut j = ci + 2;
            let mut d = 1usize;
            let mut last: Option<String> = None;
            while j < code.len() && d > 0 {
                match t(j).text.as_str() {
                    "(" => d += 1,
                    ")" => d -= 1,
                    _ => {
                        if d == 1 && t(j).kind == Kind::Ident {
                            last = Some(t(j).text.clone());
                        }
                    }
                }
                j += 1;
            }
            acquired = last;
        }
        if let Some(name) = acquired {
            if !in_regions(ci, &regions) {
                let rank = rank_of(&name);
                if rank.is_none() && !allow_registry.contains(&tok.line) {
                    out.push(Finding {
                        file: file.to_string(),
                        line: tok.line,
                        rule: "lock_registry",
                        message: format!(
                            "lock on `{name}`, which is not in the declared mutex \
                             registry for this file — add it (with its order) to \
                             amlint's registry"
                        ),
                    });
                }
                if let Some(r) = rank {
                    for g in &guards {
                        if let Some(gr) = g.rank {
                            if gr >= r && !allow_order.contains(&tok.line) {
                                out.push(Finding {
                                    file: file.to_string(),
                                    line: tok.line,
                                    rule: "lock_order",
                                    message: format!(
                                        "`{name}` acquired while holding `{}` — \
                                         violates the declared acquisition order",
                                        g.name
                                    ),
                                });
                            }
                        }
                    }
                }
                // classify the guard's lifetime from the statement head
                let head: Vec<&str> =
                    (stmt_start..ci).map(|k| t(k).text.as_str()).collect();
                let (scope, binding) = if head.first() == Some(&"let") {
                    let mut h = &head[1..];
                    if h.first() == Some(&"mut") {
                        h = &h[1..];
                    }
                    let binding = h
                        .first()
                        .filter(|s| {
                            s.chars().all(|c| c.is_alphanumeric() || c == '_')
                        })
                        .map(|s| s.to_string());
                    (Scope::Block(depth), binding)
                } else if matches!(head.first(), Some(&"if") | Some(&"while"))
                    && head.contains(&"let")
                {
                    (Scope::Block(depth), None)
                } else if matches!(head.first(), Some(&"match") | Some(&"for")) {
                    (Scope::Block(depth), None)
                } else {
                    (Scope::Statement, None)
                };
                guards.push(Guard { name, rank, scope, binding });
            }
        }
        // blocking call while a registry guard is held
        if tok.kind == Kind::Ident
            && BLOCKING_CALLS.contains(&tok.text.as_str())
            && ci > 0
            && (t(ci - 1).text == "." || t(ci - 1).text == ":")
            && ci + 1 < code.len()
            && t(ci + 1).text == "("
            && !in_regions(ci, &regions)
        {
            let held: Vec<&str> = guards
                .iter()
                .filter(|g| g.rank.is_some())
                .map(|g| g.name.as_str())
                .collect();
            if !held.is_empty() && !allow_blocking.contains(&tok.line) {
                out.push(Finding {
                    file: file.to_string(),
                    line: tok.line,
                    rule: "lock_blocking",
                    message: format!(
                        "blocking `{}()` while holding `{}` — move the call out \
                         of the critical section or tag \
                         `// amlint: allow(lock_blocking, reason = \"...\")`",
                        tok.text,
                        held.join("`, `")
                    ),
                });
            }
        }
        ci += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn panics(src: &str) -> Vec<Finding> {
        let toks = lex(src);
        let mut out = Vec::new();
        rule_panic("f.rs", &toks, &mut out);
        out
    }

    #[test]
    fn flags_unwrap_and_macros() {
        let found = panics("fn f() { x.unwrap(); panic!(\"no\"); }");
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].rule, "panic");
    }

    #[test]
    fn ignores_test_code_and_lookalikes() {
        let src = r#"
            #[cfg(test)]
            mod tests {
                fn g() { x.unwrap(); }
            }
            fn ok() { x.unwrap_or(0); std::panic::catch_unwind(f); }
        "#;
        assert!(panics(src).is_empty());
    }

    #[test]
    fn allow_needs_nonempty_reason() {
        assert_eq!(
            allow_in_comment(r#"// amlint: allow(panic, reason = "fixture only")"#),
            Some("panic")
        );
        assert_eq!(allow_in_comment(r#"// amlint: allow(panic, reason = "")"#), None);
        assert_eq!(allow_in_comment("// amlint: allow(panic)"), None);
    }

    #[test]
    fn annotation_covers_next_code_line_only() {
        let src = r#"
            fn f() {
                // amlint: allow(panic, reason = "checked above")
                x.unwrap();
                y.unwrap();
            }
        "#;
        let found = panics(src);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].line, 5);
    }

    #[test]
    fn safety_rule_accepts_block_above_and_same_line() {
        let ok = r#"
            // SAFETY: disjoint slots
            unsafe { *p = 1; }
            unsafe impl Send for T {} // SAFETY: no shared state
        "#;
        let mut out = Vec::new();
        rule_safety("f.rs", &lex(ok), &mut out);
        assert!(out.is_empty(), "{out:?}");
        let bad = "fn f() { unsafe { *p = 1; } }";
        let mut out = Vec::new();
        rule_safety("f.rs", &lex(bad), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "safety");
    }

    #[test]
    fn safety_comment_does_not_leak_across_blank_line() {
        let src = "// SAFETY: stale\n\nfn f() { unsafe { *p = 1; } }";
        let mut out = Vec::new();
        rule_safety("f.rs", &lex(src), &mut out);
        assert_eq!(out.len(), 1);
    }

    fn locks(src: &str, registry: &[&str]) -> Vec<Finding> {
        let mut out = Vec::new();
        rule_locks("f.rs", &lex(src), registry, &mut out);
        out
    }

    #[test]
    fn out_of_order_nesting_flagged() {
        let src = r#"
            fn f(&self) {
                let m = self.metrics.lock();
                let t = self.tx.lock();
            }
        "#;
        let found = locks(src, &["tx", "metrics"]);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "lock_order");
        assert_eq!(found[0].line, 4);
    }

    #[test]
    fn in_order_nesting_passes() {
        let src = r#"
            fn f(&self) {
                let t = self.tx.lock();
                let m = self.metrics.lock();
            }
        "#;
        assert!(locks(src, &["tx", "metrics"]).is_empty());
    }

    #[test]
    fn statement_temporary_releases_at_semicolon_and_tail() {
        let src = r#"
            fn f(&self) -> M {
                *self.tx.lock() = None;
                self.metrics.lock().clone()
            }
            fn g(&self) {
                let t = self.tx.lock();
            }
        "#;
        // metrics is a tail expression; tx guard died at the `;` — and
        // neither may leak into `g`
        assert!(locks(src, &["tx", "metrics"]).is_empty());
    }

    #[test]
    fn blocking_call_under_guard_flagged_and_allowable() {
        let src = r#"
            fn f(&self) {
                let g = self.tx.lock();
                g.send(req);
            }
        "#;
        let found = locks(src, &["tx"]);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, "lock_blocking");
        let annotated = r#"
            fn f(&self) {
                let g = self.tx.lock();
                // amlint: allow(lock_blocking, reason = "bounded queue")
                g.send(req);
            }
        "#;
        assert!(locks(annotated, &["tx"]).is_empty());
    }

    #[test]
    fn drop_releases_guard() {
        let src = r#"
            fn f(&self) {
                let g = self.tx.lock();
                drop(g);
                out.send(req);
            }
        "#;
        assert!(locks(src, &["tx"]).is_empty());
    }

    #[test]
    fn undeclared_mutex_flagged() {
        let found = locks("fn f() { let g = other.lock(); }", &["tx"]);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, "lock_registry");
    }

    #[test]
    fn lock_unpoisoned_form_recognized() {
        let src = r#"
            fn f(&self) {
                let g = lock_unpoisoned(&self.tx);
                g.send(req);
            }
        "#;
        let found = locks(src, &["tx"]);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, "lock_blocking");
    }

    fn simd(src: &str, in_kernels: bool) -> Vec<Finding> {
        let mut out = Vec::new();
        rule_simd("f.rs", &lex(src), in_kernels, &mut out);
        out
    }

    #[test]
    fn intrinsics_flagged_outside_kernels_only() {
        let src = r#"
            use std::arch::x86_64::*;
            fn f(a: __m128) -> __m128 { _mm_add_ps(a, a) }
        "#;
        let found = simd(src, false);
        assert_eq!(found.len(), 4, "{found:?}"); // arch + 2x __m128 + _mm_add_ps
        assert!(found.iter().all(|f| f.rule == "simd"));
        assert!(simd(src, true).is_empty());
    }

    #[test]
    fn arch_in_comments_and_unrelated_idents_pass() {
        let src = r#"
            // std::arch and _mm_add_ps in a comment are fine
            fn f(arch: &str, mmap: usize) -> usize { mmap }
        "#;
        assert!(simd(src, false).is_empty());
    }

    #[test]
    fn simd_allow_annotation_respected() {
        let src = r#"
            // amlint: allow(simd, reason = "feature probe, not a kernel")
            let ok = std::arch::is_x86_feature_detected!("avx2");
        "#;
        assert!(simd(src, false).is_empty());
    }

    #[test]
    fn target_feature_contract_enforced_even_in_kernels() {
        let good = r#"
            // SAFETY: dispatch probes `is_x86_feature_detected!("avx2")`
            // once before constructing this backend.
            #[inline]
            #[target_feature(enable = "avx2")]
            pub(crate) unsafe fn f(a: &[f32]) -> f32 { a[0] }
        "#;
        assert!(simd(good, true).is_empty(), "{:?}", simd(good, true));

        let not_unsafe = good.replace("unsafe fn", "fn");
        let found = simd(&not_unsafe, true);
        assert_eq!(found.len(), 1, "{found:?}");
        assert!(found[0].message.contains("must be declared `unsafe`"));

        let wrong_feature = good.replace("avx2", "sse4.1");
        // comment now names sse4.1 consistently, so it passes; but a
        // comment naming a different feature than the attribute fails
        assert!(simd(&wrong_feature, true).is_empty());
        let mismatched = good.replace("`is_x86_feature_detected!(\"avx2\")`", "nothing");
        let found = simd(&mismatched, true);
        assert_eq!(found.len(), 1, "{found:?}");
        assert!(found[0].message.contains("SAFETY"));
    }

    #[test]
    fn target_feature_without_any_comment_flagged() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}";
        let found = simd(src, true);
        assert_eq!(found.len(), 1);
        assert!(found[0].message.contains("SAFETY"));
    }

    #[test]
    fn safety_comment_covers_unsafe_fn_through_attribute_stack() {
        let src = r#"
            // SAFETY: callers probe avx2 first.
            #[target_feature(enable = "avx2")]
            unsafe fn f() {}
        "#;
        let mut out = Vec::new();
        rule_safety("f.rs", &lex(src), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    fn store_io(src: &str, in_store: bool) -> Vec<Finding> {
        let mut out = Vec::new();
        rule_store_io("f.rs", &lex(src), in_store, &mut out);
        out
    }

    #[test]
    fn io_result_discard_flagged_and_allowable() {
        let src = r#"
            fn f(mut s: TcpStream) {
                let _ = s.write_all(&bytes);
            }
        "#;
        let found = store_io(src, false);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "store_io");
        assert_eq!(found[0].line, 3);
        assert!(found[0].message.contains("write_all"));
        let annotated = r#"
            fn f(mut s: TcpStream) {
                // amlint: allow(store_io, reason = "best-effort error reply")
                let _ = s.write_all(&bytes);
            }
        "#;
        assert!(store_io(annotated, false).is_empty());
    }

    #[test]
    fn bound_and_propagated_io_pass() {
        let src = r#"
            fn f(file: &File, buf: &mut [u8]) -> io::Result<usize> {
                file.read_exact_at(buf, 0)?;
                let n = file.read(buf)?;
                let _ = handle.join();
                Ok(n)
            }
        "#;
        assert!(store_io(src, false).is_empty());
        assert!(store_io(src, true).is_empty());
    }

    #[test]
    fn io_discard_in_test_code_passes() {
        let src = r#"
            #[cfg(test)]
            mod tests {
                fn g(mut s: TcpStream) { let _ = s.flush(); }
            }
        "#;
        assert!(store_io(src, false).is_empty());
    }

    #[test]
    fn mmap_idents_flagged_in_and_out_of_store() {
        let src = "fn f() { let m = MmapOptions::new(); }";
        for in_store in [false, true] {
            let found = store_io(src, in_store);
            assert_eq!(found.len(), 1, "{found:?}");
            assert!(found[0].message.contains("memory-mapped"));
        }
        // `mmap` in a comment or string literal is fine
        let ok = "// mmap would SIGBUS here\nfn f(s: &str) { g(\"mmap\"); }";
        assert!(store_io(ok, true).is_empty());
    }

    #[test]
    fn unsafe_forbidden_inside_store_only() {
        let src = "// SAFETY: aligned\nfn f(p: *mut f32) { unsafe { *p = 1.0; } }";
        assert!(store_io(src, false).is_empty());
        let found = store_io(src, true);
        assert_eq!(found.len(), 1, "{found:?}");
        assert!(found[0].message.contains("`unsafe` inside `store/`"));
    }

    #[test]
    fn if_let_temporary_lives_for_the_block() {
        // the `if let` scrutinee temporary lives to the end of the block
        let src = r#"
            fn f(&self) {
                if let Some(x) = self.tx.lock().as_ref() {
                    out.send(x);
                }
            }
        "#;
        let found = locks(src, &["tx"]);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "lock_blocking");
    }
}
