//! benchcmp: compare a fresh bench JSON against a committed baseline.
//!
//! Zero-dependency (the workspace is fully offline), so it carries its
//! own minimal JSON reader.  Two input shapes are accepted, matching
//! the bench harnesses in `rust/benches/`:
//!
//! * a flat array of measurements:
//!   `[{"name": ..., "mean_ns": ...}, ...]`
//! * the kernels shape with provenance:
//!   `{"meta": {...}, "measurements": [{"name": ..., "ns_per_distance":
//!   ..., "gbps": ...}, ...]}`
//!
//! Cells are joined by exact `name`.  The compared metric is
//! `ns_per_distance` when both sides carry it, else `mean_ns` (lower is
//! better for both).  A cell regresses when
//! `fresh > baseline * (1 + threshold)`.
//!
//! Exit policy: without `--enforce` this is informational (always exit
//! 0).  With `--enforce` it exits 1 on regression — **unless** the two
//! files disagree on provenance (`meta.harness` / `meta.cpu`), in which
//! case the failure is downgraded to a warning: numbers measured on one
//! machine or harness must never hard-gate another.  A missing baseline
//! file warns and exits 0, so the gate is soft until a baseline is
//! committed.
//!
//! Usage: `benchcmp <baseline.json> <fresh.json> [--threshold 0.15]
//! [--enforce]`
//!
//! Single-file pair mode compares two cells of the *same* run instead
//! of two runs — the shape the observability overhead gate needs
//! (`obs/untraced` vs `obs/traced` are measured seconds apart on the
//! same machine, so provenance can never disagree):
//!
//! `benchcmp --pair <base_cell> <test_cell> <run.json>
//! [--threshold 0.02] [--enforce]`
//!
//! A missing cell warns and exits 0 (soft until the bench emits both).

use std::collections::BTreeMap;
use std::process::ExitCode;

/// Minimal JSON value (objects keep key order irrelevant: BTreeMap).
#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Recursive-descent JSON reader over the byte buffer; returns the
/// value and the index just past it.
fn parse_value(s: &[u8], mut i: usize) -> Result<(Json, usize), String> {
    i = skip_ws(s, i);
    match s.get(i) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => {
            let mut o = BTreeMap::new();
            i += 1;
            i = skip_ws(s, i);
            if s.get(i) == Some(&b'}') {
                return Ok((Json::Obj(o), i + 1));
            }
            loop {
                i = skip_ws(s, i);
                let (key, ni) = parse_string(s, i)?;
                i = skip_ws(s, ni);
                if s.get(i) != Some(&b':') {
                    return Err(format!("expected ':' at byte {i}"));
                }
                let (val, ni) = parse_value(s, i + 1)?;
                o.insert(key, val);
                i = skip_ws(s, ni);
                match s.get(i) {
                    Some(b',') => i += 1,
                    Some(b'}') => return Ok((Json::Obj(o), i + 1)),
                    _ => return Err(format!("expected ',' or '}}' at byte {i}")),
                }
            }
        }
        Some(b'[') => {
            let mut a = Vec::new();
            i += 1;
            i = skip_ws(s, i);
            if s.get(i) == Some(&b']') {
                return Ok((Json::Arr(a), i + 1));
            }
            loop {
                let (val, ni) = parse_value(s, i)?;
                a.push(val);
                i = skip_ws(s, ni);
                match s.get(i) {
                    Some(b',') => i += 1,
                    Some(b']') => return Ok((Json::Arr(a), i + 1)),
                    _ => return Err(format!("expected ',' or ']' at byte {i}")),
                }
            }
        }
        Some(b'"') => {
            let (v, ni) = parse_string(s, i)?;
            Ok((Json::Str(v), ni))
        }
        Some(b't') if s[i..].starts_with(b"true") => Ok((Json::Bool(true), i + 4)),
        Some(b'f') if s[i..].starts_with(b"false") => {
            Ok((Json::Bool(false), i + 5))
        }
        Some(b'n') if s[i..].starts_with(b"null") => Ok((Json::Null, i + 4)),
        Some(_) => {
            let start = i;
            while i < s.len()
                && matches!(s[i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                i += 1;
            }
            let text = std::str::from_utf8(&s[start..i])
                .map_err(|e| e.to_string())?;
            let n: f64 = text
                .parse()
                .map_err(|_| format!("bad number {text:?} at byte {start}"))?;
            Ok((Json::Num(n), i))
        }
    }
}

fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && s[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Parse a string literal starting at `i` (which must be `"`); handles
/// the escapes the bench writers emit (\" \\ \/ \n \t \r \u).
fn parse_string(s: &[u8], i: usize) -> Result<(String, usize), String> {
    if s.get(i) != Some(&b'"') {
        return Err(format!("expected string at byte {i}"));
    }
    let mut out = String::new();
    let mut j = i + 1;
    while j < s.len() {
        match s[j] {
            b'"' => return Ok((out, j + 1)),
            b'\\' => {
                let esc = s.get(j + 1).ok_or("truncated escape")?;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        let hex = s
                            .get(j + 2..j + 6)
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                            16,
                        )
                        .map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        j += 4;
                    }
                    other => {
                        return Err(format!("unknown escape \\{}", *other as char))
                    }
                }
                j += 2;
            }
            byte => {
                // multi-byte UTF-8 passes through unchanged
                let len = utf8_len(byte);
                let chunk = s.get(j..j + len).ok_or("truncated utf8")?;
                out.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                j += len;
            }
        }
    }
    Err("unterminated string".to_string())
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xF0..=0xF7 => 4,
        0xE0..=0xEF => 3,
        0xC0..=0xDF => 2,
        _ => 1,
    }
}

/// One comparable cell: the preferred metric and which field it came
/// from.
#[derive(Debug, Clone, PartialEq)]
struct CellMetric {
    value: f64,
    field: &'static str,
}

/// Provenance fields that must agree for `--enforce` to hard-fail.
#[derive(Debug, Clone, PartialEq, Default)]
struct Provenance {
    harness: Option<String>,
    cpu: Option<String>,
}

/// A parsed bench file: provenance + name → measurement object.
struct BenchFile {
    provenance: Provenance,
    cells: BTreeMap<String, Json>,
}

fn load_bench(doc: &Json) -> Result<BenchFile, String> {
    let (meta, list) = match doc {
        Json::Arr(a) => (None, a),
        Json::Obj(_) => {
            let list = match doc.get("measurements") {
                Some(Json::Arr(a)) => a,
                _ => return Err("object form needs a \"measurements\" array".into()),
            };
            (doc.get("meta"), list)
        }
        _ => return Err("top level must be an array or an object".into()),
    };
    let provenance = Provenance {
        harness: meta
            .and_then(|m| m.get("harness"))
            .and_then(|v| v.as_str().map(str::to_string)),
        cpu: meta
            .and_then(|m| m.get("cpu"))
            .and_then(|v| v.as_str().map(str::to_string)),
    };
    let mut cells = BTreeMap::new();
    for m in list {
        let Some(name) = m.get("name").and_then(Json::as_str) else {
            return Err("measurement without a \"name\"".into());
        };
        cells.insert(name.to_string(), m.clone());
    }
    Ok(BenchFile { provenance, cells })
}

/// The compared metric for a (baseline, fresh) cell pair:
/// ns_per_distance when both sides have it, else mean_ns.
fn joint_metric(base: &Json, fresh: &Json) -> Option<(CellMetric, CellMetric)> {
    for field in ["ns_per_distance", "mean_ns"] {
        if let (Some(b), Some(f)) = (
            base.get(field).and_then(Json::as_f64),
            fresh.get(field).and_then(Json::as_f64),
        ) {
            return Some((
                CellMetric { value: b, field },
                CellMetric { value: f, field },
            ));
        }
    }
    None
}

struct Comparison {
    regressions: Vec<String>,
    improvements: usize,
    compared: usize,
    missing_in_fresh: usize,
    new_in_fresh: usize,
}

fn compare(base: &BenchFile, fresh: &BenchFile, threshold: f64) -> Comparison {
    let mut c = Comparison {
        regressions: Vec::new(),
        improvements: 0,
        compared: 0,
        missing_in_fresh: 0,
        new_in_fresh: 0,
    };
    for (name, b) in &base.cells {
        let Some(f) = fresh.cells.get(name) else {
            c.missing_in_fresh += 1;
            continue;
        };
        let Some((bm, fm)) = joint_metric(b, f) else {
            continue;
        };
        c.compared += 1;
        let ratio = if bm.value > 0.0 { fm.value / bm.value } else { 1.0 };
        if ratio > 1.0 + threshold {
            c.regressions.push(format!(
                "{name}: {field} {base:.2} -> {fresh:.2} ({pct:+.1}%)",
                field = bm.field,
                base = bm.value,
                fresh = fm.value,
                pct = (ratio - 1.0) * 100.0
            ));
        } else if ratio < 1.0 - threshold {
            c.improvements += 1;
        }
    }
    c.new_in_fresh =
        fresh.cells.keys().filter(|k| !base.cells.contains_key(*k)).count();
    c
}

/// Pair-mode verdict within one run: `Ok(Some(msg))` when `test_cell`
/// exceeds `base_cell` by more than `threshold`, `Ok(None)` when it is
/// within budget, `Err` when either cell (or a shared metric) is absent.
fn pair_verdict(
    file: &BenchFile,
    base_cell: &str,
    test_cell: &str,
    threshold: f64,
) -> Result<Option<String>, String> {
    let base = file
        .cells
        .get(base_cell)
        .ok_or_else(|| format!("cell {base_cell:?} not in the run"))?;
    let test = file
        .cells
        .get(test_cell)
        .ok_or_else(|| format!("cell {test_cell:?} not in the run"))?;
    let (bm, tm) = joint_metric(base, test)
        .ok_or_else(|| "cells share no comparable metric".to_string())?;
    let ratio = if bm.value > 0.0 { tm.value / bm.value } else { 1.0 };
    println!(
        "benchcmp: {test_cell} vs {base_cell}: {field} {base:.2} -> \
         {test:.2} ({pct:+.2}%, budget {budget:.0}%)",
        field = bm.field,
        base = bm.value,
        test = tm.value,
        pct = (ratio - 1.0) * 100.0,
        budget = threshold * 100.0
    );
    if ratio > 1.0 + threshold {
        Ok(Some(format!(
            "{test_cell}: {field} {base:.2} -> {test:.2} ({pct:+.1}% over \
             {base_cell})",
            field = bm.field,
            base = bm.value,
            test = tm.value,
            pct = (ratio - 1.0) * 100.0
        )))
    } else {
        Ok(None)
    }
}

fn read_json_file(path: &str) -> Result<Json, String> {
    let text = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
    let (doc, end) = parse_value(&text, 0)?;
    if skip_ws(&text, end) != text.len() {
        return Err(format!("{path}: trailing garbage after JSON"));
    }
    Ok(doc)
}

fn run(args: &[String]) -> ExitCode {
    let mut paths: Vec<&String> = Vec::new();
    let mut threshold = 0.15f64;
    let mut enforce = false;
    let mut pair: Option<(String, String)> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--threshold" => {
                let Some(v) = it.next().and_then(|v| v.parse::<f64>().ok()) else {
                    eprintln!("benchcmp: --threshold needs a number");
                    return ExitCode::from(2);
                };
                threshold = v;
            }
            "--enforce" => enforce = true,
            "--pair" => {
                let (Some(b), Some(t)) = (it.next(), it.next()) else {
                    eprintln!("benchcmp: --pair needs <base_cell> <test_cell>");
                    return ExitCode::from(2);
                };
                pair = Some((b.clone(), t.clone()));
            }
            "--help" | "-h" => {
                println!(
                    "usage: benchcmp <baseline.json> <fresh.json> \
                     [--threshold 0.15] [--enforce]\n\
                     \x20      benchcmp --pair <base_cell> <test_cell> \
                     <run.json> [--threshold 0.02] [--enforce]"
                );
                return ExitCode::SUCCESS;
            }
            _ => paths.push(a),
        }
    }
    if let Some((base_cell, test_cell)) = pair {
        let [run_path] = paths.as_slice() else {
            eprintln!("benchcmp: --pair mode takes exactly one run file");
            return ExitCode::from(2);
        };
        if !std::path::Path::new(run_path.as_str()).exists() {
            println!(
                "benchcmp: no run file at {run_path} — nothing to compare"
            );
            return ExitCode::SUCCESS;
        }
        let file = match read_json_file(run_path).and_then(|d| load_bench(&d)) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("benchcmp: {e}");
                return ExitCode::from(2);
            }
        };
        return match pair_verdict(&file, &base_cell, &test_cell, threshold) {
            Err(e) => {
                // missing cells keep the gate soft, like a missing baseline
                println!("benchcmp: {e} — nothing to compare");
                ExitCode::SUCCESS
            }
            Ok(None) => ExitCode::SUCCESS,
            Ok(Some(r)) => {
                println!("  REGRESSION {r}");
                if enforce {
                    ExitCode::FAILURE
                } else {
                    println!(
                        "benchcmp: informational run (no --enforce); not failing"
                    );
                    ExitCode::SUCCESS
                }
            }
        };
    }
    let [base_path, fresh_path] = paths.as_slice() else {
        eprintln!(
            "usage: benchcmp <baseline.json> <fresh.json> \
             [--threshold 0.15] [--enforce]"
        );
        return ExitCode::from(2);
    };
    if !std::path::Path::new(base_path.as_str()).exists() {
        println!(
            "benchcmp: no baseline at {base_path} — nothing to compare \
             (commit one to arm the gate)"
        );
        return ExitCode::SUCCESS;
    }
    let (base, fresh) = match (read_json_file(base_path), read_json_file(fresh_path))
    {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("benchcmp: {e}");
            return ExitCode::from(2);
        }
    };
    let (base, fresh) = match (load_bench(&base), load_bench(&fresh)) {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("benchcmp: {e}");
            return ExitCode::from(2);
        }
    };
    let same_provenance = base.provenance == fresh.provenance;
    let c = compare(&base, &fresh, threshold);
    println!(
        "benchcmp: {} compared, {} improved, {} regressed \
         (threshold {:.0}%, {} baseline-only, {} fresh-only)",
        c.compared,
        c.improvements,
        c.regressions.len(),
        threshold * 100.0,
        c.missing_in_fresh,
        c.new_in_fresh
    );
    for r in &c.regressions {
        println!("  REGRESSION {r}");
    }
    if c.regressions.is_empty() {
        return ExitCode::SUCCESS;
    }
    if !enforce {
        println!("benchcmp: informational run (no --enforce); not failing");
        return ExitCode::SUCCESS;
    }
    if !same_provenance {
        println!(
            "benchcmp: provenance differs (harness/cpu: {:?} vs {:?}); \
             downgrading failure to a warning — cross-machine numbers \
             never hard-gate",
            base.provenance, fresh.provenance
        );
        return ExitCode::SUCCESS;
    }
    ExitCode::FAILURE
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    run(&args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Json {
        let (v, end) = parse_value(text.as_bytes(), 0).unwrap();
        assert_eq!(skip_ws(text.as_bytes(), end), text.len());
        v
    }

    #[test]
    fn parses_flat_array_shape() {
        let doc = parse(
            r#"[
              {"name": "a", "iters": 3, "mean_ns": 12.5},
              {"name": "b", "iters": 4, "mean_ns": 100.0}
            ]"#,
        );
        let f = load_bench(&doc).unwrap();
        assert_eq!(f.cells.len(), 2);
        assert_eq!(f.provenance, Provenance::default());
        assert_eq!(
            f.cells["a"].get("mean_ns").and_then(Json::as_f64),
            Some(12.5)
        );
    }

    #[test]
    fn parses_meta_measurements_shape() {
        let doc = parse(
            r#"{"meta": {"harness": "c-mirror-gcc", "cpu": "Xeon"},
                "measurements": [
                  {"name": "kern f32 d=64 sse2", "ns_per_distance": 9.79,
                   "gbps": 26.14}
                ]}"#,
        );
        let f = load_bench(&doc).unwrap();
        assert_eq!(f.provenance.harness.as_deref(), Some("c-mirror-gcc"));
        assert_eq!(f.provenance.cpu.as_deref(), Some("Xeon"));
        assert_eq!(f.cells.len(), 1);
    }

    #[test]
    fn string_escapes_and_nesting() {
        let doc = parse(r#"{"a": "q\"uo\\te\nx", "b": [1, -2.5e1, true, null]}"#);
        assert_eq!(doc.get("a").and_then(Json::as_str), Some("q\"uo\\te\nx"));
        assert_eq!(
            doc.get("b"),
            Some(&Json::Arr(vec![
                Json::Num(1.0),
                Json::Num(-25.0),
                Json::Bool(true),
                Json::Null
            ]))
        );
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse_value(b"{", 0).is_err());
        assert!(parse_value(b"[1,", 0).is_err());
        assert!(parse_value(b"\"open", 0).is_err());
        assert!(parse_value(b"nope", 0).is_err());
        let (_, end) = parse_value(b"[] []", 0).unwrap();
        assert_ne!(skip_ws(b"[] []", end), 5); // trailing garbage detected
    }

    fn bench_of(pairs: &[(&str, f64)], field: &str) -> BenchFile {
        let cells = pairs
            .iter()
            .map(|(n, v)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str((*n).to_string()));
                o.insert(field.to_string(), Json::Num(*v));
                ((*n).to_string(), Json::Obj(o))
            })
            .collect();
        BenchFile { provenance: Provenance::default(), cells }
    }

    #[test]
    fn flags_regressions_beyond_threshold_only() {
        let base = bench_of(
            &[("a", 100.0), ("b", 100.0), ("c", 100.0), ("gone", 1.0)],
            "mean_ns",
        );
        let fresh = bench_of(
            &[("a", 114.9), ("b", 116.0), ("c", 50.0), ("new", 1.0)],
            "mean_ns",
        );
        let c = compare(&base, &fresh, 0.15);
        assert_eq!(c.compared, 3);
        assert_eq!(c.regressions.len(), 1, "{:?}", c.regressions);
        assert!(c.regressions[0].starts_with("b:"), "{:?}", c.regressions);
        assert_eq!(c.improvements, 1);
        assert_eq!(c.missing_in_fresh, 1);
        assert_eq!(c.new_in_fresh, 1);
    }

    #[test]
    fn prefers_ns_per_distance_over_mean_ns() {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str("a".to_string()));
        o.insert("mean_ns".to_string(), Json::Num(1.0));
        o.insert("ns_per_distance".to_string(), Json::Num(10.0));
        let b = Json::Obj(o.clone());
        let (bm, fm) = joint_metric(&b, &Json::Obj(o)).unwrap();
        assert_eq!(bm.field, "ns_per_distance");
        assert_eq!(bm.value, 10.0);
        assert_eq!(fm.value, 10.0);
    }

    #[test]
    fn missing_metric_cells_are_skipped() {
        let base = bench_of(&[("a", 100.0)], "mean_ns");
        let fresh = bench_of(&[("a", 200.0)], "gbps"); // no shared metric
        let c = compare(&base, &fresh, 0.15);
        assert_eq!(c.compared, 0);
        assert!(c.regressions.is_empty());
    }

    #[test]
    fn pair_mode_flags_over_budget_cells_only() {
        let run = bench_of(
            &[("obs/untraced", 100.0), ("obs/traced", 101.5), ("obs/slow", 110.0)],
            "mean_ns",
        );
        // within the 2% budget
        assert_eq!(
            pair_verdict(&run, "obs/untraced", "obs/traced", 0.02).unwrap(),
            None
        );
        // over budget: named in the regression message
        let r = pair_verdict(&run, "obs/untraced", "obs/slow", 0.02)
            .unwrap()
            .expect("10% over a 2% budget must flag");
        assert!(r.starts_with("obs/slow:"), "{r}");
        // faster than baseline is never a regression
        assert_eq!(
            pair_verdict(&run, "obs/slow", "obs/untraced", 0.02).unwrap(),
            None
        );
    }

    #[test]
    fn pair_mode_missing_cells_are_soft() {
        let run = bench_of(&[("obs/untraced", 100.0)], "mean_ns");
        assert!(pair_verdict(&run, "obs/untraced", "obs/traced", 0.02).is_err());
        assert!(pair_verdict(&run, "absent", "obs/untraced", 0.02).is_err());
    }
}
