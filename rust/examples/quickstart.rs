//! Quickstart: the paper's core scenario end to end.
//!
//! Store 16k dense ±1 patterns in q=16 associative memories, probe with
//! *corrupted* versions of stored patterns (90% overlap), and retrieve
//! the original at a fraction of exhaustive-search cost.
//!
//! Run: `cargo run --release --example quickstart`

use amsearch::baseline::Exhaustive;
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::{OpsCounter, Recall, RecallAtK};
use amsearch::search::Metric;

fn main() -> amsearch::Result<()> {
    // 1. workload: 16384 random ±1 patterns; queries are stored patterns
    //    with 5% of coordinates flipped (overlap alpha = 0.9)
    let mut rng = Rng::new(42);
    let (d, n) = (128usize, 16_384usize);
    let wl = synthetic::dense_workload(
        d,
        n,
        300,
        QueryModel::Corrupted { alpha: 0.9 },
        &mut rng,
    );
    println!("workload: n={n} d={d}, corrupted probes (alpha=0.9)");

    // 2. build the index: q=16 classes of k=1024, one sum-rule memory each
    let params = IndexParams { n_classes: 16, top_p: 1, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    println!(
        "index: q={} k={} bank={} MB  (k in (d, d²) — the theorem's regime)",
        16,
        n / 16,
        index.bank().stacked().len() * 4 / 1_000_000
    );

    // 3. query: poll all memories with x^T W_i x, scan top-p classes only
    let exhaustive = Exhaustive::new(wl.base.clone(), Metric::SqL2);
    println!();
    for p in [1usize, 2, 4] {
        let mut ops = OpsCounter::new();
        let mut recall = Recall::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = index.query(wl.queries.get(qi), p, &mut ops);
            recall.record(r.id() == gt);
        }
        let reference = exhaustive.reference_ops(wl.queries.get(0));
        println!(
            "p={p}  recall@1={:.3}  cost={:.3} of exhaustive search",
            recall.value(),
            ops.relative_to(reference)
        );
    }

    // 4. k-NN retrieval: the same scan returns the k nearest, ranked —
    //    the paper's "classification and object retrieval" consumers.
    //    Measured as recall@k against the exhaustive top-k.
    let k = 5usize;
    let mut ops = OpsCounter::new();
    let mut recall_k = RecallAtK::new(k);
    for qi in 0..wl.queries.len() {
        let x = wl.queries.get(qi);
        let r = index.query_k(x, 2, k, &mut ops);
        let got: Vec<u32> = r.neighbors.iter().map(|n| n.id).collect();
        let truth: Vec<u32> = exhaustive
            .query_k(x, k, &mut OpsCounter::new())
            .into_iter()
            .map(|n| n.id)
            .collect();
        recall_k.record(&got, &truth);
    }
    println!("\nk-NN mode: p=2 k={k}  recall@{k}={:.3}", recall_k.value());

    println!("\nScanning 1-4 of 16 classes recovers the stored pattern from a");
    println!("corrupted probe at a fraction of the cost of comparing against");
    println!("all 16384 vectors (cost model: (d^2 q + p k d) / (n d)).");
    Ok(())
}
