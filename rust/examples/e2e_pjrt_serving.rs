//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload:
//!
//!   Layer 1  Pallas class-score kernel (AOT, interpret mode)
//!   Layer 2  JAX graph lowered to HLO text by `make artifacts`
//!   Layer 3  this rust coordinator: dynamic batcher + PJRT workers
//!
//! Builds a 16k-vector SIFT-like index at the AOT shape (d=128, q=64),
//! loads the `class_scores` artifact through PJRT, serves batched
//! concurrent requests through the coordinator, and reports
//! latency/throughput/recall — then repeats with the native backend and
//! cross-checks that both backends return identical neighbors.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pjrt_serving`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::Recall;
use amsearch::runtime::Backend;
use amsearch::util::concurrent_map;

struct RunReport {
    backend: &'static str,
    qps: f64,
    recall: f64,
    p50_us: f64,
    p95_us: f64,
    mean_batch: f64,
    neighbors: Vec<u32>,
}

fn run_backend(
    backend: Backend,
    artifacts_dir: Option<PathBuf>,
    index: Arc<AmIndex>,
    wl: &amsearch::data::Workload,
    passes: usize,
) -> amsearch::Result<RunReport> {
    let factory = EngineFactory { index, backend, artifacts_dir };
    let config = CoordinatorConfig {
        max_batch: 8, // matches the AOT batch size
        max_wait_us: 300,
        workers: 2,
        queue_depth: 512,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(factory, config)?);
    let total = wl.queries.len() * passes;
    let started = Instant::now();
    let results = concurrent_map(total, 16, |i| {
        let qi = i % wl.queries.len();
        let resp = server.search(wl.queries.get(qi).to_vec(), 0, 0).expect("search");
        (qi, resp.neighbor())
    });
    let elapsed = started.elapsed();
    let mut recall = Recall::new();
    let mut neighbors = vec![u32::MAX; wl.queries.len()];
    for (qi, nb) in results {
        recall.record(nb == Some(wl.ground_truth[qi]));
        neighbors[qi] = nb.unwrap_or(u32::MAX);
    }
    let m = server.metrics();
    let report = RunReport {
        backend: if backend == Backend::Pjrt { "pjrt" } else { "native" },
        qps: total as f64 / elapsed.as_secs_f64(),
        recall: recall.value(),
        p50_us: m.latency.quantile_ns(0.5) as f64 / 1e3,
        p95_us: m.latency.quantile_ns(0.95) as f64 / 1e3,
        mean_batch: m.mean_batch_size(),
        neighbors,
    };
    server.shutdown();
    Ok(report)
}

fn main() -> amsearch::Result<()> {
    println!("=== E2E: 3-layer stack on a SIFT-like serving workload ===\n");

    // workload + index at the AOT artifact shape (d=128, q=64)
    let mut rng = Rng::new(42);
    let wl = clustered_workload(ClusteredSpec::sift_like(), 16_384, 128, &mut rng);
    let params = IndexParams { n_classes: 64, top_p: 4, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng)?);
    println!(
        "index: n={} d={} q={} k={}  bank={}MB  (top_p=4 default)",
        index.len(),
        index.dim(),
        64,
        index.len() / 64,
        index.bank().stacked().len() * 4 / 1_000_000
    );

    let artifacts = PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    if !have_artifacts {
        println!("\nWARNING: artifacts/manifest.json missing — run `make artifacts`.");
        println!("Running native backend only.\n");
    }

    let native = run_backend(Backend::Native, None, index.clone(), &wl, 4)?;
    let mut reports = vec![&native];

    let pjrt = if have_artifacts {
        Some(run_backend(
            Backend::Pjrt,
            Some(artifacts),
            index.clone(),
            &wl,
            4,
        )?)
    } else {
        None
    };
    if let Some(p) = &pjrt {
        reports.push(p);
    }

    println!(
        "\n{:<8} {:>10} {:>10} {:>12} {:>12} {:>11}",
        "backend", "qps", "recall@1", "p50 latency", "p95 latency", "mean batch"
    );
    for r in &reports {
        println!(
            "{:<8} {:>10.0} {:>10.4} {:>10.1}us {:>10.1}us {:>11.2}",
            r.backend, r.qps, r.recall, r.p50_us, r.p95_us, r.mean_batch
        );
    }

    if let Some(p) = &pjrt {
        let agree = native
            .neighbors
            .iter()
            .zip(&p.neighbors)
            .filter(|(a, b)| a == b)
            .count();
        println!(
            "\nbackend agreement: {agree}/{} neighbors identical",
            native.neighbors.len()
        );
        assert_eq!(
            agree,
            native.neighbors.len(),
            "PJRT and native backends must return identical results"
        );
        println!("E2E OK: Pallas->JAX->HLO->PJRT and native paths agree exactly.");
    }
    Ok(())
}
