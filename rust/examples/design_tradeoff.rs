//! The designer's view (paper §5.1, Figure 3): given a fixed collection
//! of n vectors, how should it be split into q classes of k vectors?
//! Sweeps k at constant n = k·q and prints error rate, relative
//! complexity, and memory use side by side — reproducing the paper's
//! observation that the trade-off is "more about complexity vs.
//! precision of the answer than about error rate".
//!
//! Run: `cargo run --release --example design_tradeoff`

use amsearch::eval::{class_selection_trials, PatternModel, TrialConfig};
use amsearch::memory::StorageRule;
use amsearch::metrics::CostModel;

fn main() {
    let d = 128usize;
    let c = 8.0f64;
    let n = 16_384usize;
    let trials = 4_000;

    println!("fixed n = {n}, d = {d}, c = {c}  (paper Figure 3 setup)\n");
    println!(
        "{:>6} {:>6} {:>12} {:>12} {:>14} {:>12}",
        "k", "q", "error_rate", "rel_cost", "candidates", "memory_MB"
    );
    for k in [64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
        let q = n / k;
        let cfg = TrialConfig {
            d,
            k,
            q,
            model: PatternModel::Sparse { ones: c },
            alpha: None,
            rule: StorageRule::Sum,
        };
        let r = class_selection_trials(cfg, trials, 4, 42);
        let model =
            CostModel { effective_dim: c as u64, q: q as u64, k: k as u64, n: n as u64 };
        println!(
            "{:>6} {:>6} {:>12.4} {:>12.4} {:>14} {:>12.1}",
            k,
            q,
            r.error_rate(),
            model.relative(1),
            k, // candidates returned to the final scan at p=1
            (q * d * d * 4) as f64 / 1e6,
        );
    }
    println!(
        "\nreading the table: small k -> more classes (higher scoring cost,\n\
         more memory) but a smaller candidate set; large k -> cheap scoring\n\
         but the 'answer' is a whole class of {} candidates. Error rate stays\n\
         the same order across the sweep — exactly the paper's point.",
        8192
    );
}
