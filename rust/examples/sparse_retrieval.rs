//! Sparse binary retrieval — the paper's §3 setting end to end: sparse
//! 0/1 patterns, c²·q support scoring, exact and corrupted queries
//! (Theorem 3.1 and Corollary 3.2 regimes), with the cost model printed
//! against measured operations.
//!
//! Run: `cargo run --release --example sparse_retrieval`

use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel, SparseSpec};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::{CostModel, OpsCounter, Recall};

fn main() -> amsearch::Result<()> {
    let mut rng = Rng::new(7);
    let (d, c) = (128usize, 8.0f64);
    let (k, q) = (1024usize, 16usize);
    let n = k * q; // 16384 patterns, the paper's fig-3 size

    println!("sparse model: d={d} c={c} k={k} q={q} n={n}  (d << k << d² ✓)");

    // Theorem 3.1 regime: the query IS a stored pattern
    let wl = synthetic::sparse_workload(
        SparseSpec { dim: d, ones: c },
        n,
        500,
        QueryModel::Exact,
        &mut rng,
    );
    let params = IndexParams { n_classes: q, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    assert!(index.uses_sparse_scoring(), "binary data -> c² scoring path");

    let mut ops = OpsCounter::new();
    let mut recall = Recall::new();
    for (qi, &gt) in wl.ground_truth.iter().enumerate() {
        let r = index.query(wl.queries.get(qi), 1, &mut ops);
        recall.record(r.id() == gt);
    }
    let model = CostModel { effective_dim: c as u64, q: q as u64, k: k as u64, n: n as u64 };
    println!("\nexact queries (Thm 3.1):");
    println!("  recall@1 (p=1)      = {:.4}", recall.value());
    println!("  measured ops/search = {:.0}", ops.per_search());
    println!(
        "  cost model          = c²q + kc = {} (relative {:.4})",
        model.score_cost() + model.scan_cost(1),
        model.relative(1)
    );

    // Corollary 3.2 regime: corrupted queries with overlap alpha
    println!("\ncorrupted queries (Cor 3.2), error rate vs alpha:");
    for &alpha in &[0.9, 0.7, 0.5, 0.3] {
        let wl = synthetic::sparse_workload(
            SparseSpec { dim: d, ones: c },
            n,
            400,
            QueryModel::Corrupted { alpha },
            &mut rng,
        );
        let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
        let mut ops = OpsCounter::new();
        let mut class_hit = Recall::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let ranked = index.ranked_classes(wl.queries.get(qi), &mut ops);
            class_hit
                .record(ranked[0] == index.partition().class_of(gt as usize));
        }
        println!(
            "  alpha={alpha:.1}: class-selection error = {:.4}  (theory: exponent shrinks by alpha⁴ = {:.3})",
            class_hit.error_rate(),
            alpha.powi(4)
        );
    }
    Ok(())
}
