//! Network serving demo: the full stack — index, coordinator, TCP front
//! door — plus a pipelined client and a closed-loop load-generation
//! burst, all in one process on an ephemeral localhost port.
//!
//! Run: `cargo run --release --example net_serving`

use std::sync::Arc;
use std::time::Duration;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::net::{loadgen, LoadGenConfig, NetClient, NetConfig, NetServer};
use amsearch::runtime::Backend;

fn main() -> amsearch::Result<()> {
    let mut rng = Rng::new(42);
    let wl = clustered_workload(ClusteredSpec::sift_like(), 8_192, 128, &mut rng);
    let params = IndexParams { n_classes: 32, top_p: 4, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng)?);
    let factory = EngineFactory {
        index: index.clone(),
        backend: Backend::Native,
        artifacts_dir: None,
    };
    let server = Arc::new(SearchServer::start(factory, CoordinatorConfig::default())?);
    let net = NetServer::bind(server.clone(), "127.0.0.1:0", NetConfig::default())?;
    let addr = net.local_addr();
    println!("serving n={} d={} on {addr}", index.len(), index.dim());

    // --- one pipelined client connection -----------------------------
    let mut client = NetClient::connect(addr)?;
    client.ping()?;
    let ids: Vec<u64> = (0..8)
        .map(|qi| client.submit(wl.queries.get(qi), 0, 5))
        .collect::<amsearch::Result<_>>()?;
    println!("pipelined {} requests on one connection", ids.len());
    let mut hits = 0;
    for (qi, id) in ids.into_iter().enumerate() {
        let resp = client.wait(id)?;
        assert_eq!(resp.neighbors.len(), 5);
        hits += usize::from(resp.neighbors[0].id == wl.ground_truth[qi]);
    }
    println!("top-1 hits on the pipelined burst: {hits}/8");

    // --- closed-loop load burst --------------------------------------
    let queries: Vec<Vec<f32>> =
        (0..wl.queries.len()).map(|qi| wl.queries.get(qi).to_vec()).collect();
    let cfg = LoadGenConfig {
        connections: 4,
        requests: 2_000,
        depth: 8,
        top_p: 0,
        top_k: 1,
        connect_timeout: Duration::from_secs(5),
    };
    let report = loadgen::run(&addr.to_string(), &queries, &cfg)?;
    report.print();

    // --- server-side view, then graceful shutdown over the wire ------
    let stats = client.stats()?;
    println!("server stats: {}", stats.to_string());
    client.shutdown_server()?;
    net.join();
    server.shutdown();
    println!("drained and stopped");
    Ok(())
}
