//! Serving demo: the full coordinator (dynamic batcher + worker pool)
//! over a SIFT-like collection with the native scorer, under concurrent
//! client load.  Reports throughput, latency percentiles, batching
//! efficiency, recall, and the paper's per-request cost accounting.
//!
//! Run: `cargo run --release --example serve_sift_like`

use std::sync::Arc;
use std::time::Instant;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::Recall;
use amsearch::runtime::Backend;
use amsearch::util::concurrent_map;

fn main() -> amsearch::Result<()> {
    let mut rng = Rng::new(42);
    let wl = clustered_workload(ClusteredSpec::sift_like(), 16_384, 256, &mut rng);
    let params = IndexParams { n_classes: 64, top_p: 4, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng)?);
    println!(
        "index ready: n={} d={} q={}, serving with native scorer",
        index.len(),
        index.dim(),
        64
    );

    let factory = EngineFactory {
        index: index.clone(),
        backend: Backend::Native,
        artifacts_dir: None,
    };
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 200,
        workers: 2,
        queue_depth: 512,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(factory, config)?);

    // 16 concurrent client streams, 4 passes over the query set; every
    // request asks for the 10 nearest neighbors (top_k = 10)
    let streams = 16usize;
    let top_k = 10usize;
    let total = wl.queries.len() * 4;
    let started = Instant::now();
    let hits = concurrent_map(total, streams, |i| {
        let qi = i % wl.queries.len();
        let resp = server
            .search(wl.queries.get(qi).to_vec(), 0, top_k)
            .expect("search");
        assert_eq!(resp.neighbors.len(), top_k, "k neighbors per response");
        let top1 = resp.neighbor() == Some(wl.ground_truth[qi]);
        let in_topk = resp.neighbors.iter().any(|n| n.id == wl.ground_truth[qi]);
        (top1, in_topk)
    });
    let elapsed = started.elapsed();

    let mut recall = Recall::new();
    let mut recall_topk = Recall::new();
    for (top1, in_topk) in hits {
        recall.record(top1);
        recall_topk.record(in_topk);
    }
    let m = server.metrics();
    println!(
        "\nserved {} requests in {:.3}s  ->  {:.0} qps ({} client streams)",
        total,
        elapsed.as_secs_f64(),
        total as f64 / elapsed.as_secs_f64(),
        streams
    );
    println!("recall@1 (p=4)     : {:.4}", recall.value());
    println!("1-NN in top-{top_k}      : {:.4}", recall_topk.value());
    println!("end-to-end latency : {}", m.latency.summary());
    println!("batch service time : {}", m.service.summary());
    println!(
        "batching           : {} batches, mean size {:.2}",
        m.batches,
        m.mean_batch_size()
    );
    println!(
        "paper cost model   : {:.0} ops/search = {:.3} of exhaustive (n*d = {})",
        m.ops.per_search(),
        m.ops.per_search() / (index.len() * index.dim()) as f64,
        index.len() * index.dim()
    );
    server.shutdown();
    Ok(())
}
