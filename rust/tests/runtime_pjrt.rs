//! PJRT runtime integration tests: load the AOT artifacts produced by
//! `make artifacts`, execute them on the CPU PJRT client, and cross-check
//! against the optimized native scorer and the pure-rust reference.
//!
//! These tests are skipped (with a loud message) when `artifacts/` has
//! not been generated yet; `make test` always generates it first.

use std::path::PathBuf;

use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::runtime::{
    cpu_client, ClassScorer, Manifest, NativeScorer, PjrtDistances, PjrtScorer,
};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing; run `make artifacts`");
        None
    }
}

/// Build a d=128, q=64 index matching the default AOT artifact config.
fn default_shape_index(seed: u64) -> (AmIndex, amsearch::data::Workload) {
    let mut rng = Rng::new(seed);
    let wl = synthetic::dense_workload(128, 4096, 32, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 64, ..Default::default() };
    let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    (idx, wl)
}

#[test]
fn pjrt_scorer_matches_native_scorer() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let (idx, wl) = default_shape_index(1);

    let pjrt = PjrtScorer::from_manifest(
        &client,
        &manifest,
        idx.bank().stacked(),
        128,
        64,
    )
    .unwrap();
    assert_eq!(pjrt.backend(), "pjrt");
    assert_eq!(pjrt.batch_size(), 8);
    let native =
        NativeScorer::new(idx.bank().stacked().to_vec(), 128, 64).unwrap();

    // full batch (8), partial batch (3), multi-chunk (19)
    for m in [8usize, 3, 19] {
        let mut queries = Vec::with_capacity(m * 128);
        for qi in 0..m {
            queries.extend_from_slice(wl.queries.get(qi % wl.queries.len()));
        }
        let a = pjrt.score(&queries).unwrap();
        let b = native.score(&queries).unwrap();
        assert_eq!(a.len(), m * 64);
        assert_eq!(b.len(), m * 64);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let rel = (x - y).abs() / y.abs().max(1.0);
            assert!(rel < 1e-3, "m={m} idx={i}: pjrt={x} native={y}");
        }
    }
}

#[test]
fn pjrt_scorer_reusable_across_many_calls() {
    // The bank buffer is uploaded once and reused: 20 consecutive
    // executions must keep producing identical results (guards against
    // accidental buffer donation).
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let (idx, wl) = default_shape_index(2);
    let pjrt =
        PjrtScorer::from_manifest(&client, &manifest, idx.bank().stacked(), 128, 64)
            .unwrap();
    let mut queries = Vec::new();
    for qi in 0..8 {
        queries.extend_from_slice(wl.queries.get(qi));
    }
    let first = pjrt.score(&queries).unwrap();
    for round in 0..20 {
        let again = pjrt.score(&queries).unwrap();
        assert_eq!(first, again, "round {round} diverged");
    }
}

#[test]
fn pjrt_end_to_end_query_equals_native_query() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let (idx, wl) = default_shape_index(3);
    let pjrt =
        PjrtScorer::from_manifest(&client, &manifest, idx.bank().stacked(), 128, 64)
            .unwrap();
    let mut ops = amsearch::metrics::OpsCounter::new();
    for qi in 0..wl.queries.len() {
        let x = wl.queries.get(qi);
        let scores = pjrt.score(x).unwrap();
        let via_pjrt = idx.finish_query(x, &scores, 4, 1, &mut ops);
        let via_native = idx.query(x, 4, &mut ops);
        assert_eq!(via_pjrt.id(), via_native.id(), "query {qi}");
        assert_eq!(via_pjrt.polled, via_native.polled, "query {qi}");
    }
}

#[test]
fn pjrt_distances_match_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let dist = PjrtDistances::from_manifest(&client, &manifest, 128, 256).unwrap();
    assert_eq!(dist.capacity(), 256);

    let mut rng = Rng::new(4);
    let members = synthetic::dense_patterns(128, 200, &mut rng); // < k: padding path
    let queries = synthetic::dense_patterns(128, 5, &mut rng);
    let got = dist
        .distances(members.as_flat(), 200, queries.as_flat())
        .unwrap();
    assert_eq!(got.len(), 5 * 200);
    for (bi, q) in queries.iter().enumerate() {
        for (vi, v) in members.iter().enumerate() {
            let want: f32 = q.iter().zip(v).map(|(a, b)| (a - b) * (a - b)).sum();
            let g = got[bi * 200 + vi];
            assert!(
                (g - want).abs() / want.max(1.0) < 1e-3,
                "b={bi} v={vi}: got={g} want={want}"
            );
        }
    }
}

#[test]
fn pjrt_distances_validate_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let dist = PjrtDistances::from_manifest(&client, &manifest, 128, 256).unwrap();
    // too many members
    assert!(dist
        .distances(&vec![0f32; 300 * 128], 300, &[0f32; 128])
        .is_err());
    // zero members
    assert!(dist.distances(&[], 0, &[0f32; 128]).is_err());
    // too many query rows (> batch)
    assert!(dist
        .distances(&vec![0f32; 10 * 128], 10, &vec![0f32; 9 * 128])
        .is_err());
}

#[test]
fn pjrt_engine_with_scan_matches_native_engine() {
    use amsearch::coordinator::Engine;
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    let (idx, wl) = default_shape_index(9);
    let idx = Arc::new(idx);
    let native = Engine::native(idx.clone()).unwrap();
    let pjrt = Engine::pjrt(idx.clone(), &dir).unwrap();
    // n=4096, q=64 -> k=64 <= 256 artifact capacity: scan goes via PJRT
    assert!(pjrt.has_pjrt_scan(), "expected PJRT scan path to activate");
    // k = 3: both backends must agree on the whole ranked neighbor list
    let queries: Vec<(&[f32], usize, usize)> =
        (0..8).map(|i| (wl.queries.get(i), 4usize, 3usize)).collect();
    let a = native.serve_batch(&queries).unwrap();
    let b = pjrt.serve_batch(&queries).unwrap();
    for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(ra.polled, rb.polled, "query {i}");
        assert_eq!(ra.candidates, rb.candidates, "query {i}");
        assert_eq!(ra.neighbors.len(), rb.neighbors.len(), "query {i}");
        for (na, nb) in ra.neighbors.iter().zip(&rb.neighbors) {
            assert_eq!(na.id, nb.id, "query {i}");
            assert!(
                (na.distance - nb.distance).abs() / na.distance.max(1.0) < 1e-3,
                "query {i}: {} vs {}",
                na.distance,
                nb.distance
            );
        }
    }
}

#[test]
fn pjrt_bank_builder_matches_native_build() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    // d=128, q=64, k=256: the default AOT build_bank config
    let builder =
        amsearch::runtime::PjrtBankBuilder::from_manifest(&client, &manifest, 128, 64, 256)
            .unwrap();
    assert_eq!(builder.class_size(), 256);
    let (idx, _) = default_shape_index(8); // n=4096 e.g. k=64 per class... rebuild below
    // assemble members in AOT layout [q, k, d], zero-padded
    let q = 64;
    let k = 256;
    let d = 128;
    let mut members = vec![0f32; q * k * d];
    for ci in 0..q {
        for (j, &vid) in idx.partition().members(ci).iter().enumerate().take(k) {
            let src = idx.data().get(vid as usize);
            members[ci * k * d + j * d..ci * k * d + (j + 1) * d].copy_from_slice(src);
        }
    }
    let built = builder.build(&members).unwrap();
    let native = idx.bank().stacked();
    assert_eq!(built.len(), native.len());
    for (i, (a, b)) in built.iter().zip(native).enumerate() {
        assert!(
            (a - b).abs() / b.abs().max(1.0) < 1e-3,
            "idx {i}: pjrt={a} native={b}"
        );
    }
}

#[test]
fn manifest_verification_catches_tampering() {
    let Some(dir) = artifacts_dir() else { return };
    // copy artifacts to a temp dir, tamper with one file
    let tmp = std::env::temp_dir().join(format!("amsearch_tamper_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), tmp.join(entry.file_name())).unwrap();
    }
    let manifest = Manifest::load(&tmp).unwrap();
    let scores = manifest.find_scores(128, 64).unwrap();
    let path = manifest.path_of(scores);
    let mut text = std::fs::read_to_string(&path).unwrap();
    text.push_str("\n// tampered\n");
    std::fs::write(&path, text).unwrap();
    let err = manifest.verify(scores).unwrap_err();
    assert!(err.to_string().contains("sha256 mismatch"), "{err}");
    // and the scorer constructor refuses to load it
    let client = cpu_client().unwrap();
    assert!(PjrtScorer::from_manifest(&client, &manifest, &vec![0f32; 64 * 128 * 128], 128, 64)
        .is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn missing_artifact_is_actionable_error() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let msg = match PjrtScorer::from_manifest(&client, &manifest, &vec![0f32; 3 * 7 * 7], 7, 3)
    {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e.to_string(),
    };
    assert!(msg.contains("d=7"), "{msg}");
    assert!(msg.contains("make artifacts") || msg.contains("compile.aot"), "{msg}");
}
