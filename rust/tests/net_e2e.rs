//! Network serving end-to-end tests: real TCP connections on an
//! ephemeral localhost port, pipelined concurrent clients, typed
//! validation at the boundary, JSON-lines debug mode, graceful drain —
//! and the core acceptance pin: a network response is bitwise-identical
//! to the in-process `SearchServer::search` answer on the same index.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::data::Workload;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::net::{
    loadgen, wire, LoadGenConfig, NetClient, NetConfig, NetServer, RetryPolicy,
};
use amsearch::runtime::Backend;
use amsearch::util::Json;

fn start_stack(
    seed: u64,
    d: usize,
    n: usize,
    q: usize,
) -> (Arc<SearchServer>, NetServer, Workload) {
    let mut rng = Rng::new(seed);
    let wl = synthetic::dense_workload(d, n, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: q, top_p: 2, ..Default::default() };
    let idx = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let factory = EngineFactory { index: idx, backend: Backend::Native, artifacts_dir: None };
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 300,
        workers: 2,
        queue_depth: 64,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(factory, config).unwrap());
    // small handler pool + fast poll: tests run many stacks in parallel
    let net_cfg = NetConfig {
        max_connections: 8,
        max_inflight: 128,
        poll_ms: 10,
        ..Default::default()
    };
    let net = NetServer::bind(server.clone(), "127.0.0.1:0", net_cfg).unwrap();
    (server, net, wl)
}

/// Acceptance pin: ephemeral port, >= 2 concurrent pipelined client
/// connections, responses bitwise-identical (ids and distances) to the
/// in-process answer for the same query.
#[test]
fn tcp_pipelined_clients_match_in_process() {
    let (server, net, wl) = start_stack(1, 32, 512, 8);
    let addr = net.local_addr();

    // (query index, top_p, top_k) cells covering defaults (0), k > 1,
    // full poll, and k beyond the class size
    let cells: Vec<(usize, usize, usize)> = (0..24)
        .map(|i| {
            let qi = i % wl.queries.len();
            let p = [0usize, 1, 2, 8][i % 4];
            let k = [0usize, 1, 5, 300][(i / 4) % 4];
            (qi, p, k)
        })
        .collect();

    // in-process reference answers on the very same running server
    let expected: Vec<_> = cells
        .iter()
        .map(|&(qi, p, k)| {
            let r = server.search(wl.queries.get(qi).to_vec(), p, k).unwrap();
            (r.neighbors, r.polled, r.candidates as u64)
        })
        .collect();

    let n_clients = 3usize; // >= 2 concurrent connections
    let results = amsearch::util::concurrent_map(n_clients, n_clients, |_| {
        let mut client = NetClient::connect(addr).unwrap();
        client.set_timeout(Some(Duration::from_secs(30))).unwrap();
        // pipelined: submit everything first, then collect by id
        let ids: Vec<u64> = cells
            .iter()
            .map(|&(qi, p, k)| client.submit(wl.queries.get(qi), p, k).unwrap())
            .collect();
        assert_eq!(client.in_flight(), cells.len());
        ids.into_iter().map(|id| client.wait(id).unwrap()).collect::<Vec<_>>()
    });

    for responses in results {
        for (ci, resp) in responses.iter().enumerate() {
            let (exp_neighbors, exp_polled, exp_candidates) = &expected[ci];
            // Neighbor is (u32 id, f32 distance): PartialEq equality on
            // finite distances == bitwise equality of both fields
            assert_eq!(&resp.neighbors, exp_neighbors, "cell {ci}");
            for (a, b) in resp.neighbors.iter().zip(exp_neighbors) {
                assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "cell {ci}");
            }
            assert_eq!(&resp.polled, exp_polled, "cell {ci}");
            assert_eq!(resp.candidates, *exp_candidates, "cell {ci}");
            assert!(resp.ops > 0);
        }
    }

    net.shutdown();
    server.shutdown();
}

#[test]
fn validation_errors_have_stable_codes_and_connection_survives() {
    let (server, net, wl) = start_stack(2, 32, 128, 4);
    let addr = net.local_addr();
    let mut client = NetClient::connect(addr).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();

    // wrong dimension -> ERR_BAD_DIM from the server boundary
    let id = client.submit(&[0.0f32; 31], 1, 1).unwrap();
    let err = client.wait_detailed(id).unwrap().unwrap_err();
    assert_eq!(err.code, wire::ERR_BAD_DIM);
    assert!(err.message.contains("dim"), "{}", err.message);

    // oversized top_k -> ERR_BAD_K from the wire boundary
    let id = client
        .submit(wl.queries.get(0), 1, (wire::MAX_WIRE_TOP_K + 1) as usize)
        .unwrap();
    let err = client.wait_detailed(id).unwrap().unwrap_err();
    assert_eq!(err.code, wire::ERR_BAD_K);

    // the connection is still usable after both error frames
    let ok = client.search_k(wl.queries.get(0), 4, 1).unwrap();
    assert_eq!(ok.neighbors.len(), 1);
    assert_eq!(ok.polled.len(), 4);

    net.shutdown();
    server.shutdown();
}

#[test]
fn zero_length_search_frame_gets_error_frame_not_hangup() {
    let (server, net, _wl) = start_stack(3, 32, 128, 4);
    let addr = net.local_addr();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // hand-crafted SEARCH frame with an empty payload
    let mut raw = Vec::new();
    raw.extend_from_slice(&wire::MAGIC);
    raw.push(wire::VERSION);
    raw.push(0x01); // FT_SEARCH
    raw.extend_from_slice(&0u16.to_le_bytes());
    raw.extend_from_slice(&77u64.to_le_bytes());
    raw.extend_from_slice(&0u32.to_le_bytes());
    stream.write_all(&raw).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let frame = wire::read_frame(&mut reader).unwrap();
    let wire::Frame::Error(e) = frame else { panic!("expected error frame") };
    assert_eq!(e.code, wire::ERR_BAD_FRAME);
    assert_eq!(e.id, 77);

    // connection survives: a ping still answers
    stream
        .write_all(&wire::Frame::Ping { id: 78 }.encode())
        .unwrap();
    assert_eq!(
        wire::read_frame(&mut reader).unwrap(),
        wire::Frame::Pong { id: 78 }
    );

    net.shutdown();
    server.shutdown();
}

#[test]
fn admin_ping_and_stats() {
    let (server, net, wl) = start_stack(4, 32, 128, 4);
    let addr = net.local_addr();
    let mut client = NetClient::connect(addr).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    client.ping().unwrap();
    for qi in 0..5 {
        client.search_k(wl.queries.get(qi), 2, 3).unwrap();
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("dim").unwrap().as_usize(), Some(32));
    assert_eq!(stats.get("n_vectors").unwrap().as_usize(), Some(128));
    assert!(stats.get("requests").unwrap().as_u64().unwrap() >= 5);
    let latency = stats.get("latency").unwrap();
    assert!(latency.get("count").unwrap().as_u64().unwrap() >= 5);
    assert!(latency.get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
    net.shutdown();
    server.shutdown();
}

#[test]
fn json_lines_mode_serves_and_matches_binary() {
    let (server, net, wl) = start_stack(5, 32, 128, 4);
    let addr = net.local_addr();
    let expected = server.search(wl.queries.get(0).to_vec(), 4, 3).unwrap();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // ping first: the very first byte ('{') selects JSON-lines mode
    stream.write_all(b"{\"op\":\"ping\",\"id\":1}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("op").unwrap().as_str(), Some("pong"));
    assert_eq!(v.get("id").unwrap().as_u64(), Some(1));

    // a search through the JSON encoding matches the in-process answer
    let req = wire::Frame::Search(wire::WireRequest {
        id: 2,
        top_p: 4,
        top_k: 3,
        trace_id: 0,
        vector: wl.queries.get(0).to_vec(),
    });
    stream.write_all(req.to_json_line().as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim()).unwrap();
    let wire::Frame::Result(resp) = wire::Frame::from_json(&v).unwrap() else {
        panic!("expected result, got {line}");
    };
    assert_eq!(resp.id, 2);
    assert_eq!(resp.neighbors, expected.neighbors);
    assert_eq!(resp.polled, expected.polled);

    // a malformed line gets a typed error and the connection survives
    stream.write_all(b"this is not json\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("op").unwrap().as_str(), Some("error"));
    assert_eq!(
        v.get("code").unwrap().as_u64(),
        Some(wire::ERR_BAD_FRAME as u64)
    );
    stream.write_all(b"{\"op\":\"ping\",\"id\":3}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("op").unwrap().as_str(),
        Some("pong")
    );

    net.shutdown();
    server.shutdown();
}

#[test]
fn shutdown_frame_drains_and_stops_the_server() {
    let (server, net, wl) = start_stack(6, 32, 256, 4);
    let addr = net.local_addr();

    // connection A: pipeline a burst and collect every response — all
    // of them were accepted, so all of them must resolve
    let mut a = NetClient::connect(addr).unwrap();
    a.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let ids: Vec<u64> = (0..16)
        .map(|i| a.submit(wl.queries.get(i % wl.queries.len()), 2, 1).unwrap())
        .collect();
    for id in ids {
        a.wait(id).unwrap();
    }

    // connection B initiates the shutdown
    let mut b = NetClient::connect(addr).unwrap();
    b.set_timeout(Some(Duration::from_secs(30))).unwrap();
    b.shutdown_server().unwrap();

    // the front door fully drains: join() must return (bounded by the
    // connection poll interval), and only then is the coordinator
    // stopped — the drain ordering under test
    net.join();
    assert!(net.is_shutting_down());
    let m = server.metrics();
    assert!(m.requests >= 16);
    server.shutdown();

    // new connections are refused once the listener is gone; a search
    // on the drained connection resolves (error or EOF), never hangs
    match a.search_k(wl.queries.get(0), 1, 1) {
        Ok(_) => panic!("server should no longer serve searches"),
        Err(e) => {
            let msg = e.to_string();
            assert!(!msg.is_empty());
        }
    }
}

/// Satellite pin: `connect_backoff` retries through a server that
/// refuses the first attempt (an `ERR_OVERLOADED` frame, the saturated
/// accept loop's behavior) and lands a verified, usable connection on
/// the second — the mechanism that lets router→shard links survive
/// shard restarts.  Against a dead port it fails after bounded
/// attempts instead of hanging.
#[test]
fn connect_backoff_survives_initial_refusal_and_is_bounded() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        // first connection: refuse exactly like the overloaded accept
        // loop does (typed ERROR frame, then hang up)
        let (mut s1, _) = listener.accept().unwrap();
        let refusal = wire::Frame::Error(wire::WireError {
            id: 0,
            code: wire::ERR_OVERLOADED,
            message: "connection-handler pool exhausted".into(),
        });
        s1.write_all(&refusal.encode()).unwrap();
        drop(s1);
        // second connection: answer pings until the client hangs up
        let (mut s2, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(s2.try_clone().unwrap());
        while let Ok(frame) = wire::read_frame(&mut reader) {
            if let wire::Frame::Ping { id } = frame {
                s2.write_all(&wire::Frame::Pong { id }.encode()).unwrap();
            }
        }
    });
    let policy = RetryPolicy {
        max_attempts: 5,
        base_delay: Duration::from_millis(5),
        max_delay: Duration::from_millis(20),
        ..Default::default()
    };
    let mut client = NetClient::connect_backoff(&addr, &policy).unwrap();
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();
    client.ping().unwrap(); // the surviving link is actually usable
    drop(client);
    server.join().unwrap();

    // bounded failure: a "server" that accepts and immediately hangs up
    // on every attempt (never answers PING) must exhaust the policy and
    // error out — deterministic, unlike racing for a released port
    let dead_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let dead = dead_listener.local_addr().unwrap().to_string();
    let attempts = policy.max_attempts;
    let dropper = std::thread::spawn(move || {
        for _ in 0..attempts {
            if let Ok((s, _)) = dead_listener.accept() {
                drop(s);
            }
        }
    });
    let started = Instant::now();
    assert!(NetClient::connect_backoff(&dead, &policy).is_err());
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "backoff must be bounded"
    );
    dropper.join().unwrap();
}

/// Satellite pin: STATS exports the net-layer overload counters — the
/// `ERR_OVERLOADED` refusal count and the current pipelined depth —
/// alongside the backend snapshot, and labels the backend role.
#[test]
fn stats_exports_refusal_and_inflight_counters() {
    let mut rng = Rng::new(9);
    let wl = synthetic::dense_workload(16, 128, 8, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 4, top_p: 2, ..Default::default() };
    let idx = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let factory =
        EngineFactory { index: idx, backend: Backend::Native, artifacts_dir: None };
    let server =
        Arc::new(SearchServer::start(factory, CoordinatorConfig::default()).unwrap());
    // pool of exactly one handler (+ a one-slot queue): the third
    // concurrent connection must be refused with ERR_OVERLOADED
    let net_cfg = NetConfig { max_connections: 1, poll_ms: 5, ..Default::default() };
    let net = NetServer::bind(server.clone(), "127.0.0.1:0", net_cfg).unwrap();
    let addr = net.local_addr();

    let mut a = NetClient::connect(addr).unwrap();
    a.set_timeout(Some(Duration::from_secs(30))).unwrap();
    a.ping().unwrap(); // a ping answered == a occupies the one handler
    let _queued = TcpStream::connect(addr).unwrap(); // fills the queue
    // give the accept loop a beat to queue the second connection, so
    // the third deterministically overflows
    std::thread::sleep(Duration::from_millis(100));
    let refused = TcpStream::connect(addr).unwrap();
    refused.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(refused);
    let frame = wire::read_frame(&mut reader).unwrap();
    let wire::Frame::Error(e) = frame else { panic!("expected refusal frame") };
    assert_eq!(e.code, wire::ERR_OVERLOADED);

    // a few searches through the surviving connection, then STATS: the
    // refusal was counted, and with every response claimed the current
    // pipelined depth reads zero again
    for qi in 0..4 {
        a.search_k(wl.queries.get(qi), 2, 1).unwrap();
    }
    let stats = a.stats().unwrap();
    assert_eq!(stats.get("role").unwrap().as_str(), Some("search"));
    let netj = stats.get("net").expect("net counters present");
    assert_eq!(
        netj.get("refused_connections").unwrap().as_u64(),
        Some(1),
        "exactly one refusal"
    );
    assert_eq!(netj.get("max_connections").unwrap().as_usize(), Some(1));
    assert!(netj.get("max_inflight").is_some());
    // the writer thread releases a slot just *after* writing the
    // response, so the gauge may lag the client by a beat — poll it
    // back down to zero within a bounded window
    let mut inflight = u64::MAX;
    for _ in 0..200 {
        let s = a.stats().unwrap();
        inflight = s
            .get("net")
            .and_then(|n| n.get("inflight"))
            .and_then(|v| v.as_u64())
            .unwrap_or(u64::MAX);
        if inflight == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(inflight, 0, "all claimed responses release their slots");

    net.shutdown();
    server.shutdown();
}

#[test]
fn loadgen_closed_loop_reports_throughput_and_latency() {
    let (server, net, wl) = start_stack(7, 32, 256, 4);
    let addr = net.local_addr().to_string();
    let queries: Vec<Vec<f32>> =
        (0..wl.queries.len()).map(|qi| wl.queries.get(qi).to_vec()).collect();
    let cfg = LoadGenConfig {
        connections: 2,
        requests: 100,
        depth: 4,
        top_p: 2,
        top_k: 3,
        connect_timeout: Duration::from_secs(10),
    };
    let report = loadgen::run(&addr, &queries, &cfg).unwrap();
    assert_eq!(report.requests, 100);
    assert_eq!(report.errors, 0);
    assert_eq!(report.latency.count(), 100);
    assert!(report.qps() > 0.0);
    let j = report.to_json();
    assert_eq!(j.get("requests").unwrap().as_u64(), Some(100));
    assert!(j.get("latency").unwrap().get("p90_ns").is_some());
    // the rolling-window view: a short run fits entirely inside the
    // window, so its tail quantiles cover every sample
    assert_eq!(report.window.windowed().count(), 100);
    assert!(j.get("window_p99_ns").unwrap().as_u64().is_some());
    assert!(j.get("window").unwrap().get("window_s").is_some());
    // the server counted exactly the loadgen traffic
    assert_eq!(server.metrics().requests, 100);
    net.shutdown();
    server.shutdown();
}

/// Regression pin for the buffered trace sink: the sink now buffers
/// through a `BufWriter`, so records would sit in the writer buffer
/// forever unless the graceful drain flushes them.  A short-lived
/// traced server must lose nothing: file lines == records emitted.
#[test]
fn traced_server_flushes_buffered_records_on_shutdown() {
    use amsearch::obs::TraceSink;
    let mut rng = Rng::new(21);
    let wl = synthetic::dense_workload(16, 128, 8, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 4, top_p: 2, ..Default::default() };
    let idx = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let factory =
        EngineFactory { index: idx, backend: Backend::Native, artifacts_dir: None };
    let dir = std::env::temp_dir()
        .join(format!("amsearch_net_e2e_{}_flush", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    let sink = TraceSink::to_file(&path, 1, 0).unwrap(); // sample everything
    let config = CoordinatorConfig {
        max_batch: 4,
        max_wait_us: 200,
        workers: 1,
        queue_depth: 64,
        quality_sample: 0,
    };
    let server = Arc::new(
        SearchServer::start_traced(factory, config, Some(sink.clone())).unwrap(),
    );
    let net_cfg = NetConfig { max_connections: 4, poll_ms: 5, ..Default::default() };
    let net = NetServer::bind(server.clone(), "127.0.0.1:0", net_cfg).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    for qi in 0..9 {
        client.search_k(wl.queries.get(qi), 2, 1).unwrap();
    }
    net.shutdown();
    server.shutdown(); // the drain flushes the buffered sink
    assert!(sink.emitted() >= 9, "every request was sampled");
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        text.lines().count() as u64,
        sink.emitted(),
        "no trace record may be lost in the writer buffer"
    );
    for line in text.lines() {
        Json::parse(line).unwrap(); // each line is a complete record
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Quality-sampling acceptance pin (single node): with every request
/// shadow-verified (`quality_sample = 1`), full poll on an exact index,
/// (a) responses stay bitwise-identical to an unsampled server over the
/// same index, and (b) the online recall estimate is exactly 1.0 —
/// the shadow exhaustive scan and the full-poll serving answer see the
/// same candidate set.
#[test]
fn quality_sampled_serving_is_identical_and_estimates_unity_recall() {
    use amsearch::net::Serveable;
    let mut rng = Rng::new(23);
    let wl = synthetic::dense_workload(24, 192, 16, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 6, top_p: 6, ..Default::default() };
    let idx = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let mk = |quality_sample: u64| {
        let factory = EngineFactory {
            index: idx.clone(),
            backend: Backend::Native,
            artifacts_dir: None,
        };
        let config = CoordinatorConfig {
            max_batch: 4,
            max_wait_us: 200,
            workers: 2,
            queue_depth: 64,
            quality_sample,
        };
        Arc::new(SearchServer::start(factory, config).unwrap())
    };
    let sampled = mk(1);
    let plain = mk(0);
    let total = 32usize;
    for i in 0..total {
        let q = wl.queries.get(i % wl.queries.len());
        let a = sampled.search(q.to_vec(), 6, 3).unwrap();
        let b = plain.search(q.to_vec(), 6, 3).unwrap();
        assert_eq!(a.neighbors, b.neighbors, "query {i}");
        for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(x.distance.to_bits(), y.distance.to_bits(), "query {i}");
        }
        assert_eq!(a.polled, b.polled, "query {i}");
        assert_eq!(a.candidates, b.candidates, "query {i}");
    }
    // the shadow worker runs off the hot path: poll STATS until it has
    // digested every sample (bounded; 32 pushes can never overflow the
    // 256-slot queue, so nothing is dropped)
    let mut samples = 0u64;
    for _ in 0..1000 {
        let stats = Serveable::stats_json(&*sampled);
        samples = stats
            .get("quality")
            .and_then(|q| q.get("samples"))
            .and_then(|v| v.as_u64())
            .unwrap_or(0);
        if samples == total as u64 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(samples, total as u64, "every request was shadow-verified");
    let stats = Serveable::stats_json(&*sampled);
    let q = stats.get("quality").expect("quality block present");
    assert_eq!(q.get("recall").unwrap().as_f64(), Some(1.0), "exactly 1.0");
    assert_eq!(q.get("dropped").unwrap().as_u64(), Some(0));
    assert_eq!(q.get("exact_matches").unwrap().as_u64(), Some(total as u64));
    // the pinned Prometheus families follow the same snapshot
    let text = Serveable::metrics_registry(&*sampled).render();
    assert!(text.contains("amsearch_quality_samples_total"), "{text}");
    assert!(text.contains("amsearch_quality_recall"), "{text}");
    // the unsampled server exports no estimate at all
    let plain_stats = Serveable::stats_json(&*plain);
    assert!(plain_stats.get("quality").is_none());
    sampled.shutdown();
    plain.shutdown();
}

/// EXPLAIN over the wire: the introspection report's final neighbors
/// agree with the served answer for the same query, the poll decision
/// is visible, and the `exact` section reports unity recall on a
/// full-poll exact configuration.  Traffic on the same connection is
/// untouched before and after.
#[test]
fn explain_frame_report_matches_serving_answer() {
    let (server, net, wl) = start_stack(31, 32, 256, 8);
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let q = wl.queries.get(0);
    let served = client.search_k(q, 8, 5).unwrap();
    let report = client.explain(q, 8, 5, true).unwrap();
    // final neighbors mirror the serving answer, id for id
    let neighbors = report.get("neighbors").unwrap();
    let Json::Arr(items) = neighbors else { panic!("neighbors not an array") };
    assert_eq!(items.len(), served.neighbors.len());
    for (item, n) in items.iter().zip(&served.neighbors) {
        assert_eq!(item.get("id").unwrap().as_u64(), Some(n.id as u64));
        assert!(item.get("class").is_some());
    }
    // the poll decision is reported per class with the polled cut
    let poll = report.get("poll").expect("poll block");
    let Json::Arr(classes) = poll.get("classes").unwrap() else {
        panic!("classes not an array")
    };
    assert_eq!(classes.len(), 8, "every class is scored");
    assert_eq!(
        classes
            .iter()
            .filter(|c| c.get("polled").and_then(|v| v.as_bool()) == Some(true))
            .count(),
        8,
        "full poll"
    );
    // ground truth: full poll on an exact index is exhaustive
    let exact = report.get("exact").expect("exact section requested");
    assert_eq!(exact.get("recall").unwrap().as_f64(), Some(1.0));
    assert_eq!(exact.get("matches_exactly").unwrap().as_bool(), Some(true));
    // the connection still serves plain traffic, byte-identically
    let after = client.search_k(q, 8, 5).unwrap();
    assert_eq!(after.neighbors, served.neighbors);
    for (a, b) in after.neighbors.iter().zip(&served.neighbors) {
        assert_eq!(a.distance.to_bits(), b.distance.to_bits());
    }
    net.shutdown();
    server.shutdown();
}

/// The export surfaces must never disagree: the requests counter in the
/// STATS JSON snapshot and in the Prometheus text exposition (METRICS
/// frame) come from the same metrics snapshot, and the exposition
/// passes the format validator with every required family present.
#[test]
fn metrics_exposition_agrees_with_stats() {
    use amsearch::obs;
    let (server, net, wl) = start_stack(11, 16, 128, 4);
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    for qi in 0..7 {
        client.search_k(wl.queries.get(qi), 2, 1).unwrap();
    }
    let stats = client.stats().unwrap();
    let text = client.metrics_text().unwrap();
    obs::prom::validate(&text, &obs::REQUIRED_FAMILIES).unwrap();
    let stats_requests = stats.get("requests").unwrap().as_u64().unwrap();
    assert_eq!(stats_requests, 7);
    let prom_requests: u64 = text
        .lines()
        .find_map(|l| l.strip_prefix("amsearch_requests_total{role=\"search\"} "))
        .expect("requests sample present")
        .parse()
        .unwrap();
    assert_eq!(prom_requests, stats_requests, "STATS and exposition agree");
    // windowed family is exported alongside the cumulative one
    assert!(text.contains("amsearch_window_latency_ns"));
    assert!(text.contains("amsearch_net_inflight"));
    net.shutdown();
    server.shutdown();
}
