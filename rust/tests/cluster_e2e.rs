//! Cluster-tier end-to-end tests: a real in-process cluster (N shard
//! servers + scatter-gather router, all over loopback TCP) driven
//! through the production wire path.
//!
//! Core pins: full fan-out (`s = N`) with per-shard full poll is
//! bitwise-identical to single-node search; pruned fan-out (`s < N`)
//! degrades recall monotonically; router end-to-end latency and
//! shard-reported service time stay in separate named histograms.

use std::sync::Arc;
use std::time::Duration;

use amsearch::cluster::{
    self, ClusterConfig, ClusterHarness, ShardPlan, ShardStrategy,
};
use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::OpsCounter;
use amsearch::net::{NetClient, NetConfig};
use amsearch::runtime::Backend;

fn fast_cluster_cfg(n_shards: usize, strategy: ShardStrategy) -> ClusterConfig {
    ClusterConfig {
        n_shards,
        strategy,
        coordinator: CoordinatorConfig {
            max_batch: 4,
            max_wait_us: 200,
            workers: 1,
            queue_depth: 64,
            quality_sample: 0,
        },
        net: NetConfig { max_connections: 8, poll_ms: 5, ..Default::default() },
        ..Default::default()
    }
}

/// Acceptance pin (unit flavor; the proptest sweeps random shapes):
/// routed responses at s = N with full poll are bitwise-identical —
/// neighbor ids and distance bits — to in-process single-node answers,
/// through a real TCP client against the router's front door.
#[test]
fn router_full_fanout_matches_single_node_over_tcp() {
    let mut rng = Rng::new(71);
    let (d, n, q) = (32usize, 256usize, 8usize);
    let wl = synthetic::dense_workload(d, n, 16, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: q, top_p: 2, top_k: 3, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();

    // single-node reference on the very same index
    let factory = EngineFactory {
        index: Arc::new(index.clone()),
        backend: Backend::Native,
        artifacts_dir: None,
    };
    let single = SearchServer::start(
        factory,
        CoordinatorConfig { workers: 1, ..Default::default() },
    )
    .unwrap();

    let cfg = fast_cluster_cfg(3, ShardStrategy::BalancedMembers);
    let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(cluster.router().fan_out(), 3, "default fan-out is exact");

    let mut client = NetClient::connect(cluster.router_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();

    for (qi, k) in [(0usize, 1usize), (1, 5), (2, 300), (3, 0), (4, 7)] {
        let query = wl.queries.get(qi);
        let expected = single.search(query.to_vec(), q, k).unwrap();
        let routed = client.search_k(query, q, k).unwrap();
        assert_eq!(routed.neighbors.len(), expected.neighbors.len(), "k={k}");
        for (a, b) in routed.neighbors.iter().zip(&expected.neighbors) {
            assert_eq!(a.id, b.id, "qi={qi} k={k}");
            assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "qi={qi} k={k}");
        }
        assert_eq!(routed.candidates, expected.candidates as u64, "full scan");
        // full poll reaches every class, across all shards
        let mut polled = routed.polled.clone();
        polled.sort_unstable();
        assert_eq!(polled, (0..q as u32).collect::<Vec<_>>());
    }

    // the router's STATS reply identifies itself and carries the
    // cluster-tier fields
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("role").unwrap().as_str(), Some("router"));
    assert_eq!(stats.get("shards").unwrap().as_usize(), Some(3));
    assert_eq!(stats.get("fan_out").unwrap().as_usize(), Some(3));
    assert!(stats.get("shard_service").is_some());
    assert!(stats.get("fanout").is_some());
    // shard front doors are labeled by the harness
    let mut shard_client = NetClient::connect(cluster.shard_addr(0)).unwrap();
    let shard_stats = shard_client.stats().unwrap();
    assert_eq!(shard_stats.get("role").unwrap().as_str(), Some("shard"));
    assert!(shard_stats.get("net").is_some());

    cluster.shutdown();
    single.shutdown();
}

/// Shard pruning is the class-polling trade-off one level up: with the
/// fan-out ranking fixed per query, the candidate set at s is a subset
/// of the candidate set at s + 1, so recall@1 against the exact ground
/// truth is non-decreasing in s — and exact at s = N with full poll.
#[test]
fn pruned_fanout_degrades_recall_monotonically() {
    let mut rng = Rng::new(72);
    let spec = ClusteredSpec { dim: 32, n_clusters: 16, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, 768, 48, &mut rng);
    let params = IndexParams { n_classes: 16, top_p: 16, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let cfg = fast_cluster_cfg(4, ShardStrategy::RoundRobin);
    let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();

    let mut recalls = Vec::new();
    for s in 1..=4usize {
        cluster.router().set_fan_out(s);
        let mut hits = 0usize;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let resp = cluster
                .router()
                .search(wl.queries.get(qi).to_vec(), 16, 1)
                .unwrap();
            if resp.neighbor() == Some(gt) {
                hits += 1;
            }
        }
        recalls.push(hits as f64 / wl.ground_truth.len() as f64);
    }
    for w in recalls.windows(2) {
        assert!(
            w[0] <= w[1] + 1e-12,
            "recall must be monotone in fan-out: {recalls:?}"
        );
    }
    assert_eq!(recalls[3], 1.0, "s = N with full poll is exact: {recalls:?}");
    assert!(
        recalls[0] < 1.0,
        "s = 1 on a 4-shard clustered corpus must lose recall: {recalls:?}"
    );

    let m = cluster.router().metrics();
    assert_eq!(m.requests, 4 * 48);
    assert_eq!(m.fanout.requests, 4 * 48);
    // 1 + 2 + 3 + 4 contacts per query over the sweep
    assert_eq!(m.fanout.contacts, (1 + 2 + 3 + 4) * 48);
    assert_eq!(m.fanout.full_fanouts, 48, "only the s = 4 pass is exact fan-out");
    cluster.shutdown();
}

/// The double-count fix: the router records its own end-to-end latency
/// and the shard-reported service times in two separate named
/// histograms — one sample per request in `latency`, one per shard
/// contact in `shard_service`, never merged.
#[test]
fn router_keeps_end_to_end_and_shard_histograms_separate() {
    let mut rng = Rng::new(73);
    let wl = synthetic::dense_workload(24, 180, 10, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 6, top_p: 2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let cluster = ClusterHarness::launch(
        &index,
        "127.0.0.1:0",
        &fast_cluster_cfg(3, ShardStrategy::Contiguous),
    )
    .unwrap();
    cluster.router().set_fan_out(2);
    for qi in 0..10 {
        cluster
            .router()
            .search(wl.queries.get(qi).to_vec(), 2, 1)
            .unwrap();
    }
    let m = cluster.router().metrics();
    assert_eq!(m.latency.count(), 10, "one end-to-end sample per request");
    assert_eq!(
        m.shard_service.count(),
        20,
        "one shard-service sample per shard contact (s = 2)"
    );
    let stats = amsearch::net::Serveable::stats_json(&**cluster.router());
    let lat = stats.get("latency").unwrap();
    let svc = stats.get("shard_service").unwrap();
    assert_eq!(lat.get("count").unwrap().as_u64(), Some(10));
    assert_eq!(svc.get("count").unwrap().as_u64(), Some(20));
    cluster.shutdown();
}

/// The persisted path: `shard-plan` artifacts + v3 manifest loaded back
/// by `serve-cluster --plan-dir` serve bitwise-identically to the
/// original index (full fan-out, full poll).
#[test]
fn cluster_from_plan_dir_serves_identically() {
    let mut rng = Rng::new(74);
    let wl = synthetic::dense_workload(16, 200, 10, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 10, top_p: 3, top_k: 2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let plan = ShardPlan::for_index(&index, 3, ShardStrategy::Contiguous).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "amsearch_cluster_e2e_{}_plandir",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    cluster::write_cluster(&index, &plan, &dir).unwrap();

    let cluster = ClusterHarness::launch_from_dir(
        &dir,
        "127.0.0.1:0",
        &fast_cluster_cfg(3, ShardStrategy::Contiguous),
    )
    .unwrap();
    let mut ops = OpsCounter::new();
    for qi in 0..10 {
        let query = wl.queries.get(qi);
        let expected = index.query_k(query, 10, 4, &mut ops);
        let routed = cluster.router().search(query.to_vec(), 10, 4).unwrap();
        assert_eq!(routed.neighbors.len(), expected.neighbors.len());
        for (a, b) in routed.neighbors.iter().zip(&expected.neighbors) {
            assert_eq!(a.id, b.id, "query {qi}");
            assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "query {qi}");
        }
        assert_eq!(routed.candidates, expected.candidates);
    }
    cluster.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance pin for the quantized cluster: with `ScanPrecision::Sq8`
/// and `rerank = 0` (rerank everything), routed answers at s = N —
/// through quantized v4 shard artifacts loaded from a plan directory —
/// are bitwise-identical to the **exact** single-node index, and the
/// router's STATS report the summed compressed footprint at ≤ 0.35×
/// the f32 member-matrix bytes.
#[test]
fn quantized_cluster_matches_exact_and_reports_compression() {
    use amsearch::net::Serveable;
    use amsearch::quant::ScanPrecision;
    let mut rng = Rng::new(79);
    let wl = synthetic::dense_workload(32, 240, 12, QueryModel::Exact, &mut rng);
    let exact = AmIndex::build(
        wl.base.clone(),
        IndexParams { n_classes: 8, top_p: 2, ..Default::default() },
        &mut Rng::new(80),
    )
    .unwrap();
    let quantized = AmIndex::build(
        wl.base.clone(),
        IndexParams {
            n_classes: 8,
            top_p: 2,
            precision: ScanPrecision::Sq8 { rerank: 0 },
            ..Default::default()
        },
        &mut Rng::new(80),
    )
    .unwrap();
    let plan = ShardPlan::for_index(&quantized, 3, ShardStrategy::BalancedMembers).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "amsearch_cluster_e2e_{}_quant",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    cluster::write_cluster(&quantized, &plan, &dir).unwrap();

    let cluster = ClusterHarness::launch_from_dir(
        &dir,
        "127.0.0.1:0",
        &fast_cluster_cfg(3, ShardStrategy::BalancedMembers),
    )
    .unwrap();
    let mut ops = OpsCounter::new();
    for qi in 0..12 {
        let query = wl.queries.get(qi);
        for k in [1usize, 4, 300] {
            let expected = exact.query_k(query, 8, k, &mut ops);
            let routed = cluster.router().search(query.to_vec(), 8, k).unwrap();
            assert_eq!(
                routed.neighbors.len(),
                expected.neighbors.len(),
                "query {qi} k={k}"
            );
            for (a, b) in routed.neighbors.iter().zip(&expected.neighbors) {
                assert_eq!(a.id, b.id, "query {qi} k={k}");
                assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "query {qi} k={k}");
            }
            assert_eq!(routed.candidates, expected.candidates);
        }
    }
    // the router's STATS carry the cluster-wide compression, summed
    // over the shard indices it loaded from disk
    let stats = Serveable::stats_json(cluster.router().as_ref());
    let index_obj = stats.get("index").expect("router stats carry index.*");
    let bytes = index_obj.get("bytes").and_then(|v| v.as_u64()).unwrap();
    let compressed = index_obj
        .get("compressed_bytes")
        .and_then(|v| v.as_u64())
        .unwrap();
    assert_eq!(bytes, (240 * 32 * 4) as u64, "shard footprints sum to the corpus");
    assert!(
        (compressed as f64) <= 0.35 * bytes as f64,
        "sq8 compressed {compressed} vs f32 {bytes}"
    );
    assert_eq!(
        stats
            .get("quant")
            .and_then(|q| q.get("mode"))
            .and_then(|v| v.as_str()),
        Some("sq8")
    );
    cluster.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A stale or half-written plan directory (shard artifact disagreeing
/// with the manifest) must fail at launch with a typed error — never
/// reach a router worker that would panic on an out-of-range shard id.
#[test]
fn stale_plan_dir_rejected_at_launch() {
    let mut rng = Rng::new(76);
    let wl = synthetic::dense_workload(16, 120, 6, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 6, top_p: 2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let plan = ShardPlan::for_index(&index, 2, ShardStrategy::Contiguous).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "amsearch_cluster_e2e_{}_stale",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    cluster::write_cluster(&index, &plan, &dir).unwrap();
    // overwrite shard 0 with an artifact from a *different* build — the
    // "shard-plan rerun died between shard files and manifest" shape
    let mut rng2 = Rng::new(77);
    let wl2 = synthetic::dense_workload(16, 80, 6, QueryModel::Exact, &mut rng2);
    let other = AmIndex::build(
        wl2.base.clone(),
        IndexParams { n_classes: 4, top_p: 1, ..Default::default() },
        &mut rng2,
    )
    .unwrap();
    amsearch::index::persist::save(&other, &dir.join("shard-0.amidx")).unwrap();
    let err = ClusterHarness::launch_from_dir(
        &dir,
        "127.0.0.1:0",
        &fast_cluster_cfg(2, ShardStrategy::Contiguous),
    );
    let msg = match err {
        Ok(_) => panic!("stale plan directory must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(msg.contains("manifest"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful cluster drain: a SHUTDOWN frame through the router's front
/// door unblocks `join`, in-flight requests all resolve, and the
/// orderly teardown leaves every tier joined (no hangs, no drops).
#[test]
fn cluster_shutdown_drains_in_flight_requests() {
    let mut rng = Rng::new(75);
    let wl = synthetic::dense_workload(16, 128, 8, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 4, top_p: 2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let cluster = ClusterHarness::launch(
        &index,
        "127.0.0.1:0",
        &fast_cluster_cfg(2, ShardStrategy::Contiguous),
    )
    .unwrap();
    let addr = cluster.router_addr();

    let mut a = NetClient::connect(addr).unwrap();
    a.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let ids: Vec<u64> = (0..12)
        .map(|i| a.submit(wl.queries.get(i % 8), 4, 2).unwrap())
        .collect();
    for id in ids {
        a.wait(id).unwrap(); // every accepted request resolves
    }

    let mut b = NetClient::connect(addr).unwrap();
    b.set_timeout(Some(Duration::from_secs(30))).unwrap();
    b.shutdown_server().unwrap();
    cluster.join(); // returns once the front door drained
    let m = cluster.router().metrics();
    assert!(m.requests >= 12);
    assert_eq!(m.errors, 0);
    cluster.shutdown();
}

/// Router quality pin (acceptance): at full fan-out with per-shard
/// full poll the shadow's full-fanout re-execution is identical by
/// construction, so the online estimate must read exactly 1.0 — while
/// quality-sampled serving stays bitwise-identical to the plain index
/// answer for the same queries.
#[test]
fn router_quality_estimate_is_unity_at_full_fanout() {
    use amsearch::net::Serveable;
    use amsearch::util::Json;
    let mut rng = Rng::new(83);
    let wl = synthetic::dense_workload(24, 240, 12, QueryModel::Exact, &mut rng);
    let params =
        IndexParams { n_classes: 8, top_p: 8, top_k: 3, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let mut cfg = fast_cluster_cfg(3, ShardStrategy::BalancedMembers);
    cfg.router.quality_sample = 1; // shadow-verify every request
    let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(cluster.router().fan_out(), 3, "full fan-out");

    let mut ops = OpsCounter::new();
    let total = 12usize;
    for qi in 0..total {
        let query = wl.queries.get(qi);
        let expected = index.query_k(query, 8, 3, &mut ops);
        let routed = cluster.router().search(query.to_vec(), 8, 3).unwrap();
        assert_eq!(routed.neighbors.len(), expected.neighbors.len(), "qi={qi}");
        for (a, b) in routed.neighbors.iter().zip(&expected.neighbors) {
            assert_eq!(a.id, b.id, "qi={qi}");
            assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "qi={qi}");
        }
    }
    // the shadow worker runs off the hot path: poll STATS until it has
    // digested every sample (12 pushes never overflow the queue)
    let mut samples = 0u64;
    for _ in 0..1000 {
        let stats = Serveable::stats_json(&**cluster.router());
        samples = stats
            .get("quality")
            .and_then(|q| q.get("samples"))
            .and_then(|v| v.as_u64())
            .unwrap_or(0);
        if samples == total as u64 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(samples, total as u64, "every request was shadow-verified");
    let stats = Serveable::stats_json(&**cluster.router());
    let q = stats.get("quality").unwrap();
    assert_eq!(q.get("recall").unwrap().as_f64(), Some(1.0), "exactly 1.0");
    assert_eq!(q.get("exact_matches").unwrap().as_u64(), Some(total as u64));
    assert_eq!(q.get("dropped").unwrap().as_u64(), Some(0));
    // per-shard capture: at s = N every shard's share of the truth set
    // is in the served answer
    let Json::Arr(shards) = stats.get("shard_quality").unwrap() else {
        panic!("shard_quality not an array")
    };
    assert_eq!(shards.len(), 3);
    for sq in shards {
        assert_eq!(sq.get("capture_rate").unwrap().as_f64(), Some(1.0));
    }
    // the fan-out effectiveness histogram saw every sampled answer
    let fe = stats.get("fanout_effectiveness").unwrap();
    assert_eq!(fe.get("total").unwrap().as_u64(), Some(total as u64));
    // pinned Prometheus families ride the same snapshot
    let text = Serveable::metrics_registry(&**cluster.router()).render();
    assert!(text.contains("amsearch_quality_recall"), "{text}");
    assert!(text.contains("amsearch_quality_shard_capture_rate"), "{text}");
    cluster.shutdown();
}

/// Router quality pin at s = 1 on a clustered corpus: the online
/// estimate must fall below 1.0 and agree with the offline recall
/// measured against exhaustive ground truth — with full per-shard poll
/// and exact precision, the shadow's full-fanout truth *is* the
/// exhaustive answer, so the two measure the same quantity.
#[test]
fn router_quality_estimate_tracks_offline_recall_at_pruned_fanout() {
    use amsearch::net::Serveable;
    let mut rng = Rng::new(84);
    let spec =
        ClusteredSpec { dim: 32, n_clusters: 16, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, 768, 48, &mut rng);
    let params = IndexParams { n_classes: 16, top_p: 16, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let mut cfg = fast_cluster_cfg(4, ShardStrategy::RoundRobin);
    cfg.router.quality_sample = 1;
    cfg.router.fan_out = 1; // prune hard: top-ranked shard only
    let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(cluster.router().fan_out(), 1);

    let total = wl.ground_truth.len();
    let mut hits = 0usize;
    for (qi, &gt) in wl.ground_truth.iter().enumerate() {
        let resp = cluster
            .router()
            .search(wl.queries.get(qi).to_vec(), 16, 1)
            .unwrap();
        if resp.neighbor() == Some(gt) {
            hits += 1;
        }
    }
    let offline = hits as f64 / total as f64;
    assert!(offline < 1.0, "s = 1 on clustered data must lose recall");

    let mut samples = 0u64;
    for _ in 0..1000 {
        let stats = Serveable::stats_json(&**cluster.router());
        samples = stats
            .get("quality")
            .and_then(|q| q.get("samples"))
            .and_then(|v| v.as_u64())
            .unwrap_or(0);
        if samples == total as u64 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(samples, total as u64);
    let stats = Serveable::stats_json(&**cluster.router());
    let q = stats.get("quality").unwrap();
    let online = q.get("recall").unwrap().as_f64().unwrap();
    assert!(online < 1.0, "the estimate must see the fan-out loss");
    assert!(
        (online - offline).abs() < 0.05,
        "online {online} vs offline {offline}: same quantity, same queries"
    );
    cluster.shutdown();
}

/// The tracing acceptance pin, over the persisted production path: a
/// plan directory (what `shard-plan` writes) served by a traced cluster
/// (what `serve-cluster --trace-out` launches) and driven by the load
/// generator yields JSON-line trace records that stitch into
/// per-request trees — one router record plus one shard record per
/// contact under a single trace id, with span sums bounded by each
/// tier's end-to-end time and shard totals nested inside the router's.
#[test]
fn traced_cluster_stitches_router_and_shard_spans_under_one_id() {
    use amsearch::net::loadgen::{self, LoadGenConfig};
    use amsearch::obs::{stitch, TraceRecord, TraceSink};
    use amsearch::util::Json;

    let mut rng = Rng::new(81);
    let wl = synthetic::dense_workload(16, 180, 8, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 6, top_p: 2, top_k: 2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let plan = ShardPlan::for_index(&index, 3, ShardStrategy::Contiguous).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "amsearch_cluster_e2e_{}_trace",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    cluster::write_cluster(&index, &plan, &dir).unwrap();
    let trace_path = dir.join("trace.jsonl");

    // sample every request; slow-query threshold off
    let sink = TraceSink::to_file(&trace_path, 1, 0).unwrap();
    let mut cfg = fast_cluster_cfg(3, ShardStrategy::Contiguous);
    cfg.trace = Some(sink.clone());
    let cluster = ClusterHarness::launch_from_dir(&dir, "127.0.0.1:0", &cfg).unwrap();

    let queries: Vec<Vec<f32>> =
        (0..8).map(|qi| wl.queries.get(qi).to_vec()).collect();
    let load = LoadGenConfig {
        connections: 2,
        requests: 20,
        depth: 2,
        ..Default::default()
    };
    let report =
        loadgen::run(&cluster.router_addr().to_string(), &queries, &load).unwrap();
    assert_eq!(report.requests, 20);
    assert_eq!(report.errors, 0);
    // shutdown drains every worker, so all records are flushed
    cluster.shutdown();

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let records: Vec<TraceRecord> = text
        .lines()
        .map(|l| TraceRecord::from_json(&Json::parse(l).unwrap()).unwrap())
        .collect();
    // every request was sampled: 1 router + 3 shard records each
    assert_eq!(sink.emitted(), records.len() as u64);
    assert_eq!(records.len(), 20 * 4, "full fan-out traces every contact");
    for r in &records {
        assert!(r.trace_id > 0);
        assert!(
            r.spans_total_ns() <= r.total_ns,
            "span sums exceed end-to-end at {}: {r:?}",
            r.role
        );
    }
    let trees = stitch(&records);
    assert_eq!(trees.len(), 20, "one tree per request");
    for (tid, tree) in &trees {
        let routers: Vec<_> = tree.iter().filter(|r| r.role == "router").collect();
        let shards: Vec<_> = tree.iter().filter(|r| r.role == "search").collect();
        assert_eq!(routers.len(), 1, "trace {tid}");
        assert_eq!(shards.len(), 3, "trace {tid}");
        let router = routers[0];
        for stage in ["queue", "score", "scatter", "gather", "respond"] {
            assert!(router.span_ns(stage).is_some(), "trace {tid} missing {stage}");
        }
        for shard in &shards {
            for stage in ["queue", "batch", "score", "select", "scan", "respond"] {
                assert!(shard.span_ns(stage).is_some(), "trace {tid} missing {stage}");
            }
            // the shard's service interval is nested inside the
            // router's end-to-end interval (same monotonic clock)
            assert!(
                shard.total_ns <= router.total_ns,
                "trace {tid}: shard total {} > router total {}",
                shard.total_ns,
                router.total_ns
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Tracing must never change answers: the same plan directory served
/// with tracing disabled and with every request traced returns
/// bitwise-identical neighbors and distances.
#[test]
fn traced_and_untraced_clusters_answer_bitwise_identically() {
    use amsearch::obs::TraceSink;

    let mut rng = Rng::new(82);
    let wl = synthetic::dense_workload(16, 160, 10, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 5, top_p: 5, top_k: 3, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();

    let plain = ClusterHarness::launch(
        &index,
        "127.0.0.1:0",
        &fast_cluster_cfg(2, ShardStrategy::BalancedMembers),
    )
    .unwrap();
    let mut traced_cfg = fast_cluster_cfg(2, ShardStrategy::BalancedMembers);
    traced_cfg.trace =
        Some(TraceSink::new(Box::new(std::io::sink()), 1, 1));
    let traced = ClusterHarness::launch(&index, "127.0.0.1:0", &traced_cfg).unwrap();

    for qi in 0..10 {
        let query = wl.queries.get(qi);
        let a = plain.router().search(query.to_vec(), 5, 3).unwrap();
        let b = traced.router().search(query.to_vec(), 5, 3).unwrap();
        assert_eq!(a.neighbors.len(), b.neighbors.len(), "query {qi}");
        for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(x.id, y.id, "query {qi}");
            assert_eq!(x.distance.to_bits(), y.distance.to_bits(), "query {qi}");
        }
        assert_eq!(a.polled, b.polled, "query {qi}");
        assert_eq!(a.candidates, b.candidates, "query {qi}");
    }
    plain.shutdown();
    traced.shutdown();
}
