//! Cross-module integration tests: data generators -> allocation ->
//! memory bank -> index -> baselines, on realistic (small) workloads.

use amsearch::baseline::{Exhaustive, HybridIndex, RsAnchors};
use amsearch::data::clustered::{clustered_workload, exact_ground_truth, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel, SparseSpec};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::memory::StorageRule;
use amsearch::metrics::{CostModel, OpsCounter, Recall};
use amsearch::partition::Allocation;
use amsearch::search::Metric;

/// The paper's core promise, end to end: in the d << k << d² regime with
/// few classes, top-1 polling finds the exact stored pattern with low
/// error AND costs far less than exhaustive search.
#[test]
fn sparse_regime_accuracy_and_cost() {
    let mut rng = Rng::new(1);
    let d = 128;
    // k=256: d << k << d² with d²/(32k) = 2, q e^{-2} small for q=4
    let (k, q) = (256, 4);
    let wl = synthetic::sparse_workload(
        SparseSpec { dim: d, ones: 8.0 },
        k * q,
        200,
        QueryModel::Exact,
        &mut rng,
    );
    let params = IndexParams { n_classes: q, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    assert!(index.uses_sparse_scoring());

    let mut ops = OpsCounter::new();
    let mut recall = Recall::new();
    for (qi, &gt) in wl.ground_truth.iter().enumerate() {
        let r = index.query(wl.queries.get(qi), 1, &mut ops);
        recall.record(r.id() == gt);
    }
    assert!(recall.value() > 0.8, "recall={}", recall.value());

    // measured cost must sit within 2x of the closed-form c²q + kc model
    let c = 8u64;
    let model = CostModel { effective_dim: c, q: q as u64, k: k as u64, n: (k * q) as u64 };
    let per_search = ops.per_search();
    let predicted = (model.score_cost() + model.scan_cost(1)) as f64;
    assert!(
        per_search < 2.0 * predicted && per_search > 0.3 * predicted,
        "per_search={per_search} predicted={predicted}"
    );
    // and be well below exhaustive search
    assert!(ops.relative_to(model.exhaustive_cost()) < 1.0);
}

#[test]
fn dense_corrupted_queries_still_recoverable() {
    let mut rng = Rng::new(2);
    let d = 64;
    let (k, q) = (256, 6);
    let wl = synthetic::dense_workload(
        d,
        k * q,
        150,
        QueryModel::Corrupted { alpha: 0.8 },
        &mut rng,
    );
    let params =
        IndexParams { n_classes: q, metric: Metric::SqL2, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let mut ops = OpsCounter::new();
    let mut top1 = Recall::new();
    let mut top3 = Recall::new();
    for (qi, &gt) in wl.ground_truth.iter().enumerate() {
        let x = wl.queries.get(qi);
        let r1 = index.query(x, 1, &mut ops);
        // corrupted query: its exact NN is overwhelmingly the original
        top1.record(r1.id() == gt);
        let r3 = index.query(x, 3, &mut ops);
        top3.record(r3.id() == gt);
    }
    assert!(top3.value() >= top1.value());
    assert!(top1.value() > 0.5, "top1={}", top1.value());
    assert!(top3.value() > 0.8, "top3={}", top3.value());
}

/// Recall@1 must be monotonically non-decreasing in the poll depth p and
/// reach 1.0 at p = q for self-queries.
#[test]
fn recall_monotone_in_p_and_exact_at_full_poll() {
    let mut rng = Rng::new(3);
    let spec = ClusteredSpec { dim: 24, n_clusters: 6, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, 1200, 100, &mut rng);
    let q = 12;
    let params = IndexParams { n_classes: q, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let mut last = 0.0;
    for p in [1usize, 2, 4, 8, 12] {
        let mut ops = OpsCounter::new();
        let mut recall = Recall::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = index.query(wl.queries.get(qi), p, &mut ops);
            recall.record(r.id() == gt);
        }
        assert!(
            recall.value() >= last - 1e-9,
            "recall dropped at p={p}: {} < {last}",
            recall.value()
        );
        last = recall.value();
        if p == q {
            assert_eq!(recall.value(), 1.0, "full poll must find exact NN");
        }
    }
}

/// Greedy allocation beats random allocation on clustered data at equal
/// poll depth (the Figure-9 effect).
#[test]
fn greedy_beats_random_on_clustered_data() {
    let mut rng = Rng::new(4);
    let spec = ClusteredSpec {
        dim: 32,
        n_clusters: 8,
        center_scale: 3.0,
        noise_scale: 0.4,
        size_skew: 0.0,
        query_jitter: 0.3,
    };
    let wl = clustered_workload(spec, 1600, 150, &mut rng);
    let q = 8;
    let mut recalls = Vec::new();
    for alloc in [Allocation::Greedy, Allocation::Random] {
        let params = IndexParams {
            n_classes: q,
            allocation: alloc,
            greedy_cap_factor: Some(2.0),
            ..Default::default()
        };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let mut recall = Recall::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = index.query(wl.queries.get(qi), 1, &mut ops);
            recall.record(r.id() == gt);
        }
        recalls.push(recall.value());
    }
    assert!(
        recalls[0] > recalls[1] + 0.1,
        "greedy={} random={}",
        recalls[0],
        recalls[1]
    );
}

/// The three search methods agree with brute force when configured for
/// exact search.
#[test]
fn all_methods_exact_when_fully_polled() {
    let mut rng = Rng::new(5);
    let spec = ClusteredSpec { dim: 16, n_clusters: 4, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, 400, 50, &mut rng);
    let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);

    let params = IndexParams { n_classes: 4, ..Default::default() };
    let am = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let rs = RsAnchors::build(wl.base.clone(), 10, Metric::SqL2, &mut rng).unwrap();
    let hy = HybridIndex::build(wl.base.clone(), params, 100.0, 1000, &mut rng).unwrap();

    let mut ops = OpsCounter::new();
    for qi in 0..wl.queries.len() {
        let x = wl.queries.get(qi);
        let (want, _) = ex.query(x, &mut ops);
        assert_eq!(am.query(x, 4, &mut ops).id(), want, "am, query {qi}");
        assert_eq!(rs.query(x, 10, &mut ops).0, want, "rs, query {qi}");
        assert_eq!(hy.query(x, 4, &mut ops).0, want, "hybrid, query {qi}");
    }
}

/// All k-NN paths agree with the exhaustive top-k when configured for
/// exact search: the AM index at p = q, the hierarchical cascade at a
/// full cascade poll, IVF at full probe, and the hybrid with covering
/// anchors all report the identical neighbor list.
#[test]
fn all_methods_topk_agree_when_fully_polled() {
    use amsearch::baseline::IvfFlat;
    use amsearch::index::HierarchicalIndex;
    let mut rng = Rng::new(9);
    let spec = ClusteredSpec { dim: 16, n_clusters: 4, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, 400, 30, &mut rng);
    let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);

    let params = IndexParams { n_classes: 4, ..Default::default() };
    let am = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let h = HierarchicalIndex::build(wl.base.clone(), params, 2, &mut rng).unwrap();
    let ivf = IvfFlat::build(wl.base.clone(), 6, 15, Metric::SqL2, &mut rng).unwrap();
    let hy = HybridIndex::build(wl.base.clone(), params, 100.0, 1000, &mut rng).unwrap();

    let k = 10;
    let mut ops = OpsCounter::new();
    for qi in 0..wl.queries.len() {
        let x = wl.queries.get(qi);
        let want = ex.query_k(x, k, &mut ops);
        assert_eq!(am.query_k(x, 4, k, &mut ops).neighbors, want, "am, query {qi}");
        assert_eq!(
            h.query_k(x, 2, 4, k, &mut ops).neighbors,
            want,
            "hierarchical, query {qi}"
        );
        assert_eq!(ivf.query_k(x, 6, k, &mut ops).0, want, "ivf, query {qi}");
        assert_eq!(hy.query_k(x, 4, k, &mut ops), want, "hybrid, query {qi}");
    }
}

/// Max-rule (cooccurrence) banks work end-to-end and perform comparably
/// to sum-rule on sparse data (the paper's §5.1.1 observation).
#[test]
fn max_rule_comparable_on_sparse() {
    let mut rng = Rng::new(6);
    let wl = synthetic::sparse_workload(
        SparseSpec { dim: 128, ones: 8.0 },
        2048,
        150,
        QueryModel::Exact,
        &mut rng,
    );
    let mut values = Vec::new();
    for rule in [StorageRule::Sum, StorageRule::Max] {
        let params = IndexParams { n_classes: 8, rule, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let mut recall = Recall::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = index.query(wl.queries.get(qi), 1, &mut ops);
            recall.record(r.id() == gt);
        }
        values.push(recall.value());
    }
    // the paper reports the max rule gives "small improvements in every
    // case": it must not be worse, and both must be in the same ballpark
    assert!(
        values[1] >= values[0] - 0.05,
        "max rule regressed: sum={} max={}",
        values[0],
        values[1]
    );
    assert!(values[0] > 0.5 && values[1] > 0.5, "both rules must work");
}

/// fvecs round-trip through the real file format feeding a real index.
#[test]
fn fvecs_files_feed_the_index() {
    let mut rng = Rng::new(7);
    let wl = clustered_workload(
        ClusteredSpec { dim: 16, n_clusters: 3, ..ClusteredSpec::sift_like() },
        300,
        20,
        &mut rng,
    );
    let dir = std::env::temp_dir().join(format!("amsearch_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    amsearch::data::io::write_fvecs(&dir.join("base.fvecs"), &wl.base).unwrap();
    let base = amsearch::data::io::read_fvecs(&dir.join("base.fvecs")).unwrap();
    assert_eq!(base, wl.base);
    let gt = exact_ground_truth(&base, &wl.queries);
    assert_eq!(gt, wl.ground_truth);
    std::fs::remove_dir_all(&dir).ok();
}

/// Unequal class sizes (greedy, capped) still produce correct scans and
/// sane ops accounting.
#[test]
fn unequal_classes_accounting() {
    let mut rng = Rng::new(8);
    let wl = synthetic::dense_workload(32, 500, 40, QueryModel::Exact, &mut rng);
    let params = IndexParams {
        n_classes: 7,
        allocation: Allocation::Greedy,
        greedy_cap_factor: Some(3.0),
        ..Default::default()
    };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    index.partition().validate().unwrap();
    let mut ops = OpsCounter::new();
    for qi in 0..wl.queries.len() {
        let r = index.query(wl.queries.get(qi), 2, &mut ops);
        // candidates = sum of the two polled classes' true sizes
        let want: usize = r
            .polled
            .iter()
            .map(|&c| index.partition().members(c as usize).len())
            .sum();
        assert_eq!(r.candidates, want);
    }
    assert_eq!(ops.searches, 40);
}
