//! Coordinator end-to-end tests: concurrent clients through the full
//! batcher -> worker -> response pipeline, native and (when artifacts
//! exist) PJRT backends.

use std::path::PathBuf;
use std::sync::Arc;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::data::Workload;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::runtime::Backend;

fn build_index(seed: u64, d: usize, n: usize, q: usize) -> (Arc<AmIndex>, Workload) {
    let mut rng = Rng::new(seed);
    let wl = synthetic::dense_workload(d, n, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: q, top_p: 2, ..Default::default() };
    let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    (Arc::new(idx), wl)
}

fn native_factory(index: Arc<AmIndex>) -> EngineFactory {
    EngineFactory { index, backend: Backend::Native, artifacts_dir: None }
}

#[test]
fn serves_concurrent_clients_correctly() {
    let (index, wl) = build_index(1, 32, 512, 8);
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 300,
        workers: 3,
        queue_depth: 64,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(native_factory(index), config).unwrap());

    let n_clients = 8;
    let per_client = 32;
    let hits: Vec<usize> = amsearch::util::concurrent_map(n_clients, n_clients, |ci| {
        let mut hits = 0;
        for j in 0..per_client {
            let qi = (ci * per_client + j) % wl.queries.len();
            // p = q (full poll): response must be the exact stored copy
            let resp = server.search(wl.queries.get(qi).to_vec(), 8, 1).unwrap();
            if resp.neighbor() == Some(wl.ground_truth[qi]) {
                hits += 1;
            } else {
                eprintln!("MISS ci={ci} j={j} qi={qi} got={:?} want={} dist={} id={} polled={:?}",
                    resp.neighbor(), wl.ground_truth[qi], resp.distance(), resp.id, resp.polled);
            }
            assert_eq!(resp.distance(), 0.0);
            assert_eq!(resp.polled.len(), 8);
        }
        hits
    });
    let total_hits: usize = hits.iter().sum();
    assert_eq!(total_hits, n_clients * per_client, "full poll must be exact");

    let m = server.metrics();
    assert_eq!(m.requests, (n_clients * per_client) as u64);
    assert!(m.batches <= m.requests);
    assert!(m.mean_batch_size() >= 1.0);
    assert!(m.latency.count() == m.requests);
    server.shutdown();
}

/// The serving acceptance check for the quantized subsystem: a server
/// over an SQ8 index answers exactly like one over the exact index
/// (rerank = 0) and its STATS report `index.compressed_bytes` at
/// ≤ 0.35× the f32 member-matrix bytes plus `quant.mode = "sq8"`.
#[test]
fn quantized_server_matches_exact_and_reports_footprint() {
    use amsearch::quant::ScanPrecision;
    let mut rng = Rng::new(17);
    let wl = synthetic::dense_workload(32, 512, 64, QueryModel::Exact, &mut rng);
    let build = |precision| {
        Arc::new(
            AmIndex::build(
                wl.base.clone(),
                IndexParams { n_classes: 8, top_p: 2, precision, ..Default::default() },
                &mut Rng::new(18),
            )
            .unwrap(),
        )
    };
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 300,
        workers: 2,
        queue_depth: 64,
        quality_sample: 0,
    };
    let exact =
        SearchServer::start(native_factory(build(ScanPrecision::Exact)), config).unwrap();
    let quant = SearchServer::start(
        native_factory(build(ScanPrecision::Sq8 { rerank: 0 })),
        config,
    )
    .unwrap();
    for qi in 0..32 {
        let x = wl.queries.get(qi).to_vec();
        let a = exact.search(x.clone(), 3, 5).unwrap();
        let b = quant.search(x, 3, 5).unwrap();
        assert_eq!(a.neighbors.len(), b.neighbors.len(), "query {qi}");
        for (na, nb) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(na.id, nb.id, "query {qi}");
            assert_eq!(na.distance.to_bits(), nb.distance.to_bits(), "query {qi}");
        }
    }
    let stats = quant.stats_json();
    let index_obj = stats.get("index").expect("stats carry index.*");
    let bytes = index_obj.get("bytes").and_then(|v| v.as_u64()).unwrap();
    let compressed = index_obj
        .get("compressed_bytes")
        .and_then(|v| v.as_u64())
        .unwrap();
    assert_eq!(bytes, (512 * 32 * 4) as u64);
    assert!(
        (compressed as f64) <= 0.35 * bytes as f64,
        "sq8 compressed {compressed} vs f32 {bytes}"
    );
    assert_eq!(
        stats
            .get("quant")
            .and_then(|v| v.get("mode"))
            .and_then(|v| v.as_str()),
        Some("sq8")
    );
    // the exact server reports no compression and an exact mode
    let estats = exact.stats_json();
    assert_eq!(
        estats
            .get("index")
            .and_then(|v| v.get("compression_ratio"))
            .and_then(|v| v.as_f64()),
        Some(1.0)
    );
    assert_eq!(
        estats
            .get("quant")
            .and_then(|v| v.get("mode"))
            .and_then(|v| v.as_str()),
        Some("exact")
    );
    quant.shutdown();
    exact.shutdown();
}

#[test]
fn batching_actually_groups_requests() {
    let (index, wl) = build_index(2, 32, 256, 4);
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 5_000, // generous window so the batch fills
        workers: 1,
        queue_depth: 256,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(native_factory(index), config).unwrap());
    let total = 64;
    amsearch::util::concurrent_map(total, 16, |i| {
        let qi = i % wl.queries.len();
        server.search(wl.queries.get(qi).to_vec(), 1, 1).unwrap()
    });
    let m = server.metrics();
    assert_eq!(m.requests, total as u64);
    assert!(
        m.mean_batch_size() > 1.5,
        "expected batching under concurrent load, got {:.2}",
        m.mean_batch_size()
    );
    server.shutdown();
}

#[test]
fn rejects_wrong_dimension() {
    let (index, _) = build_index(3, 32, 128, 4);
    let server =
        SearchServer::start(native_factory(index), CoordinatorConfig::default()).unwrap();
    let err = server.search(vec![0.0; 31], 1, 1).unwrap_err();
    assert!(err.to_string().contains("dim"));
    server.shutdown();
}

#[test]
fn top_k_boundary_validation_default_and_clamp() {
    // n = 128; the server boundary must (a) substitute the index default
    // at top_k = 0, (b) clamp top_k > n to n, (c) return sorted
    // neighbors for any accepted k
    let (index, wl) = build_index(8, 32, 128, 4);
    let server =
        SearchServer::start(native_factory(index), CoordinatorConfig::default()).unwrap();
    // (a) top_k = 0 -> index default (top_k = 1)
    let resp = server.search(wl.queries.get(0).to_vec(), 4, 0).unwrap();
    assert_eq!(resp.neighbors.len(), 1);
    // (b) top_k far beyond n -> clamped to n, full poll returns all 128
    let resp = server.search(wl.queries.get(0).to_vec(), 4, 1_000_000).unwrap();
    assert_eq!(resp.neighbors.len(), 128);
    // (c) a mid-range k comes back sorted ascending by (distance, id)
    let resp = server.search(wl.queries.get(1).to_vec(), 4, 9).unwrap();
    assert_eq!(resp.neighbors.len(), 9);
    assert_eq!(resp.neighbor(), Some(wl.ground_truth[1]));
    for w in resp.neighbors.windows(2) {
        assert!(
            w[0].distance < w[1].distance
                || (w[0].distance == w[1].distance && w[0].id < w[1].id),
            "response neighbors not (distance, id)-ascending"
        );
    }
    server.shutdown();
}

#[test]
fn zero_top_p_uses_index_default() {
    let (index, wl) = build_index(4, 32, 128, 4);
    let server =
        SearchServer::start(native_factory(index), CoordinatorConfig::default()).unwrap();
    let resp = server.search(wl.queries.get(0).to_vec(), 0, 0).unwrap();
    assert_eq!(resp.polled.len(), 2); // index default top_p = 2
    assert_eq!(resp.neighbors.len(), 1); // index default top_k = 1
    server.shutdown();
}

#[test]
fn no_candidates_surfaces_as_none_through_the_server() {
    // classes 0 and 1 are empty; the probe ties every class score at 0
    // so top-2 polls exactly the two empty classes -> the server must
    // deliver a proper "no candidates" response (the old protocol leaked
    // neighbor = u32::MAX, distance = inf)
    let index = amsearch::index::am_index::two_empty_classes_fixture();
    let server =
        SearchServer::start(native_factory(Arc::new(index)), CoordinatorConfig::default())
            .unwrap();
    let resp = server.search(vec![0., 0., 1.], 2, 1).unwrap();
    assert!(resp.neighbors.is_empty());
    assert_eq!(resp.neighbor(), None);
    assert_eq!(resp.candidates, 0);
    assert!(resp.distance().is_infinite());
    // the empty-neighbors protocol holds at k > 1 too
    let resp = server.search(vec![0., 0., 1.], 2, 3).unwrap();
    assert!(resp.neighbors.is_empty());
    // a full poll still reaches the stored vectors
    let resp = server.search(vec![0., 0., 1.], 4, 1).unwrap();
    assert_eq!(resp.neighbor(), Some(0));
    server.shutdown();
}

#[test]
fn shutdown_then_search_fails_cleanly() {
    let (index, wl) = build_index(5, 32, 128, 4);
    let server =
        SearchServer::start(native_factory(index), CoordinatorConfig::default()).unwrap();
    server.shutdown();
    assert!(server.search(wl.queries.get(0).to_vec(), 1, 1).is_err());
}

#[test]
fn dead_worker_pool_errors_instead_of_hanging() {
    // the PJRT backend with no artifacts makes every worker's engine
    // build fail: the whole pool exits, the batch channel disconnects,
    // and the batcher must answer every request with an explicit error
    // response (the shutdown-audit guarantee: a request that cannot be
    // served is *failed*, never silently dropped — a silent drop would
    // hang a TCP client waiting on a shared response funnel)
    let (index, wl) = build_index(9, 32, 128, 4);
    let factory = EngineFactory {
        index,
        backend: Backend::Pjrt,
        artifacts_dir: Some(PathBuf::from("/nonexistent/artifacts")),
    };
    let server = SearchServer::start(factory, CoordinatorConfig::default()).unwrap();
    // give the workers a moment to fail and exit
    std::thread::sleep(std::time::Duration::from_millis(50));
    for qi in 0..4 {
        let err = server.search(wl.queries.get(qi).to_vec(), 1, 1).unwrap_err();
        // the first batch can race the workers' exit ("worker dropped
        // request"); once the batcher observes the dead pool, every
        // later request gets the explicit error response
        assert!(
            err.to_string().contains("worker pool unavailable")
                || err.to_string().contains("worker dropped request")
                || err.to_string().contains("shutting down"),
            "unexpected error: {err}"
        );
    }
    // by now the batcher is in its fail-drain loop: the explicit error
    // delivery (not a dropped channel) is pinned here
    let err = server.search(wl.queries.get(0).to_vec(), 1, 1).unwrap_err();
    assert!(
        err.to_string().contains("worker pool unavailable"),
        "expected explicit failure response, got: {err}"
    );
    server.shutdown();
}

#[test]
fn searches_racing_shutdown_always_get_a_response() {
    // requests queued (but maybe not yet batched) when shutdown() drops
    // the producer side must each resolve — served or error — and the
    // join must complete: no client thread may hang
    let (index, wl) = build_index(10, 32, 256, 4);
    let config = CoordinatorConfig {
        max_batch: 4,
        max_wait_us: 2_000,
        workers: 2,
        queue_depth: 64,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(native_factory(index), config).unwrap());
    let outcomes = {
        let server = server.clone();
        let wl = &wl;
        std::thread::scope(|scope| {
            let mut clients = Vec::new();
            for ci in 0..8usize {
                let server = server.clone();
                clients.push(scope.spawn(move || {
                    let mut ok = 0usize;
                    let mut failed = 0usize;
                    for j in 0..64usize {
                        let qi = (ci * 64 + j) % wl.queries.len();
                        match server.search(wl.queries.get(qi).to_vec(), 1, 1) {
                            Ok(_) => ok += 1,
                            Err(_) => failed += 1,
                        }
                    }
                    (ok, failed)
                }));
            }
            // shut down while the clients are mid-flight
            std::thread::sleep(std::time::Duration::from_millis(5));
            server.shutdown();
            clients.into_iter().map(|c| c.join().unwrap()).collect::<Vec<_>>()
        })
    };
    // every single request resolved one way or the other
    let total: usize = outcomes.iter().map(|(ok, failed)| ok + failed).sum();
    assert_eq!(total, 8 * 64, "a request neither completed nor failed");
}

#[test]
fn ops_accounting_flows_to_metrics() {
    let (index, wl) = build_index(6, 32, 256, 4);
    let server =
        SearchServer::start(native_factory(index), CoordinatorConfig::default()).unwrap();
    for qi in 0..10 {
        server.search(wl.queries.get(qi).to_vec(), 1, 1).unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.ops.searches, 10);
    assert!(m.ops.per_search() > 0.0);
    server.shutdown();
}

#[test]
fn pjrt_backend_serves_if_artifacts_present() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    // must match an AOT config: d=128, q=64
    let (index, wl) = build_index(7, 128, 2048, 64);
    let factory = EngineFactory {
        index,
        backend: Backend::Pjrt,
        artifacts_dir: Some(dir),
    };
    let config = CoordinatorConfig {
        max_batch: 8,
        max_wait_us: 500,
        workers: 2,
        queue_depth: 64,
        quality_sample: 0,
    };
    let server = Arc::new(SearchServer::start(factory, config).unwrap());
    let hits: Vec<bool> = amsearch::util::concurrent_map(24, 8, |i| {
        let qi = i % wl.queries.len();
        let resp = server.search(wl.queries.get(qi).to_vec(), 64, 1).unwrap();
        resp.neighbor() == Some(wl.ground_truth[qi])
    });
    assert!(hits.iter().all(|&h| h), "full poll through PJRT must be exact");
    server.shutdown();
}
