//! Property-based tests over randomized inputs (the offline build has no
//! proptest crate; `cases!` runs a property over many seeded random
//! configurations and reports the failing seed for reproduction).

use amsearch::data::dataset::Dataset;
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel, SparseSpec};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::memory::{score, MemoryBank, OuterProductMemory, StorageRule};
use amsearch::metrics::{CostModel, OpsCounter};
use amsearch::partition::{greedy_alloc, random_alloc, roundrobin};
use amsearch::search::{top_p_largest, TopK};

/// Run `prop` for `n` seeded cases; panic with the seed on failure.
fn cases(n: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xBEEF_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Any partition produced by any allocator is an exact cover with the
/// right class count.
#[test]
fn prop_partitions_are_exact_covers() {
    cases(40, |rng| {
        let n = 10 + rng.below(400) as usize;
        let q = 1 + rng.below(n as u64 / 2) as usize;
        let p1 = random_alloc::allocate(n, q, rng).unwrap();
        p1.validate().unwrap();
        assert_eq!(p1.n_vectors(), n);
        let p2 = roundrobin::allocate(n, q).unwrap();
        p2.validate().unwrap();
        // random equal-size: all classes within 1 of n/q except the last
        let k = n / q;
        for (i, s) in p1.sizes().iter().enumerate() {
            if i + 1 < q {
                assert_eq!(*s, k);
            }
        }
    });
}

/// Greedy allocation is a cover and respects its cap for all shapes.
#[test]
fn prop_greedy_allocation_cover_and_cap() {
    cases(15, |rng| {
        let n = 20 + rng.below(150) as usize;
        let q = 2 + rng.below(6) as usize;
        let d = 8 + rng.below(24) as usize;
        let ds = synthetic::dense_patterns(d, n, rng);
        let cap = n.div_ceil(q) + rng.below(10) as usize + 1;
        let p = greedy_alloc::allocate(
            &ds,
            q,
            greedy_alloc::GreedyOptions { max_size: Some(cap) },
            rng,
        )
        .unwrap();
        p.validate().unwrap();
        assert!(p.sizes().iter().all(|&s| s <= cap));
    });
}

/// The memory score identity: x^T (Σ x_μ x_μ^T) x == Σ ⟨x, x_μ⟩², for
/// arbitrary real-valued patterns.
#[test]
fn prop_memory_score_identity() {
    cases(40, |rng| {
        let d = 4 + rng.below(40) as usize;
        let k = 1 + rng.below(20) as usize;
        let mut mem = OuterProductMemory::new(d);
        let patterns: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        for p in &patterns {
            mem.add(p);
        }
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let direct: f64 = patterns
            .iter()
            .map(|p| {
                let dot: f64 =
                    p.iter().zip(&x).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
                dot * dot
            })
            .sum();
        let via_mem = mem.score(&x) as f64;
        let scale = direct.abs().max(1.0);
        assert!(
            (via_mem - direct).abs() / scale < 1e-3,
            "d={d} k={k}: mem={via_mem} direct={direct}"
        );
    });
}

/// The batched native scorer agrees with the scalar bank scorer on
/// arbitrary shapes (the same property the PJRT path is tested against).
#[test]
fn prop_batch_scorer_matches_scalar() {
    use amsearch::search::Kernels;
    cases(25, |rng| {
        let d = 3 + rng.below(40) as usize;
        let q = 1 + rng.below(10) as usize;
        let k = 1 + rng.below(8) as usize;
        let b = 1 + rng.below(6) as usize;
        let classes: Vec<Vec<f32>> = (0..q)
            .map(|_| (0..k * d).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = classes.iter().map(|c| c.as_slice()).collect();
        let bank = MemoryBank::build(d, &refs, StorageRule::Sum).unwrap();
        let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let batch =
            score::score_batch(bank.stacked(), &queries, d, q, Kernels::select());
        for bi in 0..b {
            let single = bank.score_query(&queries[bi * d..(bi + 1) * d]);
            for ci in 0..q {
                let (a, z) = (batch[bi * q + ci], single[ci]);
                assert!(
                    (a - z).abs() / z.abs().max(1.0) < 1e-3,
                    "bi={bi} ci={ci}: batch={a} single={z}"
                );
            }
        }
    });
}

/// TopK equals the prefix of a full sort for random inputs (with ties).
#[test]
fn prop_topk_equals_sort_prefix() {
    cases(60, |rng| {
        let n = 1 + rng.below(300) as usize;
        let k = 1 + rng.below(30) as usize;
        // coarse values force ties
        let vals: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
        let mut t = TopK::new(k);
        for (i, &v) in vals.iter().enumerate() {
            t.push(v, i as u32);
        }
        let got: Vec<f32> = t.into_sorted().iter().map(|x| x.0).collect();
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = sorted.into_iter().take(k).collect();
        assert_eq!(got, want);
    });
}

/// top_p_largest returns indices sorted by strictly non-increasing value.
#[test]
fn prop_top_p_ordering() {
    cases(60, |rng| {
        let n = 1 + rng.below(100) as usize;
        let p = 1 + rng.below(20) as usize;
        let vals: Vec<f32> = (0..n).map(|_| (rng.uniform() * 10.0) as f32).collect();
        let got = top_p_largest(&vals, p);
        assert_eq!(got.len(), p.min(n));
        for w in got.windows(2) {
            assert!(vals[w[0] as usize] >= vals[w[1] as usize]);
        }
        // every omitted value <= every kept value
        if let Some(&last) = got.last() {
            let kept: std::collections::HashSet<u32> = got.iter().cloned().collect();
            for (i, &v) in vals.iter().enumerate() {
                if !kept.contains(&(i as u32)) {
                    assert!(v <= vals[last as usize] + 1e-6);
                }
            }
        }
    });
}

/// Measured ops equal the closed-form cost model exactly for equal-sized
/// random partitions and dense data.
#[test]
fn prop_ops_match_cost_model() {
    cases(12, |rng| {
        let d = 8 + 4 * rng.below(10) as usize;
        let q = 2 + rng.below(6) as usize;
        let k = 8 + rng.below(24) as usize;
        let n = q * k;
        let wl = synthetic::dense_workload(d, n, 3, QueryModel::Exact, rng);
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, rng).unwrap();
        let p = 1 + rng.below(q as u64) as usize;
        let mut ops = OpsCounter::new();
        index.query(wl.queries.get(0), p, &mut ops);
        let model = CostModel {
            effective_dim: d as u64,
            q: q as u64,
            k: k as u64,
            n: n as u64,
        };
        assert_eq!(ops.score_ops, model.score_cost());
        assert_eq!(ops.scan_ops, model.scan_cost(p as u64));
    });
}

/// `finish_batch` over a batch of B queries is bitwise-identical to B
/// independent `finish_query` calls — results (neighbor ids, distances,
/// polled order, candidate counts) AND per-query op accounting — across
/// dense ±1 and sparse 0-1 workloads, random poll depths including
/// p = q, random neighbor counts including k = 1, k ≥ class size and
/// k > n, and partitions that may contain empty classes (greedy with a
/// tight cap).
#[test]
fn prop_finish_batch_matches_sequential() {
    use amsearch::partition::Allocation;
    cases(25, |rng| {
        let dense = rng.bernoulli(0.5);
        let d = 8 + rng.below(40) as usize;
        let q = 1 + rng.below(8) as usize;
        let n = q + rng.below(150) as usize;
        let wl = if dense {
            synthetic::dense_workload(d, n, 8, QueryModel::Exact, rng)
        } else {
            synthetic::sparse_workload(
                SparseSpec { dim: d, ones: 4.0 },
                n,
                8,
                QueryModel::Exact,
                rng,
            )
        };
        // greedy with a tight cap produces unequal class sizes — the
        // batch path must agree there too (fully empty classes are
        // covered by `finish_batch_handles_empty_classes_and_empty_polls`)
        let allocation =
            if rng.bernoulli(0.3) { Allocation::Greedy } else { Allocation::Random };
        let params = IndexParams {
            n_classes: q,
            allocation,
            greedy_cap_factor: if allocation == Allocation::Greedy {
                Some(1.0 + rng.uniform())
            } else {
                None
            },
            ..Default::default()
        };
        let index = AmIndex::build(wl.base.clone(), params, rng).unwrap();
        let b = 1 + rng.below(6) as usize;
        let queries: Vec<&[f32]> =
            (0..b).map(|i| wl.queries.get(i % wl.queries.len())).collect();
        let mut ps: Vec<usize> =
            (0..b).map(|_| 1 + rng.below(q as u64) as usize).collect();
        ps[b - 1] = q; // always exercise the p = q edge
        // random k per query, spanning k = 1 up to past the database
        // size; the first query always exercises k = 1 (the legacy 1-NN
        // pipeline) and, when the batch is big enough, the last two pin
        // k ≥ class size and k > n
        let mut ks: Vec<usize> =
            (0..b).map(|_| 1 + rng.below((n + 4) as u64) as usize).collect();
        ks[0] = 1;
        if b >= 3 {
            ks[b - 2] = n.div_ceil(q) + 1; // ≥ every class size
            ks[b - 1] = n + 3; // > n: returns everything scanned
        }

        // the same per-query scores feed both paths (the scan-stage
        // equivalence is what this property pins down)
        let mut flat_scores = Vec::with_capacity(b * q);
        let mut seq_results = Vec::new();
        let mut seq_ops = Vec::new();
        for (bi, x) in queries.iter().enumerate() {
            let mut throwaway = OpsCounter::new();
            let scores = index.score_classes(x, &mut throwaway);
            let mut o = OpsCounter::new();
            seq_results.push(index.finish_query(x, &scores, ps[bi], ks[bi], &mut o));
            seq_ops.push(o);
            flat_scores.extend_from_slice(&scores);
        }
        let mut batch_ops = vec![OpsCounter::new(); b];
        let batch_results =
            index.finish_batch(&queries, &flat_scores, &ps, &ks, &mut batch_ops);
        assert_eq!(batch_results, seq_results, "results diverged");
        assert_eq!(batch_ops, seq_ops, "op accounting diverged");
        for (bi, (a, s)) in batch_results.iter().zip(&seq_results).enumerate() {
            // f32 equality above is not approximate: require bit equality
            // of every reported distance too
            assert_eq!(a.neighbors.len(), s.neighbors.len(), "query {bi}");
            for (an, sn) in a.neighbors.iter().zip(&s.neighbors) {
                assert_eq!(an.id, sn.id, "query {bi}");
                assert_eq!(
                    an.distance.to_bits(),
                    sn.distance.to_bits(),
                    "query {bi}"
                );
            }
            // never more neighbors than requested or than scanned
            assert!(a.neighbors.len() <= ks[bi].min(a.candidates), "query {bi}");
        }
    });
}

/// The two-stage compressed scan with `rerank = 0` (every scanned
/// candidate survives to the exact stage) is bitwise-identical to
/// `ScanPrecision::Exact` — neighbor ids, `to_bits()` distances, polled
/// order, and candidate counts — across dense ±1 and sparse 0-1
/// workloads, both quantizers (SQ8 and PQ at random shapes), random
/// poll depths including p = q, and random k including k = 1 and k > n.
#[test]
fn prop_quant_rerank_full_matches_exact() {
    use amsearch::quant::ScanPrecision;
    cases(12, |rng| {
        let dense = rng.bernoulli(0.5);
        // d = m · sub_dim so PQ always divides the dimension
        let m = 1 + rng.below(4) as usize;
        let sub_dim = 2 + rng.below(8) as usize;
        let d = m * sub_dim;
        let q = 1 + rng.below(6) as usize;
        let n = q + rng.below(120) as usize;
        let wl = if dense {
            synthetic::dense_workload(d, n, 5, QueryModel::Exact, rng)
        } else {
            synthetic::sparse_workload(
                SparseSpec { dim: d, ones: 3.0 },
                n,
                5,
                QueryModel::Exact,
                rng,
            )
        };
        let bits = 1 + rng.below(8) as usize;
        let build_seed = 0xF17E_0000 + rng.below(1 << 20);
        let build = |precision: ScanPrecision| {
            // same build rng per precision -> identical partitions, so
            // the scan stage is the only thing that differs
            AmIndex::build(
                wl.base.clone(),
                IndexParams { n_classes: q, precision, ..Default::default() },
                &mut Rng::new(build_seed),
            )
            .unwrap()
        };
        let exact = build(ScanPrecision::Exact);
        let quantized = [
            build(ScanPrecision::Sq8 { rerank: 0 }),
            build(ScanPrecision::Pq { m, bits, rerank: 0 }),
        ];
        let mut ops = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            let x = wl.queries.get(qi);
            let p = 1 + rng.below(q as u64) as usize;
            let k = 1 + rng.below((n + 3) as u64) as usize;
            let want = exact.query_k(x, p, k, &mut ops);
            for (which, idx) in quantized.iter().enumerate() {
                let got = idx.query_k(x, p, k, &mut ops);
                let tag = ["sq8", "pq"][which];
                assert_eq!(got.polled, want.polled, "{tag} q{qi} p{p} k{k}");
                assert_eq!(got.candidates, want.candidates, "{tag} q{qi}");
                assert_eq!(
                    got.neighbors.len(),
                    want.neighbors.len(),
                    "{tag} q{qi} p{p} k{k} (d={d} m={m} bits={bits} n={n})"
                );
                for (g, w) in got.neighbors.iter().zip(&want.neighbors) {
                    assert_eq!(g.id, w.id, "{tag} q{qi} p{p} k{k}");
                    assert_eq!(
                        g.distance.to_bits(),
                        w.distance.to_bits(),
                        "{tag} q{qi} p{p} k{k}"
                    );
                }
            }
        }
    });
}

/// Recall@k of the compressed scan is monotone non-decreasing in the
/// rerank budget on the clustered workload: survivor sets are nested in
/// `r`, and a true neighbor that survives can never be evicted by
/// growing the candidate pool (at most k−1 polled candidates beat it).
#[test]
fn prop_quant_recall_monotone_in_rerank() {
    use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
    use amsearch::metrics::RecallAtK;
    use amsearch::quant::ScanPrecision;
    cases(6, |rng| {
        let spec = ClusteredSpec { dim: 16, n_clusters: 8, ..ClusteredSpec::sift_like() };
        let n = 300 + rng.below(200) as usize;
        let wl = clustered_workload(spec, n, 24, rng);
        let k = 1 + rng.below(8) as usize;
        let p = 1 + rng.below(8) as usize;
        let params = IndexParams {
            n_classes: 8,
            precision: ScanPrecision::Sq8 { rerank: 1 },
            ..Default::default()
        };
        let mut index = AmIndex::build(wl.base.clone(), params, rng).unwrap();
        // ground truth: the exact scan at the same poll depth (rerank=0)
        index.set_scan_rerank(0);
        let mut ops = OpsCounter::new();
        let truth: Vec<Vec<u32>> = (0..wl.queries.len())
            .map(|qi| {
                index
                    .query_k(wl.queries.get(qi), p, k, &mut ops)
                    .neighbors
                    .into_iter()
                    .map(|nb| nb.id)
                    .collect()
            })
            .collect();
        let mut last = -1.0f64;
        for r in [1usize, 4, 16, 64, 0] {
            index.set_scan_rerank(r);
            let mut recall = RecallAtK::new(k);
            for qi in 0..wl.queries.len() {
                let got: Vec<u32> = index
                    .query_k(wl.queries.get(qi), p, k, &mut ops)
                    .neighbors
                    .into_iter()
                    .map(|nb| nb.id)
                    .collect();
                recall.record(&got, &truth[qi]);
            }
            assert!(
                recall.value() >= last - 1e-12,
                "recall dropped from {last} to {} at r={r} (k={k} p={p})",
                recall.value()
            );
            last = recall.value();
        }
        // rerank-everything is the exact scan: recall vs it must be 1
        assert!((last - 1.0).abs() < 1e-12, "full rerank recall = {last}");
    });
}

/// At a full poll (p = q), the index's top-k equals the exhaustive
/// baseline's top-k exactly — neighbor ids and bitwise distances — so
/// AM ground truth and baselines stay comparable at every k.
#[test]
fn prop_full_poll_topk_matches_exhaustive() {
    use amsearch::baseline::Exhaustive;
    use amsearch::search::Metric;
    cases(15, |rng| {
        let dense = rng.bernoulli(0.5);
        let d = 8 + rng.below(24) as usize;
        let q = 1 + rng.below(6) as usize;
        let n = q + rng.below(120) as usize;
        let wl = if dense {
            synthetic::dense_workload(d, n, 4, QueryModel::Exact, rng)
        } else {
            synthetic::sparse_workload(
                SparseSpec { dim: d, ones: 3.0 },
                n,
                4,
                QueryModel::Exact,
                rng,
            )
        };
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, rng).unwrap();
        let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);
        let k = 1 + rng.below((n + 2) as u64) as usize;
        let mut ops = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            let x = wl.queries.get(qi);
            let got = index.query_k(x, q, k, &mut ops).neighbors;
            let want = ex.query_k(x, k, &mut ops);
            assert_eq!(got, want, "query {qi} (d={d} q={q} n={n} k={k})");
        }
    });
}

/// Add/remove on OuterProductMemory is an exact inverse for random
/// pattern sequences (online re-allocation invariant).
#[test]
fn prop_memory_add_remove_inverse() {
    cases(30, |rng| {
        let d = 4 + rng.below(20) as usize;
        let base: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut mem = OuterProductMemory::new(d);
        for p in &base {
            mem.add(p);
        }
        let snapshot = mem.clone();
        let extra: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        for p in &extra {
            mem.add(p);
        }
        for p in extra.iter().rev() {
            mem.remove(p);
        }
        assert_eq!(mem.count(), snapshot.count());
        for (a, b) in mem.weights().iter().zip(snapshot.weights()) {
            assert!((a - b).abs() < 1e-3);
        }
    });
}

/// Sparse-support scoring equals dense scoring on binary data for
/// arbitrary index configurations.
#[test]
fn prop_sparse_dense_scoring_agree() {
    cases(20, |rng| {
        let d = 16 + rng.below(64) as usize;
        let n = 40 + rng.below(100) as usize;
        let q = 2 + rng.below(5) as usize;
        let spec = SparseSpec { dim: d, ones: 2.0 + rng.uniform() * 6.0 };
        let base = synthetic::sparse_patterns(spec, n, rng);
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(base.clone(), params, rng).unwrap();
        assert!(index.uses_sparse_scoring());
        let x = base.get(rng.below(n as u64) as usize);
        let mut ops = OpsCounter::new();
        let via_support = index.score_classes(x, &mut ops); // support path
        let via_dense = index.bank().score_query(x); // dense path
        for (a, b) in via_support.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-2, "support={a} dense={b}");
        }
    });
}

/// Dataset gather/support/normalize survive arbitrary shapes.
#[test]
fn prop_dataset_invariants() {
    cases(40, |rng| {
        let d = 1 + rng.below(30) as usize;
        let n = 1 + rng.below(50) as usize;
        let data: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let ds = Dataset::from_flat(d, data).unwrap();
        // gather of a random permutation preserves rows
        let mut idx: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut idx);
        let g = ds.gather(&idx);
        for (pos, &orig) in idx.iter().enumerate() {
            assert_eq!(g.get(pos), ds.get(orig as usize));
        }
        // center+normalize leaves unit or zero norms
        let mut c = ds.clone();
        c.center_and_normalize();
        for v in c.iter() {
            let norm: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(norm < 1.0 + 1e-4);
            assert!(norm > 1.0 - 1e-4 || norm < 1e-6);
        }
    });
}

/// Wire-protocol round trip: `parse(read_raw(encode(x))) == x` for
/// random search requests across the boundary shapes (dim 1, large
/// frames, extreme ids, top_k at the wire limit).
#[test]
fn prop_wire_request_roundtrip() {
    use amsearch::net::wire::{self, Frame, WireRequest, MAX_WIRE_TOP_K};
    cases(40, |rng| {
        let dim = 1 + rng.below(2_000) as usize;
        let f = Frame::Search(WireRequest {
            id: rng.next_u64(),
            top_p: rng.below(1_000) as u32,
            top_k: rng.below(MAX_WIRE_TOP_K as u64 + 1) as u32,
            // half the cases exercise the traced v2 encoding (non-zero
            // trace id appends the trailer and bumps the version byte)
            trace_id: if rng.below(2) == 0 { 0 } else { rng.next_u64() | 1 },
            vector: (0..dim).map(|_| rng.normal() as f32).collect(),
        });
        let bytes = f.encode();
        let raw = wire::read_raw(&mut std::io::Cursor::new(bytes)).unwrap();
        assert_eq!(wire::parse(&raw).unwrap(), f);
    });
}

/// Wire-protocol round trip for responses: k > 1 neighbor lists, the
/// empty-neighbors ("no candidates") case, and long polled lists —
/// through both the blocking reader and the incremental `FrameBuffer`
/// with random packet fragmentation.
#[test]
fn prop_wire_response_roundtrip() {
    use amsearch::net::wire::{self, Frame, FrameBuffer, WireResponse};
    use amsearch::search::Neighbor;
    cases(40, |rng| {
        let k = rng.below(400) as usize; // 0 = empty-neighbors case
        let f = Frame::Result(WireResponse {
            id: rng.next_u64(),
            neighbors: (0..k)
                .map(|_| Neighbor {
                    id: rng.next_u64() as u32,
                    distance: rng.normal() as f32,
                })
                .collect(),
            polled: (0..rng.below(128)).map(|_| rng.next_u64() as u32).collect(),
            candidates: rng.next_u64(),
            ops: rng.next_u64(),
            service_ns: rng.next_u64(),
        });
        let bytes = f.encode();
        let raw = wire::read_raw(&mut std::io::Cursor::new(bytes.clone())).unwrap();
        assert_eq!(wire::parse(&raw).unwrap(), f);
        // the incremental decoder sees the same frame under arbitrary
        // TCP fragmentation
        let mut fb = FrameBuffer::new();
        let mut pos = 0usize;
        let mut got = None;
        while pos < bytes.len() {
            let step = 1 + rng.below(64) as usize;
            let end = (pos + step).min(bytes.len());
            fb.extend(&bytes[pos..end]);
            pos = end;
            if let Some(raw) = fb.next_raw().unwrap() {
                got = Some(wire::parse(&raw).unwrap());
            }
        }
        assert_eq!(got, Some(f));
        assert!(fb.is_empty());
    });
}

/// Corrupt frames are rejected, never mis-parsed: bad magic and
/// oversized length prefixes are connection-fatal, truncation is an
/// error, and single-byte payload corruption either still parses (a
/// flipped value bit) or fails cleanly — it must never panic.
#[test]
fn prop_wire_corrupt_frames_rejected() {
    use amsearch::net::wire::{self, Frame, WireRequest};
    cases(40, |rng| {
        let dim = 1 + rng.below(64) as usize;
        let f = Frame::Search(WireRequest {
            id: rng.next_u64(),
            top_p: rng.below(64) as u32,
            top_k: rng.below(64) as u32,
            trace_id: 0, // v1 layout: the corruption offsets below assume it
            vector: (0..dim).map(|_| rng.normal() as f32).collect(),
        });
        let good = f.encode();

        // (a) corrupt magic: fatal
        let mut bad_magic = good.clone();
        let mi = rng.below(4) as usize;
        bad_magic[mi] ^= 0xFF;
        assert!(wire::read_raw(&mut std::io::Cursor::new(bad_magic)).is_err());

        // (b) oversized length prefix: fatal, nothing allocated
        let mut bad_len = good.clone();
        bad_len[16..20].copy_from_slice(&(wire::MAX_PAYLOAD + 1).to_le_bytes());
        assert!(wire::read_raw(&mut std::io::Cursor::new(bad_len)).is_err());

        // (c) truncation at any point: error, not a partial frame
        let cut = rng.below(good.len() as u64) as usize;
        assert!(wire::read_raw(&mut std::io::Cursor::new(good[..cut].to_vec()))
            .is_err());

        // (d) arbitrary payload byte corruption: parse or typed reject
        let mut flipped = good.clone();
        let payload_len = (good.len() - wire::HEADER_LEN) as u64;
        let bi = wire::HEADER_LEN + rng.below(payload_len) as usize;
        flipped[bi] ^= 1 << rng.below(8);
        if let Ok(raw) = wire::read_raw(&mut std::io::Cursor::new(flipped)) {
            let _ = wire::parse(&raw); // must not panic either way
        }
    });
}

/// The cluster acceptance pin: for random dense/sparse datasets, random
/// `k`, shard counts, and planning strategies, the scatter-gather
/// router at full fan-out (`s = N`, per-shard full poll) returns
/// results **bitwise-identical** — neighbor ids and `to_bits()`
/// distances — to single-node `SearchServer::search` on the unsharded
/// index, through real loopback TCP (router → shard links and the
/// client → router connection are all real sockets).
#[test]
fn prop_router_full_fanout_matches_single_node() {
    use amsearch::cluster::{ClusterConfig, ClusterHarness, ShardStrategy};
    use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
    use amsearch::net::{NetClient, NetConfig};
    use amsearch::runtime::Backend;
    use std::sync::Arc;

    cases(6, |rng| {
        let dense = rng.bernoulli(0.5);
        let d = 8 + 8 * rng.below(3) as usize; // 8 / 16 / 24
        let q = 4 + rng.below(5) as usize; // 4..=8
        let n = q * (8 + rng.below(12) as usize); // every class non-empty
        let wl = if dense {
            synthetic::dense_workload(d, n, 8, QueryModel::Exact, rng)
        } else {
            synthetic::sparse_workload(
                SparseSpec { dim: d, ones: 4.0 },
                n,
                8,
                QueryModel::Exact,
                rng,
            )
        };
        let params =
            IndexParams { n_classes: q, top_p: 2, top_k: 3, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, rng).unwrap();

        let single = SearchServer::start(
            EngineFactory {
                index: Arc::new(index.clone()),
                backend: Backend::Native,
                artifacts_dir: None,
            },
            CoordinatorConfig {
                max_batch: 4,
                max_wait_us: 200,
                workers: 1,
                queue_depth: 64,
                quality_sample: 0,
            },
        )
        .unwrap();

        let n_shards = 1 + rng.below(q.min(4) as u64) as usize;
        let strategy = match rng.below(3) {
            0 => ShardStrategy::Contiguous,
            1 => ShardStrategy::RoundRobin,
            _ => ShardStrategy::BalancedMembers,
        };
        let cfg = ClusterConfig {
            n_shards,
            strategy,
            coordinator: CoordinatorConfig {
                max_batch: 4,
                max_wait_us: 200,
                workers: 1,
                queue_depth: 64,
                quality_sample: 0,
            },
            net: NetConfig { max_connections: 4, poll_ms: 5, ..Default::default() },
            ..Default::default()
        };
        let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
        let mut client = NetClient::connect(cluster.router_addr()).unwrap();
        client
            .set_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();

        for qi in 0..wl.queries.len() {
            // k sweeps the edges: 1, a mid value, beyond the database
            let k = match qi % 4 {
                0 => 1,
                1 => 1 + rng.below(8) as usize,
                2 => n + 3,
                _ => 0, // index default
            };
            let query = wl.queries.get(qi);
            let expected = single.search(query.to_vec(), q, k).unwrap();
            let routed = client.search_k(query, q, k).unwrap();
            assert_eq!(
                routed.neighbors.len(),
                expected.neighbors.len(),
                "qi={qi} k={k} N={n_shards} {strategy}"
            );
            for (a, b) in routed.neighbors.iter().zip(&expected.neighbors) {
                assert_eq!(a.id, b.id, "qi={qi} k={k} N={n_shards} {strategy}");
                assert_eq!(
                    a.distance.to_bits(),
                    b.distance.to_bits(),
                    "qi={qi} k={k} N={n_shards} {strategy}"
                );
            }
            assert_eq!(routed.candidates, expected.candidates as u64);
        }
        cluster.shutdown();
        single.shutdown();
    });
}

/// Every available SIMD backend is **bitwise-identical** (`to_bits`) to
/// the scalar reference for every f32 kernel — squared L2, dot, the
/// wide dot, and hamming — across odd lengths, n < 4 (tail-only, no
/// full SIMD chunk), n = 0, and NaN-free random data with planted
/// equal coordinates (hamming must count, not approximate).
#[test]
fn prop_kernel_backends_bitwise_equal_scalar() {
    use amsearch::search::{Backend, Kernels};
    cases(60, |rng| {
        let scalar = Kernels::scalar();
        // length mix: tails only (0..=3), one-chunk-ish, and general
        // odd/even lengths spanning several probe groups
        let n = match rng.below(3) {
            0 => rng.below(4) as usize,
            1 => 4 + rng.below(12) as usize,
            _ => rng.below(300) as usize,
        };
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n)
            .map(|i| if rng.bernoulli(0.2) { a[i] } else { rng.normal() as f32 })
            .collect();
        for backend in
            [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon]
        {
            let Some(k) = Kernels::with_backend(backend) else {
                continue;
            };
            let tag = backend.name();
            assert_eq!(
                k.sq_l2(&a, &b).to_bits(),
                scalar.sq_l2(&a, &b).to_bits(),
                "sq_l2 {tag} n={n}"
            );
            assert_eq!(
                k.dot(&a, &b).to_bits(),
                scalar.dot(&a, &b).to_bits(),
                "dot {tag} n={n}"
            );
            assert_eq!(
                k.dot_wide(&a, &b).to_bits(),
                scalar.dot_wide(&a, &b).to_bits(),
                "dot_wide {tag} n={n}"
            );
            assert_eq!(
                k.hamming(&a, &b),
                scalar.hamming(&a, &b),
                "hamming {tag} n={n}"
            );
        }
    });
}

/// The early-abandoning scan kernel makes the **same keep/abandon
/// decision** with the same bitwise distance on every backend, at every
/// bound — including a bound placed exactly at the full distance (the
/// tie case: `accumulate_pruned` abandons only on strictly-greater, so
/// ties must survive on all backends alike).
#[test]
fn prop_kernel_pruned_bitwise_equal_scalar() {
    use amsearch::search::{Backend, Kernels, Metric};
    cases(60, |rng| {
        let scalar = Kernels::scalar();
        let n = rng.below(260) as usize;
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for metric in [Metric::SqL2, Metric::Dot] {
            let full = scalar.distance(metric, &a, &b);
            // bound sweep: never-abandon, bound-at-tie (full distance),
            // always-abandon-late, and a random partial-sum cut
            let bounds = [
                f32::INFINITY,
                full,
                full - full.abs() * 0.5,
                full * (rng.uniform() as f32),
            ];
            for backend in
                [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon]
            {
                let Some(k) = Kernels::with_backend(backend) else {
                    continue;
                };
                let tag = backend.name();
                for &bound in &bounds {
                    let want = scalar.distance_pruned(metric, &a, &b, bound);
                    let got = k.distance_pruned(metric, &a, &b, bound);
                    match (got, want) {
                        (None, None) => {}
                        (Some(g), Some(w)) => assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{metric:?} {tag} n={n} bound={bound}"
                        ),
                        _ => panic!(
                            "{metric:?} {tag} n={n} bound={bound}: \
                             keep/abandon diverged ({got:?} vs {want:?})"
                        ),
                    }
                }
            }
        }
    });
}

/// The integer-domain SQ8 kernel and the padded gather-free ADC kernel
/// agree bitwise across every available backend, full and pruned
/// (bound-at-tie included), over random code lengths including 0 and
/// sub-chunk sizes, and random centroid counts (pad cells present).
#[test]
fn prop_quant_kernel_backends_bitwise_equal_scalar() {
    use amsearch::search::{Backend, Kernels};
    cases(40, |rng| {
        let scalar = Kernels::scalar();
        let n = rng.below(70) as usize;
        let qcode: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let code: Vec<u8> = (0..n)
            .map(|i| if rng.bernoulli(0.2) { qcode[i] } else { rng.below(256) as u8 })
            .collect();
        let step2: Vec<f32> =
            (0..n).map(|_| rng.uniform() as f32 * 0.1 + 1e-3).collect();
        let sq8_full = scalar.sq8(&qcode, &code, &step2);
        // ADC: m subspaces, c centroids padded to the pow2 stride
        let m = rng.below(40) as usize;
        let c = 1 + rng.below(256) as usize;
        let shift = (c as u32).next_power_of_two().trailing_zeros();
        let lut: Vec<f32> =
            (0..m << shift).map(|_| rng.normal() as f32).collect();
        let acode: Vec<u8> = (0..m).map(|_| rng.below(c as u64) as u8).collect();
        let adc_full = scalar.adc(&lut, shift, &acode);
        for backend in
            [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon]
        {
            let Some(k) = Kernels::with_backend(backend) else {
                continue;
            };
            let tag = backend.name();
            assert_eq!(
                k.sq8(&qcode, &code, &step2).to_bits(),
                sq8_full.to_bits(),
                "sq8 {tag} n={n}"
            );
            assert_eq!(
                k.adc(&lut, shift, &acode).to_bits(),
                adc_full.to_bits(),
                "adc {tag} m={m} c={c}"
            );
            for &(full, pruned) in &[
                (sq8_full, k.sq8_pruned(&qcode, &code, &step2, sq8_full)),
                (adc_full, k.adc_pruned(&lut, shift, &acode, adc_full)),
            ] {
                // bound-at-tie: ties survive on every backend
                assert_eq!(
                    pruned.map(f32::to_bits),
                    Some(full.to_bits()),
                    "{tag} tie survival"
                );
            }
            for bound in [f32::INFINITY, sq8_full * 0.5] {
                assert_eq!(
                    k.sq8_pruned(&qcode, &code, &step2, bound)
                        .map(f32::to_bits),
                    scalar
                        .sq8_pruned(&qcode, &code, &step2, bound)
                        .map(f32::to_bits),
                    "sq8_pruned {tag} n={n} bound={bound}"
                );
            }
            for bound in [f32::INFINITY, adc_full * 0.5] {
                assert_eq!(
                    k.adc_pruned(&lut, shift, &acode, bound).map(f32::to_bits),
                    scalar
                        .adc_pruned(&lut, shift, &acode, bound)
                        .map(f32::to_bits),
                    "adc_pruned {tag} m={m} c={c} bound={bound}"
                );
            }
        }
    });
}

/// Forcing each backend through the `AMSEARCH_KERNEL` override selects
/// exactly that backend when it is available on the host.  Ignored by
/// default: it mutates process environment, so it must not race other
/// tests — run explicitly with
/// `cargo test --test proptests -- --ignored --test-threads=1`.
#[test]
#[ignore = "mutates process env; run with --ignored --test-threads=1"]
fn forced_kernel_override_selects_each_backend() {
    use amsearch::search::{Backend, Kernels};
    for backend in [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon] {
        if !backend.available() {
            continue;
        }
        std::env::set_var("AMSEARCH_KERNEL", backend.name());
        assert_eq!(Kernels::select().backend(), backend, "{}", backend.name());
    }
    std::env::remove_var("AMSEARCH_KERNEL");
    assert!(Kernels::select().backend().available());
}

/// Windowed-histogram merging is associative and commutative under a
/// shared clock: `(a ∪ b) ∪ c` and `a ∪ (c ∪ b)` expose identical
/// windowed statistics at every probe time.  This is the property the
/// serving stack leans on — loadgen merges per-connection windows and
/// the router merges per-shard windows in arbitrary order.
#[test]
fn prop_windowed_merge_associative_commutative() {
    use amsearch::metrics::WindowedHistogram;
    cases(40, |rng| {
        let slot_ns = 1_000 + rng.below(10_000);
        let n_slots = 2 + rng.below(8) as usize;
        let span = slot_ns * n_slots as u64;
        let mk = |rng: &mut Rng| {
            let mut w = WindowedHistogram::with_slots(slot_ns, n_slots);
            for _ in 0..rng.below(60) {
                // samples spread over ~2 windows so some slots expire
                w.record_at(1 + rng.below(1_000_000), rng.below(2 * span));
            }
            w
        };
        let (a, b, c) = (mk(rng), mk(rng), mk(rng));
        let now = rng.below(3 * span);
        let mut left = a.clone();
        left.merge_at(&b, now);
        left.merge_at(&c, now);
        let mut right = a.clone();
        let mut cb = c.clone();
        cb.merge_at(&b, now);
        right.merge_at(&cb, now);
        for probe in [now, now + slot_ns, now + span] {
            let (l, r) = (left.windowed_at(probe), right.windowed_at(probe));
            assert_eq!(l.count(), r.count(), "count at probe {probe}");
            assert_eq!(l.sum_ns(), r.sum_ns(), "sum at probe {probe}");
            assert_eq!(l.max_ns(), r.max_ns(), "max at probe {probe}");
            for q in [0.5, 0.9, 0.99] {
                assert_eq!(l.quantile_ns(q), r.quantile_ns(q), "q{q} at {probe}");
            }
        }
    });
}

/// When every sample lands inside the live window, the windowed view
/// agrees exactly with a cumulative histogram fed the same samples —
/// the STATS JSON's `window` block and `latency` block can only
/// diverge by expiry, never by accounting.
#[test]
fn prop_windowed_agrees_with_cumulative_when_window_covers_all() {
    use amsearch::metrics::{LatencyHistogram, WindowedHistogram};
    cases(40, |rng| {
        let slot_ns = 1_000 + rng.below(10_000);
        let n_slots = 2 + rng.below(8) as usize;
        let span = slot_ns * n_slots as u64;
        let mut w = WindowedHistogram::with_slots(slot_ns, n_slots);
        let mut cum = LatencyHistogram::new();
        // all arrival times inside one window ending at `now`
        let base = rng.below(1_000_000) * span;
        let now = base + span - 1;
        for _ in 0..1 + rng.below(200) {
            let ns = 1 + rng.below(10_000_000);
            let at = base + rng.below(span);
            w.record_at(ns, at);
            cum.record_ns(ns);
        }
        let live = w.windowed_at(now);
        assert_eq!(live.count(), cum.count());
        assert_eq!(live.sum_ns(), cum.sum_ns());
        assert_eq!(live.max_ns(), cum.max_ns());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(live.quantile_ns(q), cum.quantile_ns(q), "q{q}");
        }
    });
}
