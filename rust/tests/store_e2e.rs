//! Disk-resident store end-to-end: a coordinator over a paged index
//! must answer bitwise-identically to one over the resident index,
//! surface `store` accounting through STATS / Prometheus / EXPLAIN,
//! and fail requests loudly (never silently drop candidates) when the
//! data file is corrupted underneath it.

#![cfg(unix)]

use std::path::PathBuf;
use std::sync::Arc;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::data::Workload;
use amsearch::index::persist;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::runtime::Backend;
use amsearch::store::{StoreMode, StoreOptions};

fn scratch(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("amsearch_store_e2e_{}_{name}.amidx", std::process::id()))
}

fn cleanup(path: &PathBuf) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(persist::data_path(path));
}

/// Build, save, and reload an index both ways.
fn saved_pair(seed: u64, name: &str) -> (PathBuf, AmIndex, AmIndex, Workload) {
    let mut rng = Rng::new(seed);
    let wl = synthetic::dense_workload(32, 512, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 8, top_p: 2, ..Default::default() };
    let built = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let path = scratch(name);
    persist::save(&built, &path).unwrap();
    let resident = persist::load(&path).unwrap();
    let paged = persist::load_paged(&path, 1 << 20).unwrap();
    (path, resident, paged, wl)
}

fn server(index: AmIndex) -> Arc<SearchServer> {
    let factory = EngineFactory {
        index: Arc::new(index),
        backend: Backend::Native,
        artifacts_dir: None,
    };
    Arc::new(SearchServer::start(factory, CoordinatorConfig::default()).unwrap())
}

#[test]
fn paged_server_is_bitwise_equal_and_observable() {
    let (path, resident, paged, wl) = saved_pair(71, "bitwise");
    assert!(paged.is_paged());
    let rs = server(resident);
    let ps = server(paged);

    // bitwise equality across mixed fan-outs and k, batched serving path
    let combos = [(1usize, 1usize), (2, 5), (8, 10), (2, 1)];
    for qi in 0..32usize {
        let (p, k) = combos[qi % combos.len()];
        let x = wl.queries.get(qi % wl.queries.len()).to_vec();
        let a = rs.search(x.clone(), p, k).unwrap();
        let b = ps.search(x, p, k).unwrap();
        assert_eq!(a.polled, b.polled, "query {qi}");
        assert_eq!(a.candidates, b.candidates, "query {qi}");
        assert_eq!(a.neighbors.len(), b.neighbors.len(), "query {qi}");
        for (na, nb) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(na.id, nb.id, "query {qi}");
            assert_eq!(
                na.distance.to_bits(),
                nb.distance.to_bits(),
                "query {qi}: paged rerank must be bitwise-equal"
            );
        }
    }

    // STATS: the store object distinguishes the two layouts
    let stats = ps.stats_json();
    let store = stats.get("store").expect("STATS carry store.*");
    assert_eq!(store.get("kind").and_then(|v| v.as_str()), Some("paged"));
    let bytes_read = store.get("bytes_read").and_then(|v| v.as_u64()).unwrap();
    let bytes_disk = store.get("bytes_disk").and_then(|v| v.as_u64()).unwrap();
    assert!(bytes_read > 0, "paged serving must have read extents");
    assert_eq!(bytes_disk, 512 * 32 * 4, "payload bytes on disk");
    assert!(
        bytes_read <= bytes_disk,
        "with a warm cache each extent is fetched at most once \
         (read {bytes_read} of {bytes_disk})"
    );
    let rstats = rs.stats_json();
    let rstore = rstats.get("store").expect("resident STATS carry store.*");
    assert_eq!(rstore.get("kind").and_then(|v| v.as_str()), Some("resident"));
    assert_eq!(rstore.get("bytes_read").and_then(|v| v.as_u64()), Some(0));

    // Prometheus: every store family is present, bytes-read is live
    let text = ps.metrics_registry().render();
    for family in amsearch::obs::prom::STORE_FAMILIES {
        assert!(text.contains(family), "exposition missing {family}:\n{text}");
    }
    assert!(
        text.contains("amsearch_store_bytes_read_total{role=\"search\"}"),
        "{text}"
    );

    // EXPLAIN: the store section reports per-request deltas
    let explain = ps.explain(wl.queries.get(0).to_vec(), 8, 1, false).unwrap();
    let estore = explain.get("store").expect("explain carries store.*");
    assert_eq!(estore.get("kind").and_then(|v| v.as_str()), Some("paged"));
    assert!(estore.get("bytes_read").and_then(|v| v.as_f64()).is_some());

    rs.shutdown();
    ps.shutdown();
    cleanup(&path);
}

#[test]
fn corrupted_data_file_fails_requests_loudly() {
    let (path, _resident, paged, wl) = saved_pair(72, "corrupt");
    // flip the first payload byte (offset 4096, past the checked
    // header/table) after open: the per-extent checksum must catch it
    // on first fetch
    let data = persist::data_path(&path);
    let mut bytes = std::fs::read(&data).unwrap();
    bytes[4096] ^= 0xFF;
    std::fs::write(&data, &bytes).unwrap();

    let ps = server(paged);
    // a full poll touches every class, so some request must hit the
    // poisoned extent and the server must fail it, not return a partial
    // answer
    let mut failed = None;
    for qi in 0..8 {
        if let Err(e) = ps.search(wl.queries.get(qi).to_vec(), 8, 1) {
            failed = Some(e.to_string());
            break;
        }
    }
    let msg = failed.expect("corruption must surface as a failed request");
    assert!(
        msg.contains("vector store failed"),
        "unexpected error message: {msg}"
    );
    ps.shutdown();
    cleanup(&path);
}

#[test]
fn factory_store_options_select_the_layout() {
    let (path, _resident, _paged, wl) = saved_pair(73, "factory");
    let opts = StoreOptions { mode: StoreMode::Paged, cache_bytes: 1 << 20 };
    let factory =
        EngineFactory::from_index_file_with_store(&path, Backend::Native, None, &opts)
            .unwrap();
    assert!(factory.index.is_paged());
    let ps = Arc::new(SearchServer::start(factory, CoordinatorConfig::default()).unwrap());
    let resp = ps.search(wl.queries.get(0).to_vec(), 8, 1).unwrap();
    assert_eq!(resp.neighbor(), Some(wl.ground_truth[0]));
    ps.shutdown();

    let opts = StoreOptions { mode: StoreMode::Resident, cache_bytes: 0 };
    let factory =
        EngineFactory::from_index_file_with_store(&path, Backend::Native, None, &opts)
            .unwrap();
    assert!(!factory.index.is_paged());
    cleanup(&path);
}
