//! Uniformly random equal-sized partition — the model of Theorems 3.1/4.1.
//!
//! A random permutation of `0..n` is cut into `q` consecutive chunks of
//! size `k = n/q` (the last chunk absorbs the remainder when `q ∤ n`).

use super::Partition;
use crate::data::rng::Rng;
use crate::error::{Error, Result};

/// Random equal-sized allocation of `n` vectors into `q` classes.
pub fn allocate(n: usize, q: usize, rng: &mut Rng) -> Result<Partition> {
    if q == 0 || q > n {
        return Err(Error::Config(format!("need 1 <= q={q} <= n={n}")));
    }
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let k = n / q;
    let mut assignments = vec![0u32; n];
    for (pos, &v) in perm.iter().enumerate() {
        let class = (pos / k).min(q - 1) as u32;
        assignments[v as usize] = class;
    }
    Partition::from_assignments(assignments, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_sizes_when_divisible() {
        let mut rng = Rng::new(1);
        let p = allocate(1000, 10, &mut rng).unwrap();
        p.validate().unwrap();
        assert!(p.sizes().iter().all(|&s| s == 100));
    }

    #[test]
    fn remainder_goes_to_last_class() {
        let mut rng = Rng::new(2);
        let p = allocate(103, 10, &mut rng).unwrap();
        p.validate().unwrap();
        let sizes = p.sizes();
        assert_eq!(sizes[..9], [10; 9]);
        assert_eq!(sizes[9], 13);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = allocate(100, 4, &mut Rng::new(7)).unwrap();
        let b = allocate(100, 4, &mut Rng::new(7)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = allocate(100, 4, &mut Rng::new(1)).unwrap();
        let b = allocate(100, 4, &mut Rng::new(2)).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn rejects_bad_params() {
        let mut rng = Rng::new(3);
        assert!(allocate(10, 0, &mut rng).is_err());
        assert!(allocate(10, 11, &mut rng).is_err());
    }
}
