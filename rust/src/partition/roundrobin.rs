//! Deterministic round-robin allocation — the no-randomness control used
//! in tests and as a debugging baseline.

use super::Partition;
use crate::error::{Error, Result};

/// Assign vector `v` to class `v % q`.
pub fn allocate(n: usize, q: usize) -> Result<Partition> {
    if q == 0 || q > n {
        return Err(Error::Config(format!("need 1 <= q={q} <= n={n}")));
    }
    let assignments: Vec<u32> = (0..n).map(|v| (v % q) as u32).collect();
    Partition::from_assignments(assignments, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_and_valid() {
        let p = allocate(10, 3).unwrap();
        p.validate().unwrap();
        assert_eq!(p.sizes(), vec![4, 3, 3]);
        assert_eq!(p.class_of(7), 1);
    }

    #[test]
    fn rejects_bad_params() {
        assert!(allocate(2, 3).is_err());
        assert!(allocate(2, 0).is_err());
    }
}
