//! Greedy normalized-score allocation — the paper's §5.2 strategy for
//! real (non-i.i.d.) data.
//!
//! "Each class is initialized with a random vector drawn without
//! replacement.  Then each remaining vector is assigned to the class that
//! achieves the maximum normalized score.  Scores are divided by the
//! number of items k currently contained in the class, as a normalization
//! criterion."
//!
//! Classes end up with *different* sizes (the paper notes complexity is
//! then estimated as an average); an optional `max_size` cap bounds the
//! skew, which also bounds worst-case candidate-scan cost.

use super::Partition;
use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::memory::OuterProductMemory;
use crate::util::par::parallel_map;

/// Options for greedy allocation.
#[derive(Debug, Clone, Copy)]
pub struct GreedyOptions {
    /// Hard cap on class size (`None` = unbounded, the paper's variant).
    pub max_size: Option<usize>,
}

impl Default for GreedyOptions {
    fn default() -> Self {
        GreedyOptions { max_size: None }
    }
}

/// Greedily allocate every vector of `data` into `q` classes.
pub fn allocate(
    data: &Dataset,
    q: usize,
    opts: GreedyOptions,
    rng: &mut Rng,
) -> Result<Partition> {
    let n = data.len();
    if q == 0 || q > n {
        return Err(Error::Config(format!("need 1 <= q={q} <= n={n}")));
    }
    if let Some(cap) = opts.max_size {
        if cap * q < n {
            return Err(Error::Config(format!(
                "max_size {cap} * q {q} < n {n}: cannot place all vectors"
            )));
        }
    }
    let dim = data.dim();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    let mut assignments = vec![u32::MAX; n];
    let mut memories: Vec<OuterProductMemory> =
        (0..q).map(|_| OuterProductMemory::new(dim)).collect();

    // seed each class with one random vector (without replacement)
    for (ci, &v) in order[..q].iter().enumerate() {
        memories[ci].add(data.get(v as usize));
        assignments[v as usize] = ci as u32;
    }

    // greedy pass over the remaining vectors
    for &v in &order[q..] {
        let x = data.get(v as usize);
        // normalized scores, parallel over classes (each is d² work)
        let scored: Vec<(usize, f64)> = parallel_map(memories.len(), |ci| {
            let mem = &memories[ci];
            if let Some(cap) = opts.max_size {
                if mem.count() >= cap {
                    return (ci, f64::NEG_INFINITY);
                }
            }
            let s = mem.score(x) as f64 / mem.count().max(1) as f64;
            (ci, s)
        });
        let (best, _) = scored
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
            .expect("q >= 1");
        memories[best].add(x);
        assignments[v as usize] = best as u32;
    }

    Partition::from_assignments(assignments, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::data::synthetic::SparseSpec;

    #[test]
    fn covers_all_vectors() {
        let mut rng = Rng::new(1);
        let ds = synthetic::dense_patterns(16, 60, &mut rng);
        let p = allocate(&ds, 4, GreedyOptions::default(), &mut rng).unwrap();
        p.validate().unwrap();
        assert_eq!(p.n_vectors(), 60);
        assert_eq!(p.n_classes(), 4);
    }

    #[test]
    fn cap_is_respected() {
        let mut rng = Rng::new(2);
        let ds = synthetic::dense_patterns(8, 40, &mut rng);
        let p = allocate(&ds, 4, GreedyOptions { max_size: Some(12) }, &mut rng)
            .unwrap();
        p.validate().unwrap();
        assert!(p.sizes().iter().all(|&s| s <= 12), "sizes={:?}", p.sizes());
    }

    #[test]
    fn infeasible_cap_rejected() {
        let mut rng = Rng::new(3);
        let ds = synthetic::dense_patterns(8, 40, &mut rng);
        assert!(
            allocate(&ds, 4, GreedyOptions { max_size: Some(5) }, &mut rng).is_err()
        );
    }

    #[test]
    fn groups_correlated_vectors() {
        // two obvious clusters of sparse patterns with disjoint supports:
        // greedy allocation with q=2 should separate them (mostly).
        let mut rng = Rng::new(4);
        let d = 64;
        let mut ds = Dataset::empty(d);
        let mut truth = Vec::new();
        for i in 0..40 {
            let mut v = vec![0f32; d];
            let base = if i % 2 == 0 { 0 } else { 32 };
            for _ in 0..6 {
                v[base + rng.below(32) as usize] = 1.0;
            }
            ds.push(&v).unwrap();
            truth.push((i % 2) as u32);
        }
        let p = allocate(&ds, 2, GreedyOptions::default(), &mut rng).unwrap();
        p.validate().unwrap();
        // count agreement up to label swap
        let mut agree = 0;
        for v in 0..40 {
            if p.class_of(v) == truth[v] {
                agree += 1;
            }
        }
        let agree = agree.max(40 - agree);
        assert!(agree >= 35, "agreement {agree}/40");
    }

    #[test]
    fn sparse_patterns_allocate() {
        let mut rng = Rng::new(5);
        let ds = synthetic::sparse_patterns(SparseSpec { dim: 64, ones: 4.0 }, 30, &mut rng);
        let p = allocate(&ds, 3, GreedyOptions::default(), &mut rng).unwrap();
        p.validate().unwrap();
    }
}
