//! Partitioning the database into classes.
//!
//! The paper's theory assumes a uniformly random equal-sized partition
//! (`random_alloc`); §5.2 introduces a greedy normalized-score allocation
//! for real (non-i.i.d.) data (`greedy_alloc`).  `roundrobin` is the
//! deterministic control.

pub mod greedy_alloc;
pub mod random_alloc;
pub mod roundrobin;

use crate::error::{Error, Result};

/// An assignment of `n` vectors to `q` classes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// `assignments[v]` = class of vector `v`.
    assignments: Vec<u32>,
    /// `classes[i]` = ids of vectors in class `i`.
    classes: Vec<Vec<u32>>,
}

impl Partition {
    /// Build from a per-vector assignment array.
    pub fn from_assignments(assignments: Vec<u32>, n_classes: usize) -> Result<Self> {
        if n_classes == 0 {
            return Err(Error::Config("need >= 1 class".into()));
        }
        let mut classes = vec![Vec::new(); n_classes];
        for (v, &c) in assignments.iter().enumerate() {
            if c as usize >= n_classes {
                return Err(Error::Config(format!(
                    "vector {v} assigned to class {c} >= q={n_classes}"
                )));
            }
            classes[c as usize].push(v as u32);
        }
        Ok(Partition { assignments, classes })
    }

    /// Number of classes `q`.
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Total number of vectors `n`.
    pub fn n_vectors(&self) -> usize {
        self.assignments.len()
    }

    /// Class of vector `v`.
    pub fn class_of(&self, v: usize) -> u32 {
        self.assignments[v]
    }

    /// Members of class `i`.
    pub fn members(&self, i: usize) -> &[u32] {
        &self.classes[i]
    }

    /// Sizes of all classes.
    pub fn sizes(&self) -> Vec<usize> {
        self.classes.iter().map(|c| c.len()).collect()
    }

    /// Verify the partition is an exact cover of `0..n`.
    pub fn validate(&self) -> Result<()> {
        let n = self.assignments.len();
        let total: usize = self.classes.iter().map(|c| c.len()).sum();
        if total != n {
            return Err(Error::Config(format!(
                "classes cover {total} vectors, expected {n}"
            )));
        }
        let mut seen = vec![false; n];
        for (i, class) in self.classes.iter().enumerate() {
            for &v in class {
                if seen[v as usize] {
                    return Err(Error::Config(format!("vector {v} in two classes")));
                }
                seen[v as usize] = true;
                if self.assignments[v as usize] != i as u32 {
                    return Err(Error::Config(format!(
                        "vector {v}: assignment {} but listed in class {i}",
                        self.assignments[v as usize]
                    )));
                }
            }
        }
        Ok(())
    }

    /// Online insert: assign the next vector id to class `c`.
    /// Returns the new vector's id.
    pub fn push(&mut self, c: u32) -> Result<u32> {
        if c as usize >= self.classes.len() {
            return Err(Error::Config(format!(
                "class {c} >= q={}",
                self.classes.len()
            )));
        }
        let id = self.assignments.len() as u32;
        self.assignments.push(c);
        self.classes[c as usize].push(id);
        Ok(id)
    }

    /// Imbalance statistic: max size / mean size (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let sizes = self.sizes();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let mean = self.n_vectors() as f64 / self.n_classes() as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean
        }
    }
}

/// Allocation strategy selector (config-facing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocation {
    /// Uniformly random equal-sized classes (the theory's model).
    Random,
    /// Greedy normalized-score assignment (§5.2).
    Greedy,
    /// Deterministic round-robin (control).
    RoundRobin,
}

impl std::str::FromStr for Allocation {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "random" => Ok(Allocation::Random),
            "greedy" => Ok(Allocation::Greedy),
            "round_robin" => Ok(Allocation::RoundRobin),
            other => Err(crate::error::Error::Config(format!(
                "unknown allocation '{other}' (random|greedy|round_robin)"
            ))),
        }
    }
}

impl std::fmt::Display for Allocation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Allocation::Random => write!(f, "random"),
            Allocation::Greedy => write!(f, "greedy"),
            Allocation::RoundRobin => write!(f, "round_robin"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_assignments_builds_classes() {
        let p = Partition::from_assignments(vec![0, 1, 0, 1, 0], 2).unwrap();
        assert_eq!(p.members(0), &[0, 2, 4]);
        assert_eq!(p.members(1), &[1, 3]);
        assert_eq!(p.sizes(), vec![3, 2]);
        p.validate().unwrap();
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Partition::from_assignments(vec![0, 2], 2).is_err());
        assert!(Partition::from_assignments(vec![], 0).is_err());
    }

    #[test]
    fn imbalance_even_is_one() {
        let p = Partition::from_assignments(vec![0, 1, 0, 1], 2).unwrap();
        assert!((p.imbalance() - 1.0).abs() < 1e-9);
        let p = Partition::from_assignments(vec![0, 0, 0, 1], 2).unwrap();
        assert!((p.imbalance() - 1.5).abs() < 1e-9);
    }
}
