//! Random Sampling (RS) baseline — §5.2's comparison methodology, as used
//! by PySparNN and (in spirit) Annoy.
//!
//! Build: sample `r` anchor points from the collection; attach every
//! vector to its nearest anchor.  Query: find the top-`p` nearest anchors
//! (cost `r·d`), then exhaustively scan the vectors attached to them
//! (cost `Σ attached · d`).

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::metrics::OpsCounter;
use crate::search::{distance_pruned, one_nn, top_p_largest, Metric, Neighbor, TopK};
use crate::util::par::parallel_map;

/// RS anchor-tree (one level).
#[derive(Debug, Clone)]
pub struct RsAnchors {
    data: Dataset,
    metric: Metric,
    /// Database ids of the anchors.
    anchors: Vec<u32>,
    /// `attached[a]` = ids of vectors whose nearest anchor is `a`.
    attached: Vec<Vec<u32>>,
    binary_sparse: bool,
}

impl RsAnchors {
    /// Build with `r` anchors sampled without replacement.
    pub fn build(data: Dataset, r: usize, metric: Metric, rng: &mut Rng) -> Result<Self> {
        let n = data.len();
        if r == 0 || r > n {
            return Err(Error::Config(format!("need 1 <= r={r} <= n={n}")));
        }
        let anchors: Vec<u32> =
            rng.sample_distinct(n, r).into_iter().map(|i| i as u32).collect();
        // attach every vector to its nearest anchor (parallel)
        let assignments: Vec<usize> = parallel_map(n, |v| {
            let x = data.get(v);
            let mut best = f32::INFINITY;
            let mut best_a = 0usize;
            for (ai, &aid) in anchors.iter().enumerate() {
                let dist = metric.distance(x, data.get(aid as usize));
                if dist < best {
                    best = dist;
                    best_a = ai;
                }
            }
            best_a
        });
        let mut attached = vec![Vec::new(); r];
        for (v, &a) in assignments.iter().enumerate() {
            attached[a].push(v as u32);
        }
        let binary_sparse = data.is_binary_sparse();
        Ok(RsAnchors { data, metric, anchors, attached, binary_sparse })
    }

    /// Number of anchors.
    pub fn n_anchors(&self) -> usize {
        self.anchors.len()
    }

    /// Sizes of the attachment lists.
    pub fn attached_sizes(&self) -> Vec<usize> {
        self.attached.iter().map(|a| a.len()).collect()
    }

    /// Effective per-element cost (d dense, c sparse).
    fn per_elem(&self, x: &[f32]) -> usize {
        if self.binary_sparse {
            x.iter().filter(|&&v| v != 0.0).count()
        } else {
            self.data.dim()
        }
    }

    /// All anchors ranked nearest-first for `x` (cost `r·d`, counted as
    /// aux).  Used by the incremental p-sweep in the eval harness.
    pub fn ranked_anchors(&self, x: &[f32], ops: &mut OpsCounter) -> Vec<u32> {
        let per = self.per_elem(x);
        let dists: Vec<f32> = self
            .anchors
            .iter()
            .map(|&aid| -self.metric.distance(x, self.data.get(aid as usize)))
            .collect();
        ops.aux_ops += (self.anchors.len() * per) as u64;
        top_p_largest(&dists, dists.len())
    }

    /// Members attached to anchor rank slot `a` (anchor index, not id).
    pub fn attached(&self, a: usize) -> &[u32] {
        &self.attached[a]
    }

    /// Database vector by id (for incremental scans).
    pub fn vector(&self, id: u32) -> &[f32] {
        self.data.get(id as usize)
    }

    /// Metric in use.
    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Effective per-candidate scan cost (d dense / c sparse).
    pub fn per_candidate(&self, x: &[f32]) -> usize {
        self.per_elem(x)
    }

    /// 1-NN query: nearest `p` anchors, scan their attachments.
    pub fn query(&self, x: &[f32], p: usize, ops: &mut OpsCounter) -> (u32, f32, usize) {
        let (top, candidates) = self.query_k(x, p, 1, ops);
        let (id, dist) = one_nn(&top);
        (id, dist, candidates)
    }

    /// k-NN query: nearest `p` anchors, scan their attachments into a
    /// fused `TopK(k)` accumulator.  Returns the neighbors (ascending by
    /// `(distance, id)`) and the candidate count.
    pub fn query_k(
        &self,
        x: &[f32],
        p: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> (Vec<Neighbor>, usize) {
        let per = self.per_elem(x);
        // anchor search: r * d ops (aux term)
        let anchor_dists: Vec<f32> = self
            .anchors
            .iter()
            .map(|&aid| -self.metric.distance(x, self.data.get(aid as usize)))
            .collect();
        ops.aux_ops += (self.anchors.len() * per) as u64;
        let polled = top_p_largest(&anchor_dists, p);
        let mut acc = TopK::new(k.max(1));
        let mut candidates = 0usize;
        for &a in &polled {
            for &vid in &self.attached[a as usize] {
                candidates += 1;
                if let Some(dist) =
                    distance_pruned(self.metric, x, self.data.get(vid as usize), acc.bound())
                {
                    acc.push(dist, vid);
                }
            }
        }
        ops.scan_ops += (candidates * per) as u64;
        ops.searches += 1;
        (acc.into_neighbors(), candidates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clustered::{clustered_workload, ClusteredSpec};
    use crate::data::synthetic;

    #[test]
    fn attachment_is_exact_cover() {
        let mut rng = Rng::new(1);
        let ds = synthetic::dense_patterns(16, 200, &mut rng);
        let rs = RsAnchors::build(ds, 10, Metric::SqL2, &mut rng).unwrap();
        let total: usize = rs.attached_sizes().iter().sum();
        assert_eq!(total, 200);
        let mut seen = vec![false; 200];
        for a in &rs.attached {
            for &v in a {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn full_poll_finds_exact_nn() {
        let mut rng = Rng::new(2);
        let ds = synthetic::dense_patterns(16, 100, &mut rng);
        let rs = RsAnchors::build(ds.clone(), 8, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let (id, dist, cands) = rs.query(ds.get(42), 8, &mut ops);
        assert_eq!(id, 42);
        assert_eq!(dist, 0.0);
        assert_eq!(cands, 100);
    }

    #[test]
    fn clustered_data_good_recall_at_small_p() {
        let mut rng = Rng::new(3);
        let spec = ClusteredSpec { dim: 16, n_clusters: 8, ..ClusteredSpec::sift_like() };
        let wl = clustered_workload(spec, 600, 40, &mut rng);
        let rs = RsAnchors::build(wl.base.clone(), 24, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let (id, _, _) = rs.query(wl.queries.get(qi), 4, &mut ops);
            if id == gt {
                hits += 1;
            }
        }
        assert!(hits >= 28, "hits={hits}/40");
    }

    #[test]
    fn ops_accounting() {
        let mut rng = Rng::new(4);
        let ds = synthetic::dense_patterns(8, 50, &mut rng);
        let rs = RsAnchors::build(ds.clone(), 5, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let (_, _, cands) = rs.query(ds.get(0), 2, &mut ops);
        assert_eq!(ops.aux_ops, 5 * 8);
        assert_eq!(ops.scan_ops, (cands * 8) as u64);
    }

    #[test]
    fn rejects_bad_r() {
        let mut rng = Rng::new(5);
        let ds = synthetic::dense_patterns(8, 10, &mut rng);
        assert!(RsAnchors::build(ds.clone(), 0, Metric::SqL2, &mut rng).is_err());
        assert!(RsAnchors::build(ds, 11, Metric::SqL2, &mut rng).is_err());
    }
}
