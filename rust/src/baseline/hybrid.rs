//! Hybrid AM→RS method (§5.2): associative memories first identify which
//! part of the collection should be investigated, then the selected parts
//! are searched with their own per-class RS anchor structures instead of
//! exhaustively.
//!
//! Query cost: `d²·q` (AM scoring) + per polled class `r_c·d` (anchor
//! search) + attached scan — strictly less scan work than plain AM at the
//! same `p` when classes are large.

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::Result;
use crate::index::{AmIndex, IndexParams};
use crate::metrics::OpsCounter;
use crate::search::{one_nn, top_p_largest, Neighbor, TopK};

use super::rs_anchors::RsAnchors;

/// Hybrid index: an [`AmIndex`] whose classes each carry an RS substructure.
#[derive(Debug, Clone)]
pub struct HybridIndex {
    am: AmIndex,
    /// Per-class RS structures (over the class's own members).
    class_rs: Vec<RsAnchors>,
    /// Map from within-class candidate ids back to database ids.
    class_members: Vec<Vec<u32>>,
    /// Anchors polled inside each selected class.
    anchors_per_class: usize,
}

impl HybridIndex {
    /// Build: AM index, then one RS structure per class with
    /// `r = max(1, ceil(sqrt(k_i)))·anchor_factor` anchors.
    pub fn build(
        data: Dataset,
        params: IndexParams,
        anchor_factor: f64,
        anchors_per_class: usize,
        rng: &mut Rng,
    ) -> Result<Self> {
        let am = AmIndex::build(data, params, rng)?;
        let mut class_rs = Vec::with_capacity(params.n_classes);
        let mut class_members = Vec::with_capacity(params.n_classes);
        for ci in 0..params.n_classes {
            let members = am.partition().members(ci).to_vec();
            let sub = am.data().gather(&members);
            let r = (((members.len() as f64).sqrt() * anchor_factor).ceil() as usize)
                .clamp(1, members.len().max(1));
            let rs = RsAnchors::build(sub, r, params.metric, rng)?;
            class_rs.push(rs);
            class_members.push(members);
        }
        Ok(HybridIndex { am, class_rs, class_members, anchors_per_class })
    }

    /// The underlying AM index.
    pub fn am(&self) -> &AmIndex {
        &self.am
    }

    /// 1-NN query: AM scores -> top-`p` classes -> RS search inside each.
    pub fn query(&self, x: &[f32], p: usize, ops: &mut OpsCounter) -> (u32, f32) {
        one_nn(&self.query_k(x, p, 1, ops))
    }

    /// k-NN query: each polled class's RS substructure returns its local
    /// top-k, which are mapped back to database ids and merged into the
    /// global `TopK(k)`.
    pub fn query_k(
        &self,
        x: &[f32],
        p: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> Vec<Neighbor> {
        let scores = self.am.score_classes(x, ops);
        let polled = top_p_largest(&scores, p);
        let searches_before = ops.searches;
        let mut best = TopK::new(k.max(1));
        for &ci in &polled {
            let (locals, _) =
                self.class_rs[ci as usize].query_k(x, self.anchors_per_class, k, ops);
            for n in locals {
                let global = self.class_members[ci as usize][n.id as usize];
                best.push(n.distance, global);
            }
        }
        // the per-class RS queries each bumped `searches`; collapse the
        // whole hybrid query to exactly one search (robust to an empty
        // polled set, e.g. all-NaN scores)
        ops.searches = searches_before + 1;
        best.into_neighbors()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clustered::{clustered_workload, ClusteredSpec};

    #[test]
    fn full_poll_full_anchors_is_exact() {
        let mut rng = Rng::new(1);
        let spec = ClusteredSpec { dim: 12, n_clusters: 4, ..ClusteredSpec::sift_like() };
        let wl = clustered_workload(spec, 300, 20, &mut rng);
        let params = IndexParams { n_classes: 3, ..Default::default() };
        // anchor_factor big enough that r == k (anchors = all members)
        let hy = HybridIndex::build(wl.base.clone(), params, 100.0, 100, &mut rng)
            .unwrap();
        let mut ops = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let (id, _) = hy.query(wl.queries.get(qi), 3, &mut ops);
            assert_eq!(id, gt, "query {qi}");
        }
    }

    #[test]
    fn hybrid_scans_fewer_candidates_than_plain_am() {
        let mut rng = Rng::new(2);
        let spec = ClusteredSpec { dim: 16, n_clusters: 8, ..ClusteredSpec::sift_like() };
        let wl = clustered_workload(spec, 800, 20, &mut rng);
        let params = IndexParams { n_classes: 4, ..Default::default() };
        let hy =
            HybridIndex::build(wl.base.clone(), params, 1.0, 3, &mut rng).unwrap();
        let mut ops_h = OpsCounter::new();
        let mut ops_a = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            hy.query(wl.queries.get(qi), 2, &mut ops_h);
            hy.am().query(wl.queries.get(qi), 2, &mut ops_a);
        }
        assert!(
            ops_h.scan_ops < ops_a.scan_ops,
            "hybrid scan {} !< plain {}",
            ops_h.scan_ops,
            ops_a.scan_ops
        );
    }

    #[test]
    fn full_poll_query_k_matches_exhaustive_topk() {
        use crate::baseline::Exhaustive;
        use crate::search::Metric;
        let mut rng = Rng::new(4);
        let spec = ClusteredSpec { dim: 12, n_clusters: 4, ..ClusteredSpec::sift_like() };
        let wl = clustered_workload(spec, 300, 10, &mut rng);
        let params = IndexParams { n_classes: 3, ..Default::default() };
        // anchors cover every member: RS search inside a class is exact
        let hy = HybridIndex::build(wl.base.clone(), params, 100.0, 100, &mut rng)
            .unwrap();
        let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);
        let mut ops = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            let x = wl.queries.get(qi);
            let got = hy.query_k(x, 3, 4, &mut ops);
            let want = ex.query_k(x, 4, &mut ops);
            assert_eq!(got, want, "query {qi}");
        }
    }

    #[test]
    fn searches_counted_once_per_query() {
        let mut rng = Rng::new(3);
        let spec = ClusteredSpec { dim: 8, n_clusters: 2, ..ClusteredSpec::sift_like() };
        let wl = clustered_workload(spec, 100, 1, &mut rng);
        let params = IndexParams { n_classes: 2, ..Default::default() };
        let hy = HybridIndex::build(wl.base.clone(), params, 1.0, 2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        hy.query(wl.queries.get(0), 2, &mut ops);
        assert_eq!(ops.searches, 1);
    }
}
