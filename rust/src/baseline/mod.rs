//! Baselines the paper compares against — exhaustive search, Random
//! Sampling anchors (PySparNN/Annoy-style), the AM→RS hybrid — plus an
//! IVF-flat (k-means) index situating the method against modern practice.

pub mod exhaustive;
pub mod hybrid;
pub mod ivf;
pub mod kmeans;
pub mod rs_anchors;

pub use exhaustive::Exhaustive;
pub use hybrid::HybridIndex;
pub use ivf::IvfFlat;
pub use rs_anchors::RsAnchors;
