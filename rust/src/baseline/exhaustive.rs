//! Exhaustive (brute-force) nearest neighbor search — the reference both
//! for correctness (ground truth) and for the paper's relative-complexity
//! axis (cost `n·d`, or `n·c` for sparse data).

use crate::data::dataset::Dataset;
use crate::metrics::OpsCounter;
use crate::search::Metric;

/// Brute-force searcher.
#[derive(Debug, Clone)]
pub struct Exhaustive {
    data: Dataset,
    metric: Metric,
    binary_sparse: bool,
}

impl Exhaustive {
    /// Wrap a database.
    pub fn new(data: Dataset, metric: Metric) -> Self {
        let binary_sparse = data.as_flat().iter().all(|&x| x == 0.0 || x == 1.0);
        Exhaustive { data, metric, binary_sparse }
    }

    /// Database size.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the database is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reference cost per search for the relative-complexity axis:
    /// `n·d` dense, `n·c` sparse (c = query support size).
    pub fn reference_ops(&self, x: &[f32]) -> u64 {
        let eff = if self.binary_sparse {
            x.iter().filter(|&&v| v != 0.0).count()
        } else {
            self.data.dim()
        };
        (self.data.len() * eff) as u64
    }

    /// Exact nearest neighbor of `x`. Ties resolve to the smaller id.
    pub fn query(&self, x: &[f32], ops: &mut OpsCounter) -> (u32, f32) {
        let mut best = f32::INFINITY;
        let mut best_id = u32::MAX;
        for (i, v) in self.data.iter().enumerate() {
            let dist = self.metric.distance(x, v);
            if dist < best {
                best = dist;
                best_id = i as u32;
            }
        }
        ops.scan_ops += self.reference_ops(x);
        ops.searches += 1;
        (best_id, best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic;

    #[test]
    fn finds_exact_match() {
        let mut rng = Rng::new(1);
        let ds = synthetic::dense_patterns(16, 50, &mut rng);
        let ex = Exhaustive::new(ds.clone(), Metric::SqL2);
        let mut ops = OpsCounter::new();
        let (id, dist) = ex.query(ds.get(17), &mut ops);
        assert_eq!(id, 17);
        assert_eq!(dist, 0.0);
        assert_eq!(ops.scan_ops, 50 * 16);
    }

    #[test]
    fn sparse_reference_cost_uses_support() {
        let mut rng = Rng::new(2);
        let ds = synthetic::sparse_patterns(
            synthetic::SparseSpec { dim: 64, ones: 6.0 },
            30,
            &mut rng,
        );
        let ex = Exhaustive::new(ds.clone(), Metric::SqL2);
        let q = ds.get(0);
        let c = q.iter().filter(|&&v| v != 0.0).count() as u64;
        assert_eq!(ex.reference_ops(q), 30 * c);
    }

    #[test]
    fn ties_resolve_to_smaller_id() {
        let ds = Dataset::from_flat(2, vec![1., 0., 1., 0., 0., 0.]).unwrap();
        let ex = Exhaustive::new(ds, Metric::SqL2);
        let mut ops = OpsCounter::new();
        let (id, _) = ex.query(&[1., 0.], &mut ops);
        assert_eq!(id, 0);
    }
}
