//! Exhaustive (brute-force) nearest neighbor search — the reference both
//! for correctness (ground truth) and for the paper's relative-complexity
//! axis (cost `n·d`, or `n·c` for sparse data).

use crate::data::dataset::Dataset;
use crate::metrics::OpsCounter;
use crate::search::{distance_pruned, one_nn, Metric, Neighbor, TopK};

/// Brute-force searcher.
#[derive(Debug, Clone)]
pub struct Exhaustive {
    data: Dataset,
    metric: Metric,
    binary_sparse: bool,
}

impl Exhaustive {
    /// Wrap a database.
    pub fn new(data: Dataset, metric: Metric) -> Self {
        let binary_sparse = data.is_binary_sparse();
        Exhaustive { data, metric, binary_sparse }
    }

    /// Database size.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the database is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reference cost per search for the relative-complexity axis:
    /// `n·d` dense, `n·c` sparse (c = query support size).
    pub fn reference_ops(&self, x: &[f32]) -> u64 {
        let eff = if self.binary_sparse {
            x.iter().filter(|&&v| v != 0.0).count()
        } else {
            self.data.dim()
        };
        (self.data.len() * eff) as u64
    }

    /// Exact nearest neighbor of `x`. Ties resolve to the smaller id.
    pub fn query(&self, x: &[f32], ops: &mut OpsCounter) -> (u32, f32) {
        one_nn(&self.query_k(x, 1, ops))
    }

    /// Exact `k` nearest neighbors of `x`, sorted ascending by
    /// `(distance, id)` — the ground truth of every recall@k evaluation.
    pub fn query_k(&self, x: &[f32], k: usize, ops: &mut OpsCounter) -> Vec<Neighbor> {
        let mut acc = TopK::new(k.max(1));
        for (i, v) in self.data.iter().enumerate() {
            if let Some(dist) = distance_pruned(self.metric, x, v, acc.bound()) {
                acc.push(dist, i as u32);
            }
        }
        ops.scan_ops += self.reference_ops(x);
        ops.searches += 1;
        acc.into_neighbors()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic;

    #[test]
    fn finds_exact_match() {
        let mut rng = Rng::new(1);
        let ds = synthetic::dense_patterns(16, 50, &mut rng);
        let ex = Exhaustive::new(ds.clone(), Metric::SqL2);
        let mut ops = OpsCounter::new();
        let (id, dist) = ex.query(ds.get(17), &mut ops);
        assert_eq!(id, 17);
        assert_eq!(dist, 0.0);
        assert_eq!(ops.scan_ops, 50 * 16);
    }

    #[test]
    fn sparse_reference_cost_uses_support() {
        let mut rng = Rng::new(2);
        let ds = synthetic::sparse_patterns(
            synthetic::SparseSpec { dim: 64, ones: 6.0 },
            30,
            &mut rng,
        );
        let ex = Exhaustive::new(ds.clone(), Metric::SqL2);
        let q = ds.get(0);
        let c = q.iter().filter(|&&v| v != 0.0).count() as u64;
        assert_eq!(ex.reference_ops(q), 30 * c);
    }

    #[test]
    fn ties_resolve_to_smaller_id() {
        let ds = Dataset::from_flat(2, vec![1., 0., 1., 0., 0., 0.]).unwrap();
        let ex = Exhaustive::new(ds, Metric::SqL2);
        let mut ops = OpsCounter::new();
        let (id, _) = ex.query(&[1., 0.], &mut ops);
        assert_eq!(id, 0);
    }

    #[test]
    fn query_k_matches_full_sort() {
        let mut rng = Rng::new(3);
        let ds = synthetic::dense_patterns(8, 60, &mut rng);
        let ex = Exhaustive::new(ds.clone(), Metric::SqL2);
        let mut ops = OpsCounter::new();
        let x = ds.get(7);
        let got = ex.query_k(x, 5, &mut ops);
        // reference: sort all (distance, id) pairs and take the prefix
        let mut all: Vec<(f32, u32)> = (0..ds.len())
            .map(|i| (Metric::SqL2.distance(x, ds.get(i)), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (n, &(d, id)) in got.iter().zip(&all) {
            assert_eq!((n.id, n.distance), (id, d));
        }
        assert_eq!(got.len(), 5);
        assert_eq!(got[0].id, 7);
        assert_eq!(got[0].distance, 0.0);
        // k > n truncates
        let all_of_them = ex.query_k(x, 100, &mut ops);
        assert_eq!(all_of_them.len(), 60);
    }
}
