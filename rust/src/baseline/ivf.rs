//! IVF-flat baseline: k-means coarse quantizer + inverted lists — the
//! modern counterpart of the paper's Random-Sampling anchors (same
//! probe-then-scan structure, learned centroids instead of sampled
//! anchors).  Included so the trade-off curves can situate the paper's
//! method against what practitioners deploy today.

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::Result;
use crate::metrics::OpsCounter;
use crate::search::{distance_pruned, one_nn, top_p_largest, Metric, Neighbor, TopK};

use super::kmeans::{kmeans, KMeans};

/// IVF-flat index.
#[derive(Debug, Clone)]
pub struct IvfFlat {
    data: Dataset,
    metric: Metric,
    centroids: Vec<f32>,
    /// Inverted lists: vectors attached to each centroid.
    lists: Vec<Vec<u32>>,
    dim: usize,
    k: usize,
    binary_sparse: bool,
}

impl IvfFlat {
    /// Build with `n_lists` centroids (`train_iters` Lloyd iterations).
    pub fn build(
        data: Dataset,
        n_lists: usize,
        train_iters: usize,
        metric: Metric,
        rng: &mut Rng,
    ) -> Result<Self> {
        let KMeans { centroids, assignments, dim, k, .. } =
            kmeans(&data, n_lists, train_iters, rng)?;
        let mut lists = vec![Vec::new(); k];
        for (v, &a) in assignments.iter().enumerate() {
            lists[a as usize].push(v as u32);
        }
        let binary_sparse = data.is_binary_sparse();
        Ok(IvfFlat { data, metric, centroids, lists, dim, k, binary_sparse })
    }

    /// Number of inverted lists.
    pub fn n_lists(&self) -> usize {
        self.k
    }

    /// Sizes of the inverted lists.
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.len()).collect()
    }

    fn per_elem(&self, x: &[f32]) -> usize {
        if self.binary_sparse {
            x.iter().filter(|&&v| v != 0.0).count()
        } else {
            self.dim
        }
    }

    /// 1-NN query with `nprobe` lists.
    pub fn query(&self, x: &[f32], nprobe: usize, ops: &mut OpsCounter) -> (u32, f32, usize) {
        let (top, candidates) = self.query_k(x, nprobe, 1, ops);
        let (id, dist) = one_nn(&top);
        (id, dist, candidates)
    }

    /// k-NN query with `nprobe` lists: the probed inverted lists are
    /// scanned into a fused `TopK(k)` accumulator.  Returns the neighbors
    /// (ascending by `(distance, id)`) and the candidate count.
    pub fn query_k(
        &self,
        x: &[f32],
        nprobe: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> (Vec<Neighbor>, usize) {
        let per = self.per_elem(x);
        let cent_scores: Vec<f32> = (0..self.k)
            .map(|c| {
                -self
                    .metric
                    .distance(x, &self.centroids[c * self.dim..(c + 1) * self.dim])
            })
            .collect();
        ops.aux_ops += (self.k * per) as u64;
        let probed = top_p_largest(&cent_scores, nprobe.max(1));
        let mut acc = TopK::new(k.max(1));
        let mut candidates = 0usize;
        for &c in &probed {
            for &vid in &self.lists[c as usize] {
                candidates += 1;
                if let Some(dist) =
                    distance_pruned(self.metric, x, self.data.get(vid as usize), acc.bound())
                {
                    acc.push(dist, vid);
                }
            }
        }
        ops.scan_ops += (candidates * per) as u64;
        ops.searches += 1;
        (acc.into_neighbors(), candidates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clustered::{clustered_workload, ClusteredSpec};

    fn wl(seed: u64) -> crate::data::Workload {
        let spec = ClusteredSpec {
            dim: 16,
            n_clusters: 8,
            center_scale: 3.0,
            noise_scale: 0.3,
            size_skew: 0.0,
            query_jitter: 0.3,
        };
        clustered_workload(spec, 800, 60, &mut Rng::new(seed))
    }

    #[test]
    fn lists_cover_everything() {
        let wl = wl(1);
        let mut rng = Rng::new(2);
        let ivf = IvfFlat::build(wl.base.clone(), 10, 20, Metric::SqL2, &mut rng).unwrap();
        assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 800);
    }

    #[test]
    fn full_probe_is_exact() {
        let wl = wl(3);
        let mut rng = Rng::new(4);
        let ivf = IvfFlat::build(wl.base.clone(), 8, 20, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let (id, _, cands) = ivf.query(wl.queries.get(qi), 8, &mut ops);
            assert_eq!(id, gt, "query {qi}");
            assert_eq!(cands, 800);
        }
    }

    #[test]
    fn small_nprobe_good_recall_on_clustered() {
        let wl = wl(5);
        let mut rng = Rng::new(6);
        let ivf = IvfFlat::build(wl.base.clone(), 16, 25, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let (id, _, _) = ivf.query(wl.queries.get(qi), 2, &mut ops);
            if id == gt {
                hits += 1;
            }
        }
        assert!(hits >= 48, "hits={hits}/60");
        // and the scan touched far fewer than n per query on average
        assert!(ops.scan_ops / ops.searches < (800 * 16 / 2) as u64);
    }

    #[test]
    fn full_probe_query_k_matches_exhaustive_topk() {
        use crate::baseline::Exhaustive;
        let wl = wl(9);
        let mut rng = Rng::new(10);
        let ivf = IvfFlat::build(wl.base.clone(), 8, 20, Metric::SqL2, &mut rng).unwrap();
        let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);
        let mut ops = OpsCounter::new();
        for qi in 0..10 {
            let x = wl.queries.get(qi);
            let (got, cands) = ivf.query_k(x, 8, 7, &mut ops);
            assert_eq!(cands, 800);
            let want = ex.query_k(x, 7, &mut ops);
            assert_eq!(got, want, "query {qi}");
        }
    }

    #[test]
    fn ivf_beats_random_anchors_on_clustered() {
        use crate::baseline::RsAnchors;
        // same number of lists/anchors and probes: learned centroids
        // should match or beat sampled anchors in recall
        let wl = wl(7);
        let mut rng = Rng::new(8);
        let ivf = IvfFlat::build(wl.base.clone(), 16, 25, Metric::SqL2, &mut rng).unwrap();
        let rs = RsAnchors::build(wl.base.clone(), 16, Metric::SqL2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let (mut ivf_hits, mut rs_hits) = (0, 0);
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            if ivf.query(wl.queries.get(qi), 1, &mut ops).0 == gt {
                ivf_hits += 1;
            }
            if rs.query(wl.queries.get(qi), 1, &mut ops).0 == gt {
                rs_hits += 1;
            }
        }
        assert!(
            ivf_hits + 3 >= rs_hits,
            "ivf={ivf_hits} rs={rs_hits} / 60"
        );
    }
}
