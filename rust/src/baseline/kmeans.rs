//! Lloyd's k-means with k-means++ seeding — the coarse-quantizer
//! substrate for the IVF baseline (what modern ANN systems use where the
//! paper's RS baseline uses random anchors).

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::search::distance::sq_l2;
use crate::util::par::parallel_map;

/// k-means result.
#[derive(Debug, Clone)]
pub struct KMeans {
    /// Flat row-major `[k * d]` centroids.
    pub centroids: Vec<f32>,
    /// Per-vector nearest centroid.
    pub assignments: Vec<u32>,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Number of centroids.
    pub k: usize,
}

/// k-means++ initial centers.
fn init_plus_plus(data: &Dataset, k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = data.len();
    let d = data.dim();
    let mut centroids = Vec::with_capacity(k * d);
    let first = rng.below(n as u64) as usize;
    centroids.extend_from_slice(data.get(first));
    let mut dist2: Vec<f64> = (0..n)
        .map(|i| sq_l2(data.get(i), data.get(first)) as f64)
        .collect();
    for _ in 1..k {
        let total: f64 = dist2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n as u64) as usize
        } else {
            let mut target = rng.uniform() * total;
            let mut idx = 0usize;
            for (i, &w) in dist2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
                idx = i;
            }
            idx
        };
        let c = data.get(pick).to_vec();
        for i in 0..n {
            let nd = sq_l2(data.get(i), &c) as f64;
            if nd < dist2[i] {
                dist2[i] = nd;
            }
        }
        centroids.extend_from_slice(&c);
    }
    centroids
}

/// Run Lloyd's algorithm for at most `max_iters` iterations (stops early
/// when assignments are stable).
pub fn kmeans(data: &Dataset, k: usize, max_iters: usize, rng: &mut Rng) -> Result<KMeans> {
    let n = data.len();
    let d = data.dim();
    if k == 0 || k > n {
        return Err(Error::Config(format!("need 1 <= k={k} <= n={n}")));
    }
    let mut centroids = init_plus_plus(data, k, rng);
    let mut assignments = vec![u32::MAX; n];
    let mut iterations = 0usize;
    for it in 0..max_iters.max(1) {
        iterations = it + 1;
        // assignment step (parallel over vectors)
        let new_assign: Vec<u32> = parallel_map(n, |i| {
            let x = data.get(i);
            let mut best = f32::INFINITY;
            let mut best_c = 0u32;
            for c in 0..k {
                let dist = sq_l2(x, &centroids[c * d..(c + 1) * d]);
                if dist < best {
                    best = dist;
                    best_c = c as u32;
                }
            }
            best_c
        });
        let stable = new_assign == assignments;
        assignments = new_assign;
        if stable {
            break;
        }
        // update step
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for (i, &a) in assignments.iter().enumerate() {
            let x = data.get(i);
            let s = &mut sums[a as usize * d..(a as usize + 1) * d];
            for (acc, &v) in s.iter_mut().zip(x) {
                *acc += v as f64;
            }
            counts[a as usize] += 1;
        }
        for c in 0..k {
            if counts[c] == 0 {
                // empty cluster: re-seed on a random vector
                let pick = rng.below(n as u64) as usize;
                centroids[c * d..(c + 1) * d].copy_from_slice(data.get(pick));
            } else {
                for j in 0..d {
                    centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    let inertia: f64 = (0..n)
        .map(|i| {
            let a = assignments[i] as usize;
            sq_l2(data.get(i), &centroids[a * d..(a + 1) * d]) as f64
        })
        .sum();
    Ok(KMeans { centroids, assignments, inertia, iterations, dim: d, k })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clustered::{clustered_base, ClusteredSpec};

    fn toy(rng: &mut Rng) -> Dataset {
        let spec = ClusteredSpec {
            dim: 8,
            n_clusters: 4,
            center_scale: 6.0,
            noise_scale: 0.2,
            size_skew: 0.0,
            query_jitter: 0.1,
        };
        clustered_base(spec, 400, rng)
    }

    #[test]
    fn finds_separated_clusters() {
        let mut rng = Rng::new(1);
        let ds = toy(&mut rng);
        let km = kmeans(&ds, 4, 50, &mut rng).unwrap();
        // well-separated data: within-cluster variance tiny vs naive 1-mean
        let one = kmeans(&ds, 1, 10, &mut Rng::new(2)).unwrap();
        assert!(km.inertia < one.inertia * 0.05, "km={} one={}", km.inertia, one.inertia);
        // every cluster non-empty and sizes ≈ 100
        let mut counts = [0usize; 4];
        for &a in &km.assignments {
            counts[a as usize] += 1;
        }
        for c in counts {
            assert!(c > 50, "counts={counts:?}");
        }
    }

    #[test]
    fn more_k_never_increases_inertia_much() {
        let mut rng = Rng::new(3);
        let ds = toy(&mut rng);
        let k4 = kmeans(&ds, 4, 50, &mut Rng::new(4)).unwrap();
        let k8 = kmeans(&ds, 8, 50, &mut Rng::new(4)).unwrap();
        assert!(k8.inertia <= k4.inertia * 1.05);
    }

    #[test]
    fn assignments_are_nearest_centroid() {
        let mut rng = Rng::new(5);
        let ds = toy(&mut rng);
        let km = kmeans(&ds, 4, 50, &mut rng).unwrap();
        let d = ds.dim();
        for i in 0..ds.len() {
            let a = km.assignments[i] as usize;
            let da = sq_l2(ds.get(i), &km.centroids[a * d..(a + 1) * d]);
            for c in 0..km.k {
                let dc = sq_l2(ds.get(i), &km.centroids[c * d..(c + 1) * d]);
                assert!(da <= dc + 1e-4, "vector {i}: {a} vs {c}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = toy(&mut Rng::new(6));
        let a = kmeans(&ds, 3, 20, &mut Rng::new(7)).unwrap();
        let b = kmeans(&ds, 3, 20, &mut Rng::new(7)).unwrap();
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn rejects_bad_k() {
        let ds = toy(&mut Rng::new(8));
        assert!(kmeans(&ds, 0, 10, &mut Rng::new(9)).is_err());
        assert!(kmeans(&ds, 401, 10, &mut Rng::new(9)).is_err());
    }
}
