//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem.
#[derive(Debug, Error)]
pub enum Error {
    /// Shape or parameter mismatch between operands.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid configuration (failed validation).
    #[error("invalid config: {0}")]
    Config(String),

    /// Dataset file I/O or format problems.
    #[error("data error: {0}")]
    Data(String),

    /// AOT artifact manifest / HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures (compile/execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator/serving failures (channel closed, timeout...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = Error::Shape("w=[2,3] x=[4]".into());
        assert_eq!(e.to_string(), "shape mismatch: w=[2,3] x=[4]");
        let e = Error::Config("k must divide n".into());
        assert!(e.to_string().contains("k must divide n"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
