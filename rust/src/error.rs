//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls (the offline build has no
//! `thiserror`); the messages are identical to the previous derive
//! output so error-string assertions stay stable.

use std::fmt;

/// Unified error for every subsystem.
#[derive(Debug)]
pub enum Error {
    /// Shape or parameter mismatch between operands.
    Shape(String),

    /// Invalid configuration (failed validation).
    Config(String),

    /// Dataset file I/O or format problems.
    Data(String),

    /// AOT artifact manifest / HLO loading problems.
    Artifact(String),

    /// PJRT runtime failures (compile/execute).
    Runtime(String),

    /// Coordinator/serving failures (channel closed, timeout...).
    Coordinator(String),

    /// Underlying I/O error (displayed transparently).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = Error::Shape("w=[2,3] x=[4]".into());
        assert_eq!(e.to_string(), "shape mismatch: w=[2,3] x=[4]");
        let e = Error::Config("k must divide n".into());
        assert!(e.to_string().contains("k must divide n"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone")); // transparent display
    }

    #[test]
    fn io_source_is_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::Config("x".into())).is_none());
    }
}
