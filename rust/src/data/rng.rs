//! Deterministic, dependency-free pseudo-random generation.
//!
//! Every experiment in the paper is a Monte-Carlo estimate; bit-for-bit
//! reproducibility across runs and platforms matters more than
//! cryptographic quality.  We use splitmix64 for seeding and
//! xoshiro256** as the main generator (Blackman & Vigna), both of which
//! have well-understood statistical behaviour and trivially portable
//! implementations.

/// splitmix64 step: used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded with splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task.
    /// Streams with different labels are statistically independent.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut seed = self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut seed),
            splitmix64(&mut seed),
            splitmix64(&mut seed),
            splitmix64(&mut seed),
        ];
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (single value; second is dropped
    /// to keep the call-site state machine trivial).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Poisson(lambda) via Knuth for small lambda, normal approx above 30.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(m);
        let mut out = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 33.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < 0.15 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(19);
        for _ in 0..50 {
            let s = r.sample_distinct(50, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(23);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(29);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.0625)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.0625).abs() < 0.005, "rate={rate}");
    }
}
