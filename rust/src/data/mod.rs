//! Dataset substrates: deterministic RNG, the paper's synthetic pattern
//! models, real-data surrogates, TEXMEX/IDX file I/O, and the core
//! [`Dataset`]/[`Workload`] containers.

pub mod clustered;
pub mod dataset;
pub mod io;
pub mod mnist_like;
pub mod rng;
pub mod santander_like;
pub mod synthetic;

pub use dataset::{Dataset, LabeledWorkload, Workload};
pub use rng::Rng;
