//! The paper's synthetic pattern models (§3, §4, §5.1).
//!
//! * **Sparse**: i.i.d. coordinates with `P(x=1) = c/d`, else 0.
//! * **Dense**: i.i.d. unbiased ±1 coordinates.
//!
//! Query models follow §3/§4: either the query *is* a stored pattern
//! (`Theorem 3.1 / 4.1`) or it is a corrupted version with macroscopic
//! overlap `α` (`Corollary 3.2 / 4.2`).

use super::dataset::{Dataset, Workload};
use super::rng::Rng;

/// Parameters of the sparse i.i.d. model.
#[derive(Debug, Clone, Copy)]
pub struct SparseSpec {
    /// Vector dimension `d`.
    pub dim: usize,
    /// Expected number of ones `c` (so `P(x_i = 1) = c/d`).
    pub ones: f64,
}

/// Generate `n` sparse 0/1 patterns.
pub fn sparse_patterns(spec: SparseSpec, n: usize, rng: &mut Rng) -> Dataset {
    let p = spec.ones / spec.dim as f64;
    let mut data = vec![0f32; n * spec.dim];
    for x in data.iter_mut() {
        if rng.bernoulli(p) {
            *x = 1.0;
        }
    }
    Dataset::from_flat(spec.dim, data).expect("consistent by construction")
}

/// Generate `n` dense ±1 patterns.
pub fn dense_patterns(dim: usize, n: usize, rng: &mut Rng) -> Dataset {
    let mut data = Vec::with_capacity(n * dim);
    for _ in 0..n * dim {
        data.push(if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 });
    }
    Dataset::from_flat(dim, data).expect("consistent by construction")
}

/// Corrupt a sparse pattern so the overlap `Σ x⁰_l x^μ_l ≈ α·c`:
/// each 1 survives with probability α, and for every killed 1 a fresh 1
/// is placed on a random zero coordinate (keeping ~c active bits, as in
/// Corollary 3.2 where x⁰ has c ones).
pub fn corrupt_sparse(pattern: &[f32], alpha: f64, rng: &mut Rng) -> Vec<f32> {
    let mut out = pattern.to_vec();
    let d = out.len();
    let mut moved = 0usize;
    for i in 0..d {
        if out[i] == 1.0 && !rng.bernoulli(alpha) {
            out[i] = 0.0;
            moved += 1;
        }
    }
    let mut placed = 0usize;
    let mut guard = 0usize;
    while placed < moved && guard < 100 * d {
        let j = rng.below(d as u64) as usize;
        if out[j] == 0.0 && pattern[j] == 0.0 {
            out[j] = 1.0;
            placed += 1;
        }
        guard += 1;
    }
    out
}

/// Corrupt a dense ±1 pattern so that `⟨x⁰, x^μ⟩ ≈ α·d`: flip each
/// coordinate independently with probability `(1-α)/2`.
pub fn corrupt_dense(pattern: &[f32], alpha: f64, rng: &mut Rng) -> Vec<f32> {
    let flip_p = (1.0 - alpha) / 2.0;
    pattern
        .iter()
        .map(|&x| if rng.bernoulli(flip_p) { -x } else { x })
        .collect()
}

/// Query model for synthetic workloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QueryModel {
    /// The query equals a stored pattern (Thm 3.1 / 4.1).
    Exact,
    /// Corrupted with overlap α ∈ (0,1) (Cor 3.2 / 4.2).
    Corrupted { alpha: f64 },
}

/// Build a full synthetic sparse workload: `n` stored patterns plus
/// `n_queries` queries, each derived from a uniformly chosen stored
/// pattern; ground truth is that pattern's index.
pub fn sparse_workload(
    spec: SparseSpec,
    n: usize,
    n_queries: usize,
    model: QueryModel,
    rng: &mut Rng,
) -> Workload {
    let base = sparse_patterns(spec, n, rng);
    let mut queries = Dataset::empty(spec.dim);
    let mut ground_truth = Vec::with_capacity(n_queries);
    for _ in 0..n_queries {
        let target = rng.below(n as u64) as u32;
        let pattern = base.get(target as usize);
        let qv = match model {
            QueryModel::Exact => pattern.to_vec(),
            QueryModel::Corrupted { alpha } => corrupt_sparse(pattern, alpha, rng),
        };
        queries.push(&qv).expect("dims match");
        ground_truth.push(target);
    }
    Workload { base, queries, ground_truth }
}

/// Build a full synthetic dense workload (see [`sparse_workload`]).
pub fn dense_workload(
    dim: usize,
    n: usize,
    n_queries: usize,
    model: QueryModel,
    rng: &mut Rng,
) -> Workload {
    let base = dense_patterns(dim, n, rng);
    let mut queries = Dataset::empty(dim);
    let mut ground_truth = Vec::with_capacity(n_queries);
    for _ in 0..n_queries {
        let target = rng.below(n as u64) as u32;
        let pattern = base.get(target as usize);
        let qv = match model {
            QueryModel::Exact => pattern.to_vec(),
            QueryModel::Corrupted { alpha } => corrupt_dense(pattern, alpha, rng),
        };
        queries.push(&qv).expect("dims match");
        ground_truth.push(target);
    }
    Workload { base, queries, ground_truth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_density_matches_spec() {
        let mut rng = Rng::new(1);
        let spec = SparseSpec { dim: 128, ones: 8.0 };
        let ds = sparse_patterns(spec, 2000, &mut rng);
        let total_ones: f32 = ds.as_flat().iter().sum();
        let mean_ones = total_ones as f64 / 2000.0;
        assert!((mean_ones - 8.0).abs() < 0.3, "mean_ones={mean_ones}");
        assert!(ds.as_flat().iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn dense_is_pm1_and_balanced() {
        let mut rng = Rng::new(2);
        let ds = dense_patterns(64, 1000, &mut rng);
        assert!(ds.as_flat().iter().all(|&x| x == 1.0 || x == -1.0));
        let sum: f32 = ds.as_flat().iter().sum();
        let frac = sum as f64 / (64.0 * 1000.0);
        assert!(frac.abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn corrupt_sparse_overlap() {
        let mut rng = Rng::new(3);
        let spec = SparseSpec { dim: 1024, ones: 64.0 };
        let ds = sparse_patterns(spec, 1, &mut rng);
        let x = ds.get(0);
        let alpha = 0.75;
        let mut overlaps = 0.0;
        let mut count_ones = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let y = corrupt_sparse(x, alpha, &mut rng);
            overlaps += x.iter().zip(&y).map(|(a, b)| a * b).sum::<f32>() as f64;
            count_ones += y.iter().sum::<f32>() as f64;
        }
        let c = x.iter().sum::<f32>() as f64;
        let mean_overlap = overlaps / trials as f64;
        assert!(
            (mean_overlap - alpha * c).abs() < 0.1 * c,
            "mean_overlap={mean_overlap} want≈{}",
            alpha * c
        );
        // the corrupted query keeps ≈ c active bits
        assert!((count_ones / trials as f64 - c).abs() < 0.05 * c);
    }

    #[test]
    fn corrupt_dense_overlap() {
        let mut rng = Rng::new(4);
        let ds = dense_patterns(2048, 1, &mut rng);
        let x = ds.get(0);
        let alpha = 0.6;
        let mut overlap = 0.0;
        let trials = 100;
        for _ in 0..trials {
            let y = corrupt_dense(x, alpha, &mut rng);
            overlap += x.iter().zip(&y).map(|(a, b)| a * b).sum::<f32>() as f64;
        }
        let mean = overlap / trials as f64 / 2048.0;
        assert!((mean - alpha).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn exact_query_workload_has_true_copy() {
        let mut rng = Rng::new(5);
        let wl = dense_workload(32, 100, 20, QueryModel::Exact, &mut rng);
        wl.validate().unwrap();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            assert_eq!(wl.queries.get(qi), wl.base.get(gt as usize));
        }
    }

    #[test]
    fn corrupted_workload_validates() {
        let mut rng = Rng::new(6);
        let wl = sparse_workload(
            SparseSpec { dim: 64, ones: 6.0 },
            50,
            10,
            QueryModel::Corrupted { alpha: 0.8 },
            &mut rng,
        );
        wl.validate().unwrap();
        assert_eq!(wl.queries.len(), 10);
    }
}
