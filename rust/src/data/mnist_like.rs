//! MNIST surrogate for Figure 9 (see DESIGN.md §6 for the substitution
//! rationale).
//!
//! Raw MNIST is 60k reference / 10k query grey-level images, 784 pixels.
//! What Figure 9 exercises is: (a) very high ambient dimension relative
//! to n, (b) strong class-cluster structure that the greedy allocation
//! can exploit while random allocation cannot, (c) correlated (spatially
//! smooth) coordinates.  The surrogate generates 10 smooth random
//! prototype "digits" on a 28×28 grid and samples noisy, intensity-scaled
//! instances of them.  Values live in [0, 255] like raw MNIST.

use super::clustered::exact_ground_truth;
use super::dataset::{Dataset, LabeledWorkload, Workload};
use super::rng::Rng;

/// 28×28 images.
pub const SIDE: usize = 28;
/// 784 pixels.
pub const DIM: usize = SIDE * SIDE;
/// 10 prototype classes, like the 10 digits.
pub const N_CLASSES: usize = 10;

/// Smooth random field on the SIDE×SIDE grid: random impulses blurred by
/// repeated 3×3 box filtering, normalized to [0, 255].
fn smooth_prototype(rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0f32; DIM];
    // sparse impulses
    for _ in 0..40 {
        let r = rng.below(SIDE as u64) as usize;
        let c = rng.below(SIDE as u64) as usize;
        img[r * SIDE + c] = 1.0 + rng.uniform() as f32;
    }
    // 3 passes of 3x3 box blur -> spatially-correlated strokes
    for _ in 0..3 {
        let mut out = vec![0f32; DIM];
        for r in 0..SIDE {
            for c in 0..SIDE {
                let mut acc = 0f32;
                let mut cnt = 0f32;
                for dr in -1i32..=1 {
                    for dc in -1i32..=1 {
                        let rr = r as i32 + dr;
                        let cc = c as i32 + dc;
                        if (0..SIDE as i32).contains(&rr) && (0..SIDE as i32).contains(&cc)
                        {
                            acc += img[rr as usize * SIDE + cc as usize];
                            cnt += 1.0;
                        }
                    }
                }
                out[r * SIDE + c] = acc / cnt;
            }
        }
        img = out;
    }
    let max = img.iter().cloned().fold(1e-9f32, f32::max);
    for x in img.iter_mut() {
        *x = *x / max * 255.0;
    }
    img
}

/// Sample one image from a prototype: global intensity scale, pixel
/// noise, clamp to [0, 255].
fn sample_from(proto: &[f32], rng: &mut Rng) -> Vec<f32> {
    let scale = 0.7 + 0.6 * rng.uniform() as f32; // [0.7, 1.3]
    proto
        .iter()
        .map(|&p| {
            let v = p * scale + (rng.normal() * 18.0) as f32;
            v.clamp(0.0, 255.0)
        })
        .collect()
}

/// Generate an MNIST-like workload of `n` base images and `n_queries`
/// query images (fresh samples of the same prototypes — like unseen test
/// digits), with exact brute-force ground truth.
pub fn mnist_like_workload(n: usize, n_queries: usize, rng: &mut Rng) -> Workload {
    mnist_like_labeled_workload(n, n_queries, rng).workload
}

/// Like [`mnist_like_workload`], but also returns which prototype
/// ("digit") each base/query image was sampled from — the labels the
/// k-NN classification scenario votes over.
pub fn mnist_like_labeled_workload(
    n: usize,
    n_queries: usize,
    rng: &mut Rng,
) -> LabeledWorkload {
    let protos: Vec<Vec<f32>> = (0..N_CLASSES).map(|_| smooth_prototype(rng)).collect();
    let mut base = Dataset::empty(DIM);
    let mut base_labels = Vec::with_capacity(n);
    for i in 0..n {
        let label = i % N_CLASSES;
        base.push(&sample_from(&protos[label], rng)).expect("dims match");
        base_labels.push(label as u32);
    }
    let mut queries = Dataset::empty(DIM);
    let mut query_labels = Vec::with_capacity(n_queries);
    for i in 0..n_queries {
        let label = i % N_CLASSES;
        queries.push(&sample_from(&protos[label], rng)).expect("dims match");
        query_labels.push(label as u32);
    }
    let ground_truth = exact_ground_truth(&base, &queries);
    LabeledWorkload {
        workload: Workload { base, queries, ground_truth },
        base_labels,
        query_labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let mut rng = Rng::new(1);
        let wl = mnist_like_workload(200, 20, &mut rng);
        wl.validate().unwrap();
        assert_eq!(wl.base.dim(), 784);
        assert!(wl
            .base
            .as_flat()
            .iter()
            .all(|&x| (0.0..=255.0).contains(&x)));
    }

    #[test]
    fn class_structure_exists() {
        // Same-prototype images are closer than cross-prototype ones on
        // average (this is what greedy allocation exploits).
        let mut rng = Rng::new(2);
        let wl = mnist_like_workload(100, 1, &mut rng);
        let sq = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum()
        };
        // rows i and i+10 share a prototype; i and i+1 do not
        let mut same = 0.0;
        let mut diff = 0.0;
        for i in 0..50 {
            same += sq(wl.base.get(i), wl.base.get(i + 10));
            diff += sq(wl.base.get(i), wl.base.get(i + 1));
        }
        assert!(diff > 1.3 * same, "same={same} diff={diff}");
    }

    #[test]
    fn labeled_workload_is_consistent() {
        let mut rng = Rng::new(4);
        let lw = mnist_like_labeled_workload(120, 30, &mut rng);
        lw.validate().unwrap();
        assert_eq!(lw.base_labels.len(), 120);
        assert_eq!(lw.query_labels.len(), 30);
        assert!(lw.base_labels.iter().all(|&l| (l as usize) < N_CLASSES));
        // labels cycle over the prototypes
        assert_eq!(lw.base_labels[0], 0);
        assert_eq!(lw.base_labels[10], 0);
        assert_eq!(lw.base_labels[11], 1);
    }

    #[test]
    fn prototypes_are_smooth() {
        let mut rng = Rng::new(3);
        let p = smooth_prototype(&mut rng);
        // neighboring-pixel correlation: avg |p[i]-p[i+1]| much smaller
        // than the dynamic range
        let mut adj = 0.0;
        for r in 0..SIDE {
            for c in 0..SIDE - 1 {
                adj += (p[r * SIDE + c] - p[r * SIDE + c + 1]).abs() as f64;
            }
        }
        adj /= (SIDE * (SIDE - 1)) as f64;
        assert!(adj < 30.0, "adjacent delta {adj} too large for smooth field");
    }
}
