//! Core dataset container and preprocessing.
//!
//! Vectors are stored row-major in a flat `Vec<f32>`; this is the layout
//! every scorer, memory builder, and the PJRT runtime consume directly
//! (no conversion on the hot path).

use crate::error::{Error, Result};

/// A collection of `n` vectors of dimension `d`, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    dim: usize,
    data: Vec<f32>,
}

impl Dataset {
    /// Create from flat row-major data.
    pub fn from_flat(dim: usize, data: Vec<f32>) -> Result<Self> {
        if dim == 0 {
            return Err(Error::Shape("dim must be > 0".into()));
        }
        if data.len() % dim != 0 {
            return Err(Error::Shape(format!(
                "data length {} not a multiple of dim {}",
                data.len(),
                dim
            )));
        }
        Ok(Dataset { dim, data })
    }

    /// An empty dataset of the given dimensionality.
    pub fn empty(dim: usize) -> Self {
        Dataset { dim, data: Vec::new() }
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    /// True when no vectors are stored.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Borrow vector `i`.
    #[inline]
    pub fn get(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The flat row-major buffer.
    pub fn as_flat(&self) -> &[f32] {
        &self.data
    }

    /// Append one vector.
    pub fn push(&mut self, v: &[f32]) -> Result<()> {
        if v.len() != self.dim {
            return Err(Error::Shape(format!(
                "vector has dim {}, dataset dim {}",
                v.len(),
                self.dim
            )));
        }
        self.data.extend_from_slice(v);
        Ok(())
    }

    /// Iterate over vectors.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.dim)
    }

    /// Gather a sub-dataset by indices (used to materialize classes).
    pub fn gather(&self, indices: &[u32]) -> Dataset {
        let mut data = Vec::with_capacity(indices.len() * self.dim);
        for &i in indices {
            data.extend_from_slice(self.get(i as usize));
        }
        Dataset { dim: self.dim, data }
    }

    /// Per-coordinate mean over all vectors.
    pub fn mean(&self) -> Vec<f32> {
        let n = self.len().max(1) as f64;
        let mut acc = vec![0f64; self.dim];
        for v in self.iter() {
            for (a, &x) in acc.iter_mut().zip(v) {
                *a += x as f64;
            }
        }
        acc.into_iter().map(|a| (a / n) as f32).collect()
    }

    /// The paper's §5.2 preprocessing for non-sparse real data: center,
    /// then project every vector onto the unit hypersphere.
    /// Returns the mean that was subtracted (to apply to queries).
    pub fn center_and_normalize(&mut self) -> Vec<f32> {
        let mean = self.mean();
        let dim = self.dim;
        for row in self.data.chunks_exact_mut(dim) {
            let mut norm2 = 0f64;
            for (x, m) in row.iter_mut().zip(&mean) {
                *x -= *m;
                norm2 += (*x as f64) * (*x as f64);
            }
            let norm = norm2.sqrt();
            if norm > 1e-12 {
                let inv = (1.0 / norm) as f32;
                for x in row.iter_mut() {
                    *x *= inv;
                }
            }
        }
        mean
    }

    /// Apply a previously computed preprocessing transform to a query.
    pub fn preprocess_query(query: &[f32], mean: &[f32]) -> Vec<f32> {
        let mut v: Vec<f32> = query.iter().zip(mean).map(|(x, m)| x - m).collect();
        let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        if norm > 1e-12 {
            let inv = (1.0 / norm) as f32;
            for x in v.iter_mut() {
                *x *= inv;
            }
        }
        v
    }

    /// True when every stored value is binary 0/1 — the condition for the
    /// paper's c²-cost sparse (support-based) scoring and the `n·c` scan
    /// cost.  Shared by every structure that gates a sparse fast path
    /// (AM index, exhaustive baseline, IVF, RS anchors).
    pub fn is_binary_sparse(&self) -> bool {
        self.data.iter().all(|&x| x == 0.0 || x == 1.0)
    }

    /// Indices of non-zero coordinates of vector `i` (sparse support).
    pub fn support(&self, i: usize) -> Vec<u32> {
        self.get(i)
            .iter()
            .enumerate()
            .filter(|(_, &x)| x != 0.0)
            .map(|(j, _)| j as u32)
            .collect()
    }
}

/// A dataset plus its query set and (optionally) ground-truth NN ids —
/// the unit every experiment consumes.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Database vectors.
    pub base: Dataset,
    /// Query vectors.
    pub queries: Dataset,
    /// For each query, the index in `base` of its exact nearest neighbor
    /// (computed by brute force when the generator doesn't know it).
    pub ground_truth: Vec<u32>,
}

impl Workload {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.base.dim() != self.queries.dim() {
            return Err(Error::Shape(format!(
                "base dim {} != query dim {}",
                self.base.dim(),
                self.queries.dim()
            )));
        }
        if self.ground_truth.len() != self.queries.len() {
            return Err(Error::Shape(format!(
                "{} ground-truth entries for {} queries",
                self.ground_truth.len(),
                self.queries.len()
            )));
        }
        if let Some(&g) = self.ground_truth.iter().max() {
            if g as usize >= self.base.len() {
                return Err(Error::Data(format!(
                    "ground-truth id {} out of range (n={})",
                    g,
                    self.base.len()
                )));
            }
        }
        Ok(())
    }
}

/// A [`Workload`] whose vectors carry class labels — the unit of the
/// paper's k-NN classification scenario ("classification and object
/// retrieval").
#[derive(Debug, Clone)]
pub struct LabeledWorkload {
    /// The underlying base/query/ground-truth workload.
    pub workload: Workload,
    /// `base_labels[i]` = class label of base vector `i`.
    pub base_labels: Vec<u32>,
    /// `query_labels[i]` = true class label of query `i`.
    pub query_labels: Vec<u32>,
}

impl LabeledWorkload {
    /// Validate internal consistency (label vectors aligned with data).
    pub fn validate(&self) -> Result<()> {
        self.workload.validate()?;
        if self.base_labels.len() != self.workload.base.len() {
            return Err(Error::Shape(format!(
                "{} base labels for {} base vectors",
                self.base_labels.len(),
                self.workload.base.len()
            )));
        }
        if self.query_labels.len() != self.workload.queries.len() {
            return Err(Error::Shape(format!(
                "{} query labels for {} queries",
                self.query_labels.len(),
                self.workload.queries.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_validates() {
        assert!(Dataset::from_flat(3, vec![0.0; 9]).is_ok());
        assert!(Dataset::from_flat(3, vec![0.0; 10]).is_err());
        assert!(Dataset::from_flat(0, vec![]).is_err());
    }

    #[test]
    fn get_and_iter() {
        let ds = Dataset::from_flat(2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.get(1), &[3., 4.]);
        let rows: Vec<_> = ds.iter().collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2], &[5., 6.]);
    }

    #[test]
    fn push_checks_dim() {
        let mut ds = Dataset::empty(3);
        assert!(ds.push(&[1., 2., 3.]).is_ok());
        assert!(ds.push(&[1., 2.]).is_err());
        assert_eq!(ds.len(), 1);
    }

    #[test]
    fn gather_subset() {
        let ds = Dataset::from_flat(2, vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap();
        let sub = ds.gather(&[3, 1]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.get(0), &[3., 3.]);
        assert_eq!(sub.get(1), &[1., 1.]);
    }

    #[test]
    fn mean_is_columnwise() {
        let ds = Dataset::from_flat(2, vec![0., 10., 2., 20.]).unwrap();
        assert_eq!(ds.mean(), vec![1., 15.]);
    }

    #[test]
    fn center_and_normalize_unit_norm() {
        let mut ds =
            Dataset::from_flat(3, vec![1., 2., 3., 4., 6., 8., -1., 0., 1.]).unwrap();
        let mean = ds.center_and_normalize();
        assert_eq!(mean.len(), 3);
        for v in ds.iter() {
            let norm: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5, "norm={norm}");
        }
    }

    #[test]
    fn preprocess_query_matches_dataset_transform() {
        let rows = vec![1., 2., 3., 4., 6., 8., -1., 0., 1.];
        let mut ds = Dataset::from_flat(3, rows.clone()).unwrap();
        let mean = ds.center_and_normalize();
        let q = Dataset::preprocess_query(&rows[3..6], &mean);
        let expect = ds.get(1);
        for (a, b) in q.iter().zip(expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_vector_survives_normalize() {
        let mut ds = Dataset::from_flat(2, vec![5., 5., 5., 5.]).unwrap();
        ds.center_and_normalize(); // both rows become zero after centering
        for v in ds.iter() {
            assert!(v.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn support_lists_nonzeros() {
        let ds = Dataset::from_flat(4, vec![0., 1., 0., 2.]).unwrap();
        assert_eq!(ds.support(0), vec![1, 3]);
    }

    #[test]
    fn binary_sparse_detection() {
        let bin = Dataset::from_flat(2, vec![0., 1., 1., 0.]).unwrap();
        assert!(bin.is_binary_sparse());
        let dense = Dataset::from_flat(2, vec![0., 1., 0.5, 0.]).unwrap();
        assert!(!dense.is_binary_sparse());
        let neg = Dataset::from_flat(2, vec![1., -1.]).unwrap();
        assert!(!neg.is_binary_sparse());
        assert!(Dataset::empty(3).is_binary_sparse()); // vacuously binary
    }

    #[test]
    fn workload_validate() {
        let base = Dataset::from_flat(2, vec![0.; 8]).unwrap();
        let queries = Dataset::from_flat(2, vec![0.; 4]).unwrap();
        let wl = Workload { base: base.clone(), queries: queries.clone(), ground_truth: vec![0, 3] };
        assert!(wl.validate().is_ok());
        let bad = Workload { base, queries, ground_truth: vec![0, 4] };
        assert!(bad.validate().is_err());
    }
}
