//! Clustered real-data surrogates (SIFT1M / GIST1M stand-ins).
//!
//! The TEXMEX corpora are not redistributable inside this environment, so
//! figs 11–12 run on seeded Gaussian-mixture surrogates that preserve what
//! the methods under test actually exploit: clusterability (both RS
//! anchors and greedy-allocated associative memories win by matching
//! partition structure to data structure), the `d ≪ n` regime, and
//! anisotropic local geometry.  The real files drop in via `data::io` if
//! present (see DESIGN.md §6).

use super::dataset::{Dataset, Workload};
use super::rng::Rng;
use crate::util::par::parallel_map;

/// Parameters of the Gaussian-mixture surrogate.
#[derive(Debug, Clone, Copy)]
pub struct ClusteredSpec {
    /// Vector dimension (128 for SIFT-like, 960 for GIST-like).
    pub dim: usize,
    /// Number of mixture components.
    pub n_clusters: usize,
    /// Cluster center scale (inter-cluster separation).
    pub center_scale: f64,
    /// Within-cluster noise scale.
    pub noise_scale: f64,
    /// Zipf exponent for cluster sizes (0 = uniform; ~0.8 heavy-tailed).
    pub size_skew: f64,
    /// Noise added to a base vector to form a query (relative to
    /// `noise_scale`; small values keep the seed vector the likely NN
    /// without making the task trivial).
    pub query_jitter: f64,
}

impl ClusteredSpec {
    /// SIFT1M-like: 128-d, moderately clustered.
    pub fn sift_like() -> Self {
        ClusteredSpec {
            dim: 128,
            n_clusters: 256,
            center_scale: 1.0,
            noise_scale: 0.35,
            size_skew: 0.8,
            query_jitter: 0.25,
        }
    }

    /// GIST1M-like: 960-d global descriptors, smoother cluster structure.
    pub fn gist_like() -> Self {
        ClusteredSpec {
            dim: 960,
            n_clusters: 128,
            center_scale: 1.0,
            noise_scale: 0.45,
            size_skew: 0.6,
            query_jitter: 0.25,
        }
    }
}

/// Zipf-like cluster-size allocation: sizes ∝ (rank+1)^-skew, normalized
/// to sum exactly to `n`.
fn cluster_sizes(n: usize, n_clusters: usize, skew: f64) -> Vec<usize> {
    let weights: Vec<f64> = (0..n_clusters)
        .map(|r| 1.0 / ((r + 1) as f64).powf(skew))
        .collect();
    let total: f64 = weights.iter().sum();
    let mut sizes: Vec<usize> = weights
        .iter()
        .map(|w| ((w / total) * n as f64).floor() as usize)
        .collect();
    let mut assigned: usize = sizes.iter().sum();
    let mut r = 0;
    while assigned < n {
        sizes[r % n_clusters] += 1;
        assigned += 1;
        r += 1;
    }
    sizes
}

/// Generate the base set of a clustered workload.
pub fn clustered_base(spec: ClusteredSpec, n: usize, rng: &mut Rng) -> Dataset {
    let d = spec.dim;
    // centers
    let mut centers = Vec::with_capacity(spec.n_clusters * d);
    for _ in 0..spec.n_clusters * d {
        centers.push(rng.normal() * spec.center_scale);
    }
    // anisotropy: per-cluster per-axis scales in [0.5, 1.5]
    let mut scales = Vec::with_capacity(spec.n_clusters * d);
    for _ in 0..spec.n_clusters * d {
        scales.push(0.5 + rng.uniform());
    }
    let sizes = cluster_sizes(n, spec.n_clusters, spec.size_skew);
    let mut data = Vec::with_capacity(n * d);
    for (ci, &sz) in sizes.iter().enumerate() {
        let center = &centers[ci * d..(ci + 1) * d];
        let scale = &scales[ci * d..(ci + 1) * d];
        for _ in 0..sz {
            for j in 0..d {
                data.push(
                    (center[j] + rng.normal() * spec.noise_scale * scale[j]) as f32,
                );
            }
        }
    }
    Dataset::from_flat(d, data).expect("consistent by construction")
}

/// Brute-force exact nearest neighbors (squared L2), parallel over
/// queries.  This defines ground truth for recall@1.
pub fn exact_ground_truth(base: &Dataset, queries: &Dataset) -> Vec<u32> {
    let dim = base.dim();
    parallel_map(queries.len(), |qi| {
        let q = queries.get(qi);
        let mut best = f32::INFINITY;
        let mut best_i = 0u32;
        for (i, v) in base.iter().enumerate() {
            let mut dist = 0f32;
            for j in 0..dim {
                let t = q[j] - v[j];
                dist += t * t;
            }
            if dist < best {
                best = dist;
                best_i = i as u32;
            }
        }
        best_i
    })
}

/// Full clustered workload: queries are jittered copies of random base
/// vectors; ground truth is recomputed exactly (the jittered query's NN is
/// *not* always its seed).
pub fn clustered_workload(
    spec: ClusteredSpec,
    n: usize,
    n_queries: usize,
    rng: &mut Rng,
) -> Workload {
    let base = clustered_base(spec, n, rng);
    let d = spec.dim;
    let mut queries = Dataset::empty(d);
    for _ in 0..n_queries {
        let seed = rng.below(n as u64) as usize;
        let sv = base.get(seed);
        let q: Vec<f32> = sv
            .iter()
            .map(|&x| x + (rng.normal() * spec.noise_scale * spec.query_jitter) as f32)
            .collect();
        queries.push(&q).expect("dims match");
    }
    let ground_truth = exact_ground_truth(&base, &queries);
    Workload { base, queries, ground_truth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_sum_to_n() {
        for &(n, c, s) in &[(1000, 16, 0.8), (997, 10, 0.0), (50, 50, 1.2)] {
            let sizes = cluster_sizes(n, c, s);
            assert_eq!(sizes.iter().sum::<usize>(), n);
            assert_eq!(sizes.len(), c);
        }
    }

    #[test]
    fn skew_makes_head_heavier() {
        let sizes = cluster_sizes(10_000, 20, 0.9);
        assert!(sizes[0] > sizes[19] * 2, "sizes={sizes:?}");
    }

    #[test]
    fn base_has_cluster_structure() {
        let mut rng = Rng::new(1);
        let spec = ClusteredSpec {
            dim: 16,
            n_clusters: 4,
            center_scale: 5.0,
            noise_scale: 0.1,
            size_skew: 0.0,
            query_jitter: 0.1,
        };
        let ds = clustered_base(spec, 400, &mut rng);
        assert_eq!(ds.len(), 400);
        // within-cluster distance (consecutive rows share a cluster:
        // sizes are uniform=100) vs across-cluster distance
        let d_in = sq(ds.get(0), ds.get(1));
        let d_out = sq(ds.get(0), ds.get(399));
        assert!(d_out > 10.0 * d_in, "d_in={d_in} d_out={d_out}");
    }

    fn sq(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn ground_truth_is_argmin() {
        let mut rng = Rng::new(2);
        let spec = ClusteredSpec::sift_like();
        let spec = ClusteredSpec { dim: 8, n_clusters: 3, ..spec };
        let wl = clustered_workload(spec, 200, 20, &mut rng);
        wl.validate().unwrap();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let q = wl.queries.get(qi);
            let d_gt = sq(q, wl.base.get(gt as usize));
            for i in 0..wl.base.len() {
                assert!(d_gt <= sq(q, wl.base.get(i)) + 1e-6);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = ClusteredSpec { dim: 8, n_clusters: 3, ..ClusteredSpec::sift_like() };
        let a = clustered_workload(spec, 100, 5, &mut Rng::new(7));
        let b = clustered_workload(spec, 100, 5, &mut Rng::new(7));
        assert_eq!(a.base.as_flat(), b.base.as_flat());
        assert_eq!(a.ground_truth, b.ground_truth);
    }
}
