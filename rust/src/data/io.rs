//! TEXMEX / IDX dataset file formats.
//!
//! `fvecs`/`bvecs`/`ivecs` are the formats of the SIFT1M/GIST1M corpora
//! (each vector is a little-endian i32 dimension followed by the
//! components); IDX is the raw MNIST format.  When real corpora are
//! available (e.g. under `$DATA_DIR`), the eval harness uses them instead
//! of the surrogates.

use crate::error::{Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::dataset::Dataset;

/// Read a `.fvecs` file (f32 components).
pub fn read_fvecs(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut dim: Option<usize> = None;
    let mut data = Vec::new();
    loop {
        let mut head = [0u8; 4];
        match r.read_exact(&mut head) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(head);
        if d <= 0 {
            return Err(Error::Data(format!("fvecs: bad dim {d}")));
        }
        let d = d as usize;
        match dim {
            None => dim = Some(d),
            Some(d0) if d0 != d => {
                return Err(Error::Data(format!("fvecs: dim {d} != first dim {d0}")))
            }
            _ => {}
        }
        let mut buf = vec![0u8; d * 4];
        r.read_exact(&mut buf)?;
        for c in buf.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }
    let dim = dim.ok_or_else(|| Error::Data("fvecs: empty file".into()))?;
    Dataset::from_flat(dim, data)
}

/// Write a `.fvecs` file.
pub fn write_fvecs(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for v in ds.iter() {
        w.write_all(&(ds.dim() as i32).to_le_bytes())?;
        for &x in v {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a `.bvecs` file (u8 components, e.g. SIFT descriptors).
pub fn read_bvecs(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut dim: Option<usize> = None;
    let mut data = Vec::new();
    loop {
        let mut head = [0u8; 4];
        match r.read_exact(&mut head) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(head);
        if d <= 0 {
            return Err(Error::Data(format!("bvecs: bad dim {d}")));
        }
        let d = d as usize;
        match dim {
            None => dim = Some(d),
            Some(d0) if d0 != d => {
                return Err(Error::Data(format!("bvecs: dim {d} != first dim {d0}")))
            }
            _ => {}
        }
        let mut buf = vec![0u8; d];
        r.read_exact(&mut buf)?;
        data.extend(buf.into_iter().map(|b| b as f32));
    }
    let dim = dim.ok_or_else(|| Error::Data("bvecs: empty file".into()))?;
    Dataset::from_flat(dim, data)
}

/// Read an `.ivecs` file (i32 components — ground-truth NN lists).
pub fn read_ivecs(path: &Path) -> Result<Vec<Vec<i32>>> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    loop {
        let mut head = [0u8; 4];
        match r.read_exact(&mut head) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(head);
        if d < 0 {
            return Err(Error::Data(format!("ivecs: bad dim {d}")));
        }
        let mut buf = vec![0u8; d as usize * 4];
        r.read_exact(&mut buf)?;
        out.push(
            buf.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
    }
    Ok(out)
}

/// Write an `.ivecs` file.
pub fn write_ivecs(path: &Path, rows: &[Vec<i32>]) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes())?;
        for &x in row {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read MNIST IDX image file (magic 0x00000803) into a Dataset of
/// 784-d vectors with values in [0, 255].
pub fn read_idx_images(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut head = [0u8; 16];
    r.read_exact(&mut head)?;
    let magic = u32::from_be_bytes([head[0], head[1], head[2], head[3]]);
    if magic != 0x0000_0803 {
        return Err(Error::Data(format!("idx: bad magic {magic:#x}")));
    }
    let n = u32::from_be_bytes([head[4], head[5], head[6], head[7]]) as usize;
    let rows = u32::from_be_bytes([head[8], head[9], head[10], head[11]]) as usize;
    let cols = u32::from_be_bytes([head[12], head[13], head[14], head[15]]) as usize;
    let mut buf = vec![0u8; n * rows * cols];
    r.read_exact(&mut buf)?;
    let data: Vec<f32> = buf.into_iter().map(|b| b as f32).collect();
    Dataset::from_flat(rows * cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("amsearch_io_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..60).map(|_| rng.normal() as f32).collect();
        let ds = Dataset::from_flat(6, data).unwrap();
        let p = tmp("rt.fvecs");
        write_fvecs(&p, &ds).unwrap();
        let back = read_fvecs(&p).unwrap();
        assert_eq!(ds, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1, 2, 3], vec![7, 8, 9]];
        let p = tmp("rt.ivecs");
        write_ivecs(&p, &rows).unwrap();
        let back = read_ivecs(&p).unwrap();
        assert_eq!(rows, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bvecs_reads_bytes() {
        let p = tmp("x.bvecs");
        // two 4-d u8 vectors
        let mut bytes = Vec::new();
        for v in [[1u8, 2, 3, 4], [250, 251, 252, 253]] {
            bytes.extend(4i32.to_le_bytes());
            bytes.extend(v);
        }
        std::fs::write(&p, &bytes).unwrap();
        let ds = read_bvecs(&p).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.get(1), &[250.0, 251.0, 252.0, 253.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fvecs_rejects_mixed_dims() {
        let p = tmp("bad.fvecs");
        let mut bytes = Vec::new();
        bytes.extend(2i32.to_le_bytes());
        bytes.extend(1f32.to_le_bytes());
        bytes.extend(2f32.to_le_bytes());
        bytes.extend(3i32.to_le_bytes());
        bytes.extend(1f32.to_le_bytes());
        bytes.extend(2f32.to_le_bytes());
        bytes.extend(3f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn idx_reads_mnist_layout() {
        let p = tmp("img.idx");
        let mut bytes = Vec::new();
        bytes.extend(0x0000_0803u32.to_be_bytes());
        bytes.extend(2u32.to_be_bytes()); // 2 images
        bytes.extend(2u32.to_be_bytes()); // 2x2
        bytes.extend(2u32.to_be_bytes());
        bytes.extend([0u8, 128, 255, 64, 1, 2, 3, 4]);
        std::fs::write(&p, &bytes).unwrap();
        let ds = read_idx_images(&p).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 4);
        assert_eq!(ds.get(0), &[0.0, 128.0, 255.0, 64.0]);
        std::fs::remove_file(&p).ok();
    }
}
