//! Santander-customer-satisfaction surrogate for Figure 10 (DESIGN.md §6).
//!
//! The real dataset: 76k binary sparse vectors, 369 features, ~33
//! non-zeros per row, strongly skewed feature popularity and co-activated
//! feature blocks (survey questions answered together).  The surrogate
//! reproduces those three statistics, which are what drive both the c²·q
//! sparse scoring cost and the value of greedy allocation.  Queries are
//! the stored vectors themselves, as in the paper's §5.2 first experiment.

use super::dataset::{Dataset, Workload};
use super::rng::Rng;

/// Dimension of the real dataset.
pub const DIM: usize = 369;
/// Average non-zeros per row in the real dataset.
pub const AVG_NNZ: f64 = 33.0;
/// Number of correlated feature blocks.
const N_BLOCKS: usize = 24;

/// Generate the base set: power-law feature popularity + block
/// co-activation + Poisson row weight.
pub fn santander_like_base(n: usize, rng: &mut Rng) -> Dataset {
    // power-law popularity over features
    let pop: Vec<f64> = (0..DIM).map(|j| 1.0 / ((j + 2) as f64).powf(0.9)).collect();
    let pop_sum: f64 = pop.iter().sum();
    // cumulative distribution for popularity-weighted sampling
    let mut cdf = Vec::with_capacity(DIM);
    let mut acc = 0.0;
    for &p in &pop {
        acc += p / pop_sum;
        cdf.push(acc);
    }
    // fixed random feature blocks
    let block_of: Vec<usize> = (0..DIM).map(|_| rng.below(N_BLOCKS as u64) as usize).collect();
    let mut members_of_block: Vec<Vec<usize>> = vec![Vec::new(); N_BLOCKS];
    for (j, &b) in block_of.iter().enumerate() {
        members_of_block[b].push(j);
    }

    let sample_feature = |rng: &mut Rng, cdf: &[f64]| -> usize {
        let u = rng.uniform();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(DIM - 1),
        }
    };

    let mut data = vec![0f32; n * DIM];
    for row in 0..n {
        let target = rng.poisson(AVG_NNZ).max(1) as usize;
        let out = &mut data[row * DIM..(row + 1) * DIM];
        let mut placed = 0usize;
        let mut guard = 0usize;
        while placed < target.min(DIM) && guard < 50 * DIM {
            guard += 1;
            let j = sample_feature(rng, &cdf);
            if out[j] == 0.0 {
                out[j] = 1.0;
                placed += 1;
                // co-activation: with prob 0.35 also set a same-block peer
                if placed < target && rng.bernoulli(0.35) {
                    let peers = &members_of_block[block_of[j]];
                    let peer = peers[rng.below(peers.len() as u64) as usize];
                    if out[peer] == 0.0 {
                        out[peer] = 1.0;
                        placed += 1;
                    }
                }
            }
        }
    }
    Dataset::from_flat(DIM, data).expect("consistent by construction")
}

/// Workload where queries are stored vectors themselves (§5.2: "the
/// vectors stored in the database are the ones used to also query it").
pub fn santander_like_workload(n: usize, n_queries: usize, rng: &mut Rng) -> Workload {
    let base = santander_like_base(n, rng);
    let mut queries = Dataset::empty(DIM);
    let mut ground_truth = Vec::with_capacity(n_queries);
    for _ in 0..n_queries {
        let i = rng.below(n as u64) as u32;
        queries.push(base.get(i as usize)).expect("dims match");
        ground_truth.push(i);
    }
    Workload { base, queries, ground_truth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nnz_matches_target() {
        let mut rng = Rng::new(1);
        let ds = santander_like_base(500, &mut rng);
        let total: f32 = ds.as_flat().iter().sum();
        let mean_nnz = total as f64 / 500.0;
        assert!(
            (mean_nnz - AVG_NNZ).abs() < 4.0,
            "mean_nnz={mean_nnz} want≈{AVG_NNZ}"
        );
    }

    #[test]
    fn binary_values() {
        let mut rng = Rng::new(2);
        let ds = santander_like_base(50, &mut rng);
        assert!(ds.as_flat().iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn popularity_is_skewed() {
        let mut rng = Rng::new(3);
        let ds = santander_like_base(2000, &mut rng);
        let mut counts = vec![0usize; DIM];
        for v in ds.iter() {
            for (j, &x) in v.iter().enumerate() {
                if x == 1.0 {
                    counts[j] += 1;
                }
            }
        }
        let head: usize = counts[..20].iter().sum();
        let tail: usize = counts[DIM - 20..].iter().sum();
        assert!(head > 4 * tail.max(1), "head={head} tail={tail}");
    }

    #[test]
    fn workload_queries_are_members() {
        let mut rng = Rng::new(4);
        let wl = santander_like_workload(100, 10, &mut rng);
        wl.validate().unwrap();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            assert_eq!(wl.queries.get(qi), wl.base.get(gt as usize));
        }
    }
}
