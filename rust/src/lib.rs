//! # amsearch
//!
//! Production reproduction of *Associative Memories to Accelerate
//! Approximate Nearest Neighbor Search* (Gripon, Löwe, Vermet, 2016).
//!
//! The system partitions a vector database into `q` equal-sized classes,
//! summarizes each class with a Hopfield-style sum-of-outer-products
//! associative memory `W_i = Σ_μ x^μ (x^μ)^T`, and answers a query `x⁰` by
//! polling every memory with the bilinear score `s(X^i, x⁰) = x⁰ᵀ W_i x⁰ =
//! Σ_μ ⟨x⁰, x^μ⟩²`, then running exhaustive search only inside the top-`p`
//! classes.  Scoring costs `d²·q` (or `c²·q` for sparse data) and the
//! candidate scan `p·k·d`, versus `n·d` for exhaustive search.
//!
//! ## Architecture (three layers, python never on the request path)
//!
//! * **Layer 1** — Pallas kernel (`python/compile/kernels/class_score.py`)
//!   computing the batched bilinear form on the MXU, AOT-lowered.
//! * **Layer 2** — JAX graphs (`python/compile/model.py`) exported as HLO
//!   text artifacts (`artifacts/*.hlo.txt` + `manifest.json`).
//! * **Layer 3** — this crate: dataset substrates, memories, allocation,
//!   the AM-ANN index, baselines (exhaustive / random-sampling anchors /
//!   hybrid), a PJRT runtime that loads the AOT artifacts, an async
//!   coordinator (router + dynamic batcher + workers), a TCP front door
//!   (binary wire protocol, pipelined client library, closed-loop load
//!   generator), a sharded cluster tier (shard planner, scatter-gather
//!   router with AM-based shard pruning, single-binary cluster
//!   harness), a quantized-scan subsystem (scalar + product quantization
//!   with ADC tables and exact rerank — the complementary *dimension*
//!   axis the paper leaves open), the paper's complexity accounting,
//!   and the evaluation harness that regenerates every figure of the
//!   paper.

pub mod baseline;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod index;
pub mod memory;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod partition;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod store;
pub mod util;

pub use error::{Error, Result};
