//! `amsearch` — launcher CLI for the associative-memory ANN search system.
//!
//! ```text
//! amsearch eval  [--figure N|knn|quant | --all] [--out-dir results] [--scale S] [--seed S]
//! amsearch query [--config cfg.json] [--top-p P] [--top-k K]
//! amsearch serve [--config cfg.json] [--workers N] [--backend native|pjrt]
//!                [--repeat R] [--listen ADDR]
//! amsearch loadgen --addr HOST:PORT [--connections N] [--requests R]
//!                  [--depth D] [--top-p P] [--top-k K] [--json F] [--shutdown]
//! amsearch shard-plan [--config cfg.json] --shards N [--strategy S] [--out-dir D]
//! amsearch serve-cluster [--plan-dir D | --config cfg.json --shards N]
//!                        [--listen ADDR] [--fan-out S]
//! amsearch metrics --addr HOST:PORT [--check]
//! amsearch explain --addr HOST:PORT [--top-p P] [--top-k K] [--seed S] [--exact]
//! amsearch dash --addr HOST:PORT [--interval-ms MS] [--iterations N]
//! amsearch artifacts [--dir artifacts]
//! ```
//!
//! * `eval`  — regenerate the paper's figures (CSV + console table)
//! * `serve` — build an index per config and serve it: either drive the
//!   config's query workload in-process (default) or, with `--listen`,
//!   open the TCP front door and serve remote clients until a SHUTDOWN
//!   frame arrives
//! * `loadgen` — closed-loop TCP load generator against a running
//!   `serve --listen`, reporting throughput + latency quantiles
//! * `query` — one-shot: build index, run the config's queries, print
//!   recall and the paper's relative-complexity accounting
//! * `shard-plan` — partition a built index across N shards: per-shard
//!   index artifacts + the v3 routing-table manifest
//! * `serve-cluster` — single-binary cluster: N in-process shard
//!   servers on ephemeral ports + the scatter-gather router in front
//! * `metrics` — scrape a running server's METRICS frame (Prometheus
//!   text exposition), optionally validating it
//! * `explain` — replay one query through a running server with full
//!   introspection (the EXPLAIN admin op): poll/fan-out decision,
//!   per-stage candidates, final neighbors, optional ground-truth diff
//! * `dash` — live terminal dashboard polling a running server's STATS:
//!   rolling QPS, windowed tail latency, online recall estimate,
//!   fan-out effectiveness
//! * `artifacts` — inspect the AOT artifact manifest

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use amsearch::baseline::Exhaustive;
use amsearch::cluster::{self, ClusterConfig, ClusterHarness, ShardPlan, ShardStrategy};
use amsearch::config::{AppConfig, DatasetKind};
use amsearch::coordinator::{EngineFactory, SearchServer};
use amsearch::data::clustered::{self, ClusteredSpec};
use amsearch::data::dataset::{Dataset, Workload};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel, SparseSpec};
use amsearch::data::{io as data_io, mnist_like, santander_like};
use amsearch::error::Result;
use amsearch::eval::{run_figure, EvalOptions, ALL_FIGURES};
use amsearch::index::AmIndex;
use amsearch::metrics::{OpsCounter, Recall, RecallAtK};
use amsearch::net::{loadgen, LoadGenConfig, NetClient, NetConfig, NetServer};
use amsearch::obs::{self, TraceSink};
use amsearch::runtime::{Backend, Manifest};
use amsearch::util::{Args, Json};

const USAGE: &str = "\
usage: amsearch <command> [options]

commands:
  eval        regenerate paper figures / eval modes
              (--figure N|knn|quant | --all, --out-dir D, --scale S, --seed S)
  query       build index + run queries  (--config F, --top-p P, --top-k K,
              --index F.amidx to load instead of building)
  build       build index and save it     (--config F, --out F.amidx;
              writes F.amidx + the F.amdat class-extent data file)

  index-building commands (build, query, serve, shard-plan,
  serve-cluster) also take the scan-precision knobs:
              --precision exact|sq8|pq  compressed candidate scan
              --rerank R                exact-rerank budget (0 = all)
              --pq-m M --pq-bits B      PQ shape (M subspaces, B bits)

  index-loading commands (query --index, serve --index,
  serve-cluster --plan-dir) also take the vector-store knobs:
              --store resident|paged    where exact member vectors live:
                                        RAM slabs (default) or the
                                        .amdat file, paged in per polled
                                        class behind an LRU extent cache
              --store-cache-mb MB       extent-cache budget (paged only)
  serve       serve queries through the coordinator
              (--config F, --workers N, --backend native|pjrt, --repeat R,
               --index F.amidx to serve a saved index instead of
               building one,
               --listen ADDR to open the TCP front door instead of
               driving the config workload in-process)

  serving commands (serve --listen, serve-cluster) also take the
  tracing knobs:
              --trace-out FILE          per-request span records as
                                        JSON lines (tracing is off
                                        without this)
              --trace-sample N          sample every Nth request (0 =
                                        only slow queries)
              --trace-slow-ms MS        force-trace requests slower
                                        than MS (0 = off)
              --quality-sample N        shadow-execute every Nth request
                                        as an exact scan off the hot
                                        path and export the online
                                        recall estimate (0 = off)
  loadgen     closed-loop TCP load generator against serve --listen or
              serve-cluster (--addr HOST:PORT, --connections N,
               --requests R, --depth D, --top-p P, --top-k K,
               --connect-timeout-s S, --seed S,
               --json FILE to write a BENCH JSON artifact,
               --shutdown to stop the server afterwards)
  shard-plan  partition a built index across N shards and write the
              shard artifacts + v3 routing-table manifest
              (--config F, --shards N,
               --strategy contiguous|round_robin|balanced, --out-dir D)
  serve-cluster
              single-binary cluster: N in-process shard servers on
              ephemeral ports + scatter-gather router at --listen
              (--plan-dir D to load a shard-plan, or --config F
               --shards N --strategy S to build in-process;
               --fan-out S contacts only the top-s shards per query,
               0 = all; --listen ADDR, --router-workers W)
  metrics     scrape a running server's Prometheus text exposition
              (--addr HOST:PORT, --check to validate the format and
               required metric families, exiting non-zero on failure;
               --require-store to additionally require the
               amsearch_store_* families)
  explain     replay one query through a running server with full
              introspection: poll / fan-out decision and margin,
              per-stage candidate counts, final neighbors — and, with
              --exact, the exact ground-truth diff (recall, rank
              displacement, distance error)
              (--addr HOST:PORT, --top-p P, --top-k K,
               --seed S for the synthesized query, --exact)
  dash        live terminal dashboard for a running server: rolling
              QPS, windowed tail latency, online recall estimate,
              fan-out effectiveness, per-shard capture rates
              (--addr HOST:PORT, --interval-ms MS,
               --iterations N to stop after N frames, 0 = forever)
  artifacts   show the AOT manifest      (--dir D)
";

/// Apply the scan-precision CLI overrides (`--precision`, `--rerank`,
/// `--pq-m`, `--pq-bits`) on top of the config file.  Flags that are
/// absent keep the config's values.
fn apply_scan_precision_args(
    cfg: &mut AppConfig,
    args: &Args,
) -> Result<()> {
    use amsearch::quant::ScanPrecision;
    if args.get("precision").is_none()
        && args.get("rerank").is_none()
        && args.get("pq-m").is_none()
        && args.get("pq-bits").is_none()
    {
        return Ok(());
    }
    let mode = args
        .get("precision")
        .unwrap_or(cfg.index.precision.mode())
        .to_string();
    let knob_given = args.get("rerank").is_some()
        || args.get("pq-m").is_some()
        || args.get("pq-bits").is_some();
    if mode == "exact" && knob_given {
        // --rerank / --pq-* mean nothing on an exact scan: reject
        // instead of silently serving at a different precision
        return Err(amsearch::Error::Config(
            "--rerank/--pq-m/--pq-bits require --precision sq8|pq \
             (or a quantized 'precision' in the config)"
                .into(),
        ));
    }
    let (cfg_m, cfg_bits) = match cfg.index.precision {
        ScanPrecision::Pq { m, bits, .. } => (m, bits),
        _ => (8, 8),
    };
    cfg.index.precision = amsearch::config::scan_precision_from_knobs(
        &mode,
        args.get_parse("rerank", cfg.index.precision.rerank())?,
        args.get_parse("pq-m", cfg_m)?,
        args.get_parse("pq-bits", cfg_bits)?,
    )?;
    Ok(())
}

/// Apply the vector-store CLI overrides (`--store`,
/// `--store-cache-mb`) on top of the config file's store section.
fn apply_store_args(cfg: &mut AppConfig, args: &Args) -> Result<()> {
    if let Some(mode) = args.get("store") {
        cfg.store.mode = amsearch::store::StoreMode::parse(mode)?;
    }
    if args.get("store-cache-mb").is_some()
        && cfg.store.mode != amsearch::store::StoreMode::Paged
    {
        // a cache budget means nothing on a resident store: reject
        // instead of silently ignoring the knob
        return Err(amsearch::Error::Config(
            "--store-cache-mb requires --store paged (or a paged 'mode' \
             in the config's store section)"
                .into(),
        ));
    }
    cfg.store.cache_mb = args.get_parse("store-cache-mb", cfg.store.cache_mb)?;
    Ok(())
}

/// Build the optional per-request trace sink from the config's serve
/// section plus the CLI overrides (`--trace-out`, `--trace-sample`,
/// `--trace-slow-ms`).  Tracing stays off unless an output path is
/// given — the hot path then pays nothing (see `obs::trace`).
fn build_trace_sink(
    serve: &amsearch::config::ServeConfig,
    args: &Args,
) -> Result<Option<Arc<TraceSink>>> {
    let sample: u64 = args.get_parse("trace-sample", serve.trace_sample)?;
    let slow_ms: u64 = args.get_parse("trace-slow-ms", serve.trace_slow_ms)?;
    let Some(path) = args.get("trace-out") else {
        return Ok(None);
    };
    let sink = TraceSink::to_file(
        Path::new(path),
        sample,
        slow_ms.saturating_mul(1_000_000),
    )?;
    println!(
        "tracing to {path} (sample every {sample} requests, \
         slow-query threshold {slow_ms} ms; 0 = off)"
    );
    Ok(Some(sink))
}

/// Materialize the configured workload.
fn load_workload(cfg: &AppConfig) -> Result<Workload> {
    let d = &cfg.dataset;
    let mut rng = Rng::new(d.seed);
    let mut wl = match d.kind {
        DatasetKind::SparseSynthetic => synthetic::sparse_workload(
            SparseSpec { dim: d.dim, ones: d.sparse_ones },
            d.n,
            d.n_queries,
            QueryModel::Exact,
            &mut rng,
        ),
        DatasetKind::DenseSynthetic => {
            synthetic::dense_workload(d.dim, d.n, d.n_queries, QueryModel::Exact, &mut rng)
        }
        DatasetKind::SiftLike => clustered::clustered_workload(
            ClusteredSpec::sift_like(),
            d.n,
            d.n_queries,
            &mut rng,
        ),
        DatasetKind::GistLike => clustered::clustered_workload(
            ClusteredSpec::gist_like(),
            d.n,
            d.n_queries,
            &mut rng,
        ),
        DatasetKind::MnistLike => {
            mnist_like::mnist_like_workload(d.n, d.n_queries, &mut rng)
        }
        DatasetKind::SantanderLike => {
            santander_like::santander_like_workload(d.n, d.n_queries, &mut rng)
        }
        DatasetKind::Fvecs => {
            let dir = d.data_dir.clone().expect("validated");
            let base = data_io::read_fvecs(&dir.join("base.fvecs"))?;
            let queries = data_io::read_fvecs(&dir.join("query.fvecs"))?;
            let ground_truth = clustered::exact_ground_truth(&base, &queries);
            Workload { base, queries, ground_truth }
        }
    };
    if d.normalize {
        let mean = wl.base.center_and_normalize();
        let mut queries = Dataset::empty(wl.queries.dim());
        for qi in 0..wl.queries.len() {
            queries.push(&Dataset::preprocess_query(wl.queries.get(qi), &mean))?;
        }
        wl.queries = queries;
        wl.ground_truth = clustered::exact_ground_truth(&wl.base, &wl.queries);
    }
    wl.validate()?;
    Ok(wl)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let opts = EvalOptions {
        scale: args.get_parse("scale", 1.0)?,
        seed: args.get_parse("seed", 42u64)?,
    };
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or("results"));
    let ids: Vec<String> = if args.flag("all") {
        ALL_FIGURES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![args.get("figure").unwrap_or("1").to_string()]
    };
    for id in ids {
        let started = Instant::now();
        let fig = run_figure(&id, &opts)?;
        let path = fig.write_csv(&out_dir)?;
        println!("{}", fig.ascii_table());
        println!(
            "wrote {} ({:.1}s)\n",
            path.display(),
            started.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_build(cfg: &AppConfig, args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("index.amidx"));
    let wl = load_workload(cfg)?;
    let mut rng = Rng::new(cfg.dataset.seed ^ 0xA11C);
    let params = cfg.index.to_params();
    let build_start = Instant::now();
    let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    println!(
        "built index: n={} d={} q={} alloc={} rule={} in {:.2}s",
        index.len(),
        index.dim(),
        params.n_classes,
        params.allocation,
        params.rule,
        build_start.elapsed().as_secs_f64()
    );
    amsearch::index::persist::save(&index, &out)?;
    let bytes = std::fs::metadata(&out)?.len();
    println!("saved {} ({:.1} MB)", out.display(), bytes as f64 / 1e6);
    let fp = index.footprint();
    println!(
        "scan representation: mode={} f32_bytes={} resident_bytes={} \
         (compression {:.3}x)",
        index.params().precision,
        fp.bytes,
        fp.compressed_bytes,
        fp.ratio()
    );
    Ok(())
}

fn cmd_query(cfg: &AppConfig, args: &Args) -> Result<()> {
    let top_p: usize = args.get_parse("top-p", 0usize)?;
    let top_k: usize = args.get_parse("top-k", 0usize)?;
    let wl = load_workload(cfg)?;
    let mut rng = Rng::new(cfg.dataset.seed ^ 0xA11C);
    let params = cfg.index.to_params();
    let index = if let Some(path) = args.get("index") {
        println!("loading index from {path} (store={})", cfg.store.mode.name());
        let index = match cfg.store.mode {
            amsearch::store::StoreMode::Resident => {
                amsearch::index::persist::load(Path::new(path))?
            }
            amsearch::store::StoreMode::Paged => amsearch::index::persist::load_paged(
                Path::new(path),
                cfg.store.to_options().cache_bytes,
            )?,
        };
        if index.dim() != wl.base.dim() {
            return Err(amsearch::Error::Shape(format!(
                "index dim {} != workload dim {}",
                index.dim(),
                wl.base.dim()
            )));
        }
        index
    } else {
        println!(
            "building index: n={} d={} q={} alloc={} rule={}",
            wl.base.len(),
            wl.base.dim(),
            params.n_classes,
            params.allocation,
            params.rule
        );
        let build_start = Instant::now();
        let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
        println!("built in {:.2}s", build_start.elapsed().as_secs_f64());
        index
    };

    // defaults and metric come from the index actually being queried —
    // a loaded index may carry different params than the config
    let iparams = *index.params();
    let p = if top_p == 0 { iparams.top_p } else { top_p };
    let k = (if top_k == 0 { iparams.top_k } else { top_k })
        .min(index.len())
        .max(1);
    let mut ops = OpsCounter::new();
    let mut recall = Recall::new();
    let mut recall_k = RecallAtK::new(k);
    // exact top-k ground truth for recall@k (the 1-NN ids are already in
    // the workload, so the reference is only needed at k > 1); computed
    // BEFORE the timer so the wall-clock numbers measure only the index
    let truth_k: Option<Vec<Vec<u32>>> = (k > 1).then(|| {
        let reference = Exhaustive::new(wl.base.clone(), iparams.metric);
        (0..wl.queries.len())
            .map(|qi| {
                let mut tops = OpsCounter::new();
                reference
                    .query_k(wl.queries.get(qi), k, &mut tops)
                    .into_iter()
                    .map(|n| n.id)
                    .collect()
            })
            .collect()
    });
    let started = Instant::now();
    for (qi, &gt) in wl.ground_truth.iter().enumerate() {
        let x = wl.queries.get(qi);
        let r = index.query_k(x, p, k, &mut ops);
        recall.record(r.id() == gt);
        if let Some(truth_k) = &truth_k {
            let got: Vec<u32> = r.neighbors.iter().map(|n| n.id).collect();
            recall_k.record(&got, &truth_k[qi]);
        }
    }
    let elapsed = started.elapsed();
    // a paged store failure yields zero-candidate classes; fail the run
    // instead of printing recall computed from partial answers
    if let Some(e) = index.store_error() {
        return Err(amsearch::Error::Data(format!("vector store failed: {e}")));
    }
    let exhaustive_ops = (wl.base.len() * wl.base.dim()) as u64;
    println!(
        "queries={} p={} k={} recall@1={:.4} (+/-{:.4})",
        recall.total(),
        p,
        k,
        recall.value(),
        recall.std_error()
    );
    if k > 1 {
        println!("recall@{k}={:.4}", recall_k.value());
    }
    println!(
        "ops/search={:.0} relative_complexity={:.4} (exhaustive={})",
        ops.per_search(),
        ops.relative_to(exhaustive_ops),
        exhaustive_ops
    );
    println!(
        "wall: total={:.3}s mean={:.1}us",
        elapsed.as_secs_f64(),
        elapsed.as_micros() as f64 / recall.total().max(1) as f64
    );
    if index.is_paged() {
        let st = index.store_stats();
        let lookups = (st.cache_hits + st.cache_misses).max(1);
        println!(
            "store: paged  read {} of {} disk bytes ({} extent reads, \
             cache hit rate {:.1}%, {} bytes resident)",
            st.bytes_read,
            st.bytes_disk,
            st.extent_reads,
            st.cache_hits as f64 * 100.0 / lookups as f64,
            st.bytes_resident
        );
    }
    Ok(())
}

fn cmd_serve(cfg: &AppConfig, args: &Args) -> Result<()> {
    let mut serve_cfg = cfg.serve.to_coordinator();
    if let Some(w) = args.get("workers") {
        serve_cfg.workers = w
            .parse()
            .map_err(|_| amsearch::Error::Config(format!("--workers: bad value '{w}'")))?;
    }
    let backend_kind: Backend = match args.get("backend") {
        Some(s) => s.parse()?,
        None => cfg.backend.kind,
    };
    serve_cfg.quality_sample =
        args.get_parse("quality-sample", serve_cfg.quality_sample)?;
    let repeat: usize = args.get_parse("repeat", 1usize)?.max(1);
    // the config workload provides the base for a fresh build and the
    // queries for in-process driving; serving a saved index over TCP
    // needs neither, so skip the (possibly large) generation entirely
    let index_arg = args.get("index");
    let wl = if index_arg.is_some() && args.get("listen").is_some() {
        None
    } else {
        Some(load_workload(cfg)?)
    };
    let factory = match index_arg {
        Some(path) => {
            println!(
                "loading index from {path} (store={})",
                cfg.store.mode.name()
            );
            EngineFactory::from_index_file_with_store(
                Path::new(path),
                backend_kind,
                Some(cfg.backend.artifacts_dir.clone()),
                &cfg.store.to_options(),
            )?
        }
        None => {
            let wl = wl.as_ref().expect("workload loaded when building");
            let mut rng = Rng::new(cfg.dataset.seed ^ 0x5EED);
            let index = Arc::new(AmIndex::build(
                wl.base.clone(),
                cfg.index.to_params(),
                &mut rng,
            )?);
            EngineFactory {
                index,
                backend: backend_kind,
                artifacts_dir: Some(cfg.backend.artifacts_dir.clone()),
            }
        }
    };
    let index = factory.index.clone();
    if let Some(wl) = &wl {
        if index.dim() != wl.base.dim() {
            return Err(amsearch::Error::Shape(format!(
                "index dim {} != workload dim {}",
                index.dim(),
                wl.base.dim()
            )));
        }
    }
    println!(
        "serving: n={} d={} q={} backend={} workers={} batch={} scan={} store={}",
        index.len(),
        index.dim(),
        index.params().n_classes,
        backend_kind,
        serve_cfg.workers,
        serve_cfg.max_batch,
        index.params().precision,
        index.store().kind()
    );
    let trace = build_trace_sink(&cfg.serve, args)?;
    let server = Arc::new(SearchServer::start_traced(
        factory,
        serve_cfg,
        trace.clone(),
    )?);

    if let Some(listen) = args.get("listen") {
        // TCP front door: serve remote clients until a SHUTDOWN frame
        // arrives (amsearch loadgen ... --shutdown), then drain the
        // network layer BEFORE the coordinator so no in-flight request
        // is ever dropped
        let net = NetServer::bind(server.clone(), listen, NetConfig::default())?;
        println!(
            "listening on {} (binary AMNP v1 + JSON-lines; \
             PING/STATS/SHUTDOWN admin ops)",
            net.local_addr()
        );
        net.join();
        let m = server.metrics();
        println!("front door drained; served {} requests", m.requests);
        println!("latency:  {}", m.latency.summary());
        println!("service:  {}", m.service.summary());
        println!(
            "batches={} mean_batch={:.2} ops/search={:.0} scan_fusion={:.2}",
            m.batches,
            m.mean_batch_size(),
            m.ops.per_search(),
            m.scan.fusion_factor()
        );
        if let Some(t) = &trace {
            println!("trace records emitted: {}", t.emitted());
        }
        server.shutdown();
        return Ok(());
    }

    // load generation: one client thread per concurrent stream
    let wl = wl.expect("in-process serving keeps the config workload");
    let started = Instant::now();
    let streams = 16usize;
    let total = wl.queries.len() * repeat;
    let recall = {
        let wl = &wl;
        let results = amsearch::util::concurrent_map(streams, streams, |s| {
            let mut r = Recall::new();
            let mut i = s;
            while i < total {
                let qi = i % wl.queries.len();
                let resp = server
                    .search(wl.queries.get(qi).to_vec(), 0, 0)
                    .expect("search");
                r.record(resp.neighbor() == Some(wl.ground_truth[qi]));
                i += streams;
            }
            r
        });
        let mut total_r = Recall::new();
        for r in &results {
            total_r.merge(r);
        }
        total_r
    };
    let elapsed = started.elapsed();
    let m = server.metrics();
    println!(
        "served {} requests in {:.3}s -> {:.0} qps",
        recall.total(),
        elapsed.as_secs_f64(),
        recall.total() as f64 / elapsed.as_secs_f64()
    );
    println!("recall@1 = {:.4}", recall.value());
    println!("latency:  {}", m.latency.summary());
    println!("service:  {}", m.service.summary());
    println!(
        "batches={} mean_batch={:.2} ops/search={:.0} scan_fusion={:.2}",
        m.batches,
        m.mean_batch_size(),
        m.ops.per_search(),
        m.scan.fusion_factor()
    );
    server.shutdown();
    Ok(())
}

fn cmd_shard_plan(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n_shards: usize = args.get_parse("shards", 2usize)?;
    let strategy: ShardStrategy = args
        .get("strategy")
        .unwrap_or("balanced")
        .parse()?;
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or("cluster_plan"));
    let wl = load_workload(cfg)?;
    let mut rng = Rng::new(cfg.dataset.seed ^ 0xA11C);
    let params = cfg.index.to_params();
    let build_start = Instant::now();
    let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    println!(
        "built index: n={} d={} q={} in {:.2}s",
        index.len(),
        index.dim(),
        params.n_classes,
        build_start.elapsed().as_secs_f64()
    );
    let plan = ShardPlan::for_index(&index, n_shards, strategy)?;
    let files = cluster::write_cluster(&index, &plan, &out_dir)?;
    let sizes = plan.shard_sizes(&index.partition().sizes());
    for (si, file) in files.iter().enumerate() {
        println!(
            "shard {si}: {} classes, {} vectors -> {}",
            plan.classes_of[si].len(),
            sizes[si],
            file.display()
        );
    }
    println!(
        "wrote {} (strategy={strategy}, routing table {}x{}x{} f32)",
        out_dir.join(cluster::plan::MANIFEST_FILE).display(),
        n_shards,
        index.dim(),
        index.dim()
    );
    Ok(())
}

fn cmd_serve_cluster(cfg: &AppConfig, args: &Args) -> Result<()> {
    let listen = args.get("listen").unwrap_or("127.0.0.1:4177").to_string();
    let mut ccfg = ClusterConfig {
        n_shards: args.get_parse("shards", 2usize)?,
        strategy: args.get("strategy").unwrap_or("balanced").parse()?,
        coordinator: cfg.serve.to_coordinator(),
        backend: cfg.backend.kind,
        artifacts_dir: Some(cfg.backend.artifacts_dir.clone()),
        store: cfg.store.to_options(),
        ..Default::default()
    };
    ccfg.router.fan_out = args.get_parse("fan-out", 0usize)?;
    ccfg.router.workers = args.get_parse("router-workers", 4usize)?.max(1);
    // one knob arms both tiers: the router's full-fanout shadow (the
    // fan-out knob's cost) and each shard's exact-scan shadow (the
    // poll knob's cost)
    let quality: u64 =
        args.get_parse("quality-sample", cfg.serve.quality_sample)?;
    ccfg.router.quality_sample = quality;
    ccfg.coordinator.quality_sample = quality;
    ccfg.trace = build_trace_sink(&cfg.serve, args)?;

    let cluster = if let Some(dir) = args.get("plan-dir") {
        println!("loading cluster plan from {dir}");
        ClusterHarness::launch_from_dir(Path::new(dir), &listen, &ccfg)?
    } else {
        let wl = load_workload(cfg)?;
        let mut rng = Rng::new(cfg.dataset.seed ^ 0xA11C);
        let index = AmIndex::build(wl.base.clone(), cfg.index.to_params(), &mut rng)?;
        ClusterHarness::launch(&index, &listen, &ccfg)?
    };
    for si in 0..cluster.n_shards() {
        println!("shard {si} at {}", cluster.shard_addr(si));
    }
    println!(
        "router listening on {} ({} shards, fan-out {}; \
         AMNP v1 + JSON-lines; PING/STATS/SHUTDOWN admin ops)",
        cluster.router_addr(),
        cluster.n_shards(),
        cluster.router().fan_out()
    );
    // serve until a client sends SHUTDOWN (loadgen --shutdown), then
    // tear the tiers down router-first so nothing in flight is dropped
    cluster.join();
    let m = cluster.router().metrics();
    println!("router drained; routed {} requests ({} errors)", m.requests, m.errors);
    println!("end-to-end:    {}", m.latency.summary());
    println!("shard service: {}", m.shard_service.summary());
    println!(
        "fan-out: mean {:.2} over {} shards ({} full fan-outs)",
        m.fanout.mean_fanout(),
        cluster.n_shards(),
        m.fanout.full_fanouts
    );
    if let Some(t) = &ccfg.trace {
        println!("trace records emitted: {}", t.emitted());
    }
    cluster.shutdown();
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:4077").to_string();
    let cfg = LoadGenConfig {
        connections: args.get_parse("connections", 4usize)?.max(1),
        requests: args.get_parse("requests", 1000usize)?,
        depth: args.get_parse("depth", 8usize)?.max(1),
        top_p: args.get_parse("top-p", 0usize)?,
        top_k: args.get_parse("top-k", 0usize)?,
        connect_timeout: std::time::Duration::from_secs(
            args.get_parse("connect-timeout-s", 10u64)?,
        ),
    };
    // one admin connection: discover the index dimension, and reused at
    // the end for the final stats snapshot / optional shutdown
    let mut admin = NetClient::connect_retry(&addr, cfg.connect_timeout)?;
    let stats = admin.stats()?;
    let dim = stats
        .get("dim")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| amsearch::Error::Coordinator("stats missing 'dim'".into()))?;
    println!(
        "server at {addr}: role={} dim={dim} n={}",
        stats.get("role").and_then(|v| v.as_str()).unwrap_or("?"),
        stats.get("n_vectors").and_then(|v| v.as_usize()).unwrap_or(0)
    );
    // synthetic query pool of the right dimension (load generation does
    // not need ground truth, only realistic request shapes)
    let seed: u64 = args.get_parse("seed", 7u64)?;
    let mut rng = Rng::new(seed);
    let queries: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();

    let report = loadgen::run(&addr, &queries, &cfg)?;
    report.print();
    let server_stats = admin.stats()?;
    // net-layer overload counters (refusals + current pipelined depth)
    // exported by the server's STATS op alongside its own snapshot
    if let Some(net) = server_stats.get("net") {
        println!(
            "server net: refused_connections={} inflight={}",
            net.get("refused_connections")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            net.get("inflight").and_then(|v| v.as_u64()).unwrap_or(0)
        );
    }
    if let Some(fanout) = server_stats.get("fanout") {
        println!(
            "router fan-out: mean {:.2} ({} full fan-outs)",
            fanout.get("mean_fanout").and_then(|v| v.as_f64()).unwrap_or(0.0),
            fanout.get("full_fanouts").and_then(|v| v.as_u64()).unwrap_or(0)
        );
    }
    // online recall estimate, present iff the server runs with
    // --quality-sample
    if let Some(q) = server_stats.get("quality") {
        println!(
            "online quality: recall {:.4} over {} shadow samples \
             ({} dropped, mean rank displacement {:.2})",
            q.get("recall").and_then(|v| v.as_f64()).unwrap_or(1.0),
            q.get("samples").and_then(|v| v.as_u64()).unwrap_or(0),
            q.get("dropped").and_then(|v| v.as_u64()).unwrap_or(0),
            q.get("mean_rank_displacement")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        );
    }
    // routing overhead: the gap between what the router's clients saw
    // end-to-end and what the shards spent serving (scatter + gather +
    // queueing in the routing tier)
    if server_stats.get("role").and_then(|v| v.as_str()) == Some("router") {
        let mean = |key: &str| {
            server_stats
                .get(key)
                .and_then(|h| h.get("mean_ns"))
                .and_then(|v| v.as_f64())
        };
        if let (Some(e2e), Some(shard)) = (mean("latency"), mean("shard_service")) {
            println!(
                "router overhead: end-to-end mean {:.1}us vs shard \
                 service mean {:.1}us (delta {:.1}us)",
                e2e / 1e3,
                shard / 1e3,
                (e2e - shard) / 1e3
            );
        }
    }
    // compression visible from the wire: the server's scan footprint
    if let Some(index) = server_stats.get("index") {
        println!(
            "server index: quant={} bytes={} compressed_bytes={} \
             (compression {:.3}x)",
            server_stats
                .get("quant")
                .and_then(|q| q.get("mode"))
                .and_then(|v| v.as_str())
                .unwrap_or("?"),
            index.get("bytes").and_then(|v| v.as_u64()).unwrap_or(0),
            index
                .get("compressed_bytes")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            index
                .get("compression_ratio")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        );
    }

    if let Some(path) = args.get("json") {
        // one artifact: the client-side report plus the server's own
        // metrics snapshot after the run
        let mut o = std::collections::BTreeMap::new();
        o.insert("loadgen".to_string(), report.to_json());
        o.insert("server".to_string(), server_stats);
        let doc = Json::Obj(o).to_string();
        std::fs::write(path, doc)?;
        println!("wrote {path}");
    }
    if args.flag("shutdown") {
        admin.shutdown_server()?;
        println!("server shutdown acknowledged");
    }
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:4077").to_string();
    let timeout = std::time::Duration::from_secs(
        args.get_parse("connect-timeout-s", 10u64)?,
    );
    let mut client = NetClient::connect_retry(&addr, timeout)?;
    let text = client.metrics_text()?;
    print!("{text}");
    if args.flag("require-store") && !args.flag("check") {
        return Err(amsearch::Error::Config(
            "--require-store only means something with --check".into(),
        ));
    }
    if args.flag("check") {
        let mut required: Vec<&str> = obs::REQUIRED_FAMILIES.to_vec();
        if args.flag("require-store") {
            required.extend_from_slice(&obs::prom::STORE_FAMILIES);
        }
        obs::prom::validate(&text, &required)
            .map_err(amsearch::Error::Coordinator)?;
        eprintln!(
            "metrics check: exposition OK ({} lines, {} required families \
             present)",
            text.lines().count(),
            required.len()
        );
    }
    Ok(())
}

/// Render a JSON document with indentation for human eyes — the wire
/// form is single-line (JSON-lines framing), which is unreadable for
/// the nested EXPLAIN report.
fn pretty_json(j: &Json, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth + 1);
    match j {
        Json::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, v)) in o.iter().enumerate() {
                out.push_str(&pad);
                out.push_str(&Json::Str(k.clone()).to_string());
                out.push_str(": ");
                pretty_json(v, depth + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&"  ".repeat(depth));
            out.push('}');
        }
        Json::Arr(a) if a.iter().any(|v| matches!(v, Json::Obj(_) | Json::Arr(_))) => {
            out.push_str("[\n");
            for (i, v) in a.iter().enumerate() {
                out.push_str(&pad);
                pretty_json(v, depth + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&"  ".repeat(depth));
            out.push(']');
        }
        other => out.push_str(&other.to_string()),
    }
}

fn cmd_explain(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:4077").to_string();
    let timeout = std::time::Duration::from_secs(
        args.get_parse("connect-timeout-s", 10u64)?,
    );
    let mut client = NetClient::connect_retry(&addr, timeout)?;
    // discover the index dimension the same way loadgen does, then
    // synthesize one reproducible query from --seed
    let stats = client.stats()?;
    let dim = stats
        .get("dim")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| amsearch::Error::Coordinator("stats missing 'dim'".into()))?;
    let seed: u64 = args.get_parse("seed", 7u64)?;
    let mut rng = Rng::new(seed);
    let vector: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let top_p: u32 = args.get_parse("top-p", 0u32)?;
    let top_k: u32 = args.get_parse("top-k", 0u32)?;
    let exact = args.flag("exact");
    println!(
        "explaining one query against {addr} (role={}, dim={dim}, \
         seed={seed}, exact={exact})",
        stats.get("role").and_then(|v| v.as_str()).unwrap_or("?")
    );
    let report = client.explain(&vector, top_p, top_k, exact)?;
    let mut out = String::new();
    pretty_json(&report, 0, &mut out);
    println!("{out}");
    if let Some(e) = report.get("exact") {
        println!(
            "ground truth: recall {:.4}, exact match {}, \
             mean rank displacement {:.2}, mean distance error {:.3e}",
            e.get("recall").and_then(|v| v.as_f64()).unwrap_or(1.0),
            e.get("matches_exactly").and_then(|v| v.as_bool()).unwrap_or(false),
            e.get("mean_rank_displacement")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            e.get("mean_distance_error")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        );
    }
    Ok(())
}

fn cmd_dash(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:4077").to_string();
    let timeout = std::time::Duration::from_secs(
        args.get_parse("connect-timeout-s", 10u64)?,
    );
    let interval =
        std::time::Duration::from_millis(args.get_parse("interval-ms", 1000u64)?.max(100));
    let iterations: u64 = args.get_parse("iterations", 0u64)?;
    let mut client = NetClient::connect_retry(&addr, timeout)?;
    let mut last_requests: Option<u64> = None;
    let mut last_poll = Instant::now();
    let mut frame: u64 = 0;
    loop {
        let stats = client.stats()?;
        let now = Instant::now();
        let requests = stats.get("requests").and_then(|v| v.as_u64()).unwrap_or(0);
        let qps = match last_requests {
            Some(prev) => {
                let dt = now.duration_since(last_poll).as_secs_f64();
                if dt > 0.0 {
                    requests.saturating_sub(prev) as f64 / dt
                } else {
                    0.0
                }
            }
            None => 0.0,
        };
        last_requests = Some(requests);
        last_poll = now;
        // one frame = clear screen + redraw (plain ANSI, no TTY deps)
        let mut s = String::from("\x1b[2J\x1b[H");
        let role = stats.get("role").and_then(|v| v.as_str()).unwrap_or("?");
        s.push_str(&format!(
            "amsearch dash — {addr} (role={role})  [frame {frame}]\n\n"
        ));
        s.push_str(&format!(
            "requests {requests}   errors {}   qps {qps:.1}\n",
            stats.get("errors").and_then(|v| v.as_u64()).unwrap_or(0)
        ));
        if let Some(w) = stats.get("window") {
            let us = |key: &str| {
                w.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e3
            };
            s.push_str(&format!(
                "latency ({:.0}s window): p50 {:.1}us  p90 {:.1}us  \
                 p99 {:.1}us  max {:.1}us\n",
                w.get("window_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                us("p50_ns"),
                us("p90_ns"),
                us("p99_ns"),
                us("max_ns")
            ));
        }
        if let Some(q) = stats.get("quality") {
            s.push_str(&format!(
                "quality: recall {:.4} over {} shadow samples \
                 ({} dropped, rank displacement {:.2})\n",
                q.get("recall").and_then(|v| v.as_f64()).unwrap_or(1.0),
                q.get("samples").and_then(|v| v.as_u64()).unwrap_or(0),
                q.get("dropped").and_then(|v| v.as_u64()).unwrap_or(0),
                q.get("mean_rank_displacement")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0)
            ));
        } else {
            s.push_str("quality: sampling off (start with --quality-sample N)\n");
        }
        if let Some(sel) = stats.get("selectivity") {
            if let Some(sf) = sel.get("served_from") {
                s.push_str(&format!(
                    "served-from: top-ranked source wins {:.1}% of {} answers\n",
                    sf.get("top1_fraction").and_then(|v| v.as_f64()).unwrap_or(1.0)
                        * 100.0,
                    sf.get("total").and_then(|v| v.as_u64()).unwrap_or(0)
                ));
            }
            if let Some(sv) = sel.get("survival") {
                s.push_str(&format!(
                    "rerank survival: {:.4} ({} candidates -> {} survivors)\n",
                    sv.get("ratio").and_then(|v| v.as_f64()).unwrap_or(1.0),
                    sv.get("candidates").and_then(|v| v.as_u64()).unwrap_or(0),
                    sv.get("survivors").and_then(|v| v.as_u64()).unwrap_or(0)
                ));
            }
        }
        if let Some(st) = stats.get("store") {
            let mb = |key: &str| {
                st.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e6
            };
            if st.get("kind").and_then(|v| v.as_str()) == Some("paged") {
                s.push_str(&format!(
                    "store: paged  {:.1} MB read over {} extent reads  \
                     cache hit {:.1}% ({:.1} of {:.1} MB resident, \
                     {} evictions)\n",
                    mb("bytes_read"),
                    st.get("extent_reads").and_then(|v| v.as_u64()).unwrap_or(0),
                    st.get("cache_hit_rate").and_then(|v| v.as_f64()).unwrap_or(0.0)
                        * 100.0,
                    mb("bytes_resident"),
                    mb("bytes_disk"),
                    st.get("cache_evictions").and_then(|v| v.as_u64()).unwrap_or(0)
                ));
            } else {
                s.push_str(&format!(
                    "store: resident ({:.1} MB of exact vectors in RAM)\n",
                    mb("bytes_resident")
                ));
            }
        }
        if let Some(fe) = stats.get("fanout_effectiveness") {
            s.push_str(&format!(
                "fan-out effectiveness: true winner from top-ranked shard \
                 {:.1}% of {} sampled answers\n",
                fe.get("top1_fraction").and_then(|v| v.as_f64()).unwrap_or(1.0)
                    * 100.0,
                fe.get("total").and_then(|v| v.as_u64()).unwrap_or(0)
            ));
        }
        if let Some(Json::Arr(shards)) = stats.get("shard_quality") {
            s.push_str("shard capture (full-fanout truth captured at current s):\n");
            for (si, sq) in shards.iter().enumerate() {
                s.push_str(&format!(
                    "  shard {si}: {:.4} ({} of {} truth neighbors)\n",
                    sq.get("capture_rate").and_then(|v| v.as_f64()).unwrap_or(1.0),
                    sq.get("captured").and_then(|v| v.as_u64()).unwrap_or(0),
                    sq.get("truth").and_then(|v| v.as_u64()).unwrap_or(0)
                ));
            }
        }
        print!("{s}");
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        frame += 1;
        if iterations > 0 && frame >= iterations {
            println!();
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let manifest = Manifest::load(&dir)?;
    println!("manifest v{} in {}:", manifest.version, dir.display());
    for e in manifest.entries() {
        println!(
            "  {:<36} kind={:<16} d={:<4} q={:<4} k={:<4} b={} file={}",
            e.name,
            e.kind,
            e.d,
            e.q.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            e.k.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            e.b,
            e.file
        );
    }
    Ok(())
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(
        raw,
        &["all", "help", "shutdown", "check", "exact", "require-store"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.pos(0).is_none() {
        println!("{USAGE}");
        return;
    }
    let mut cfg = match args.get("config") {
        Some(path) => match AppConfig::from_file(Path::new(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        },
        None => AppConfig::default(),
    };
    if let Err(e) = apply_scan_precision_args(&mut cfg, &args)
        .and_then(|()| apply_store_args(&mut cfg, &args))
    {
        eprintln!("error: {e}\n{USAGE}");
        std::process::exit(2);
    }
    let result = match args.pos(0).unwrap() {
        "eval" => cmd_eval(&args),
        "build" => cmd_build(&cfg, &args),
        "query" => cmd_query(&cfg, &args),
        "serve" => cmd_serve(&cfg, &args),
        "loadgen" => cmd_loadgen(&args),
        "shard-plan" => cmd_shard_plan(&cfg, &args),
        "serve-cluster" => cmd_serve_cluster(&cfg, &args),
        "metrics" => cmd_metrics(&args),
        "explain" => cmd_explain(&args),
        "dash" => cmd_dash(&args),
        "artifacts" => cmd_artifacts(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
