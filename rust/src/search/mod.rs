//! Search primitives: distance kernels and bounded top-k selection.

pub mod distance;
pub mod policy;
pub mod topk;

pub use distance::{
    accumulate, accumulate_pruned, distance_pruned, DistanceKernel, Metric,
};
pub use policy::AdaptivePolicy;
pub use topk::{invert_polled, one_nn, top_p_largest, Neighbor, TopK};
