//! Search primitives: distance kernels (scalar reference and
//! runtime-dispatched SIMD backends) and bounded top-k selection.

pub mod distance;
pub mod kernels;
pub mod policy;
pub mod topk;

pub use distance::{
    accumulate, accumulate_pruned, distance_pruned, DistanceKernel, Metric,
};
pub use kernels::{Backend, Kernels};
pub use policy::AdaptivePolicy;
pub use topk::{invert_polled, one_nn, top_p_largest, Neighbor, TopK};
