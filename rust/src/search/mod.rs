//! Search primitives: distance kernels and bounded top-k selection.

pub mod distance;
pub mod policy;
pub mod topk;

pub use distance::Metric;
pub use policy::AdaptivePolicy;
pub use topk::{top_p_largest, TopK};
