//! Search primitives: distance kernels and bounded top-k selection.

pub mod distance;
pub mod policy;
pub mod topk;

pub use distance::{distance_pruned, Metric};
pub use policy::AdaptivePolicy;
pub use topk::{invert_polled, lex_min_update, top_p_largest, TopK};
