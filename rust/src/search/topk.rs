//! Bounded top-k selection.
//!
//! `TopK` keeps the k smallest items seen so far under the lexicographic
//! `(key, id)` order (a bounded max-heap); used for candidate-scan
//! results (k smallest distances), per-query accumulators in the batched
//! class-grouped scan, and, with negated keys, top-p class selection.
//!
//! NaN keys sort last: they are never admitted to the heap, so a NaN
//! distance or score can never be selected and never poisons the
//! comparisons (`into_sorted` cannot panic on NaN).

use std::cmp::Ordering;

/// Bounded "k smallest by `(key, id)`" selector.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// max-heap on `(key, id)`, so the root is the current worst of the
    /// best-k
    heap: Vec<(f32, u32)>,
}

/// Lexicographic `(key, id)` greater-than; keys never contain NaN inside
/// the heap (NaN is rejected at [`TopK::push`]).
#[inline]
fn lex_gt(a: (f32, u32), b: (f32, u32)) -> bool {
    match a.0.partial_cmp(&b.0) {
        Some(Ordering::Greater) => true,
        Some(Ordering::Equal) => a.1 > b.1,
        _ => false,
    }
}

impl TopK {
    /// New selector keeping the `k` smallest keys. `k` must be > 0.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TopK { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Current number of kept items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been pushed.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Largest kept key (the current cutoff), if full.  Used as the
    /// pruning threshold by the batched candidate scan.
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Offer an item.  NaN keys sort last and are never kept.
    #[inline]
    pub fn push(&mut self, key: f32, id: u32) {
        if key.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((key, id));
            self.sift_up(self.heap.len() - 1);
        } else if lex_gt(self.heap[0], (key, id)) {
            self.heap[0] = (key, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if lex_gt(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && lex_gt(self.heap[l], self.heap[largest]) {
                largest = l;
            }
            if r < n && lex_gt(self.heap[r], self.heap[largest]) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Consume into `(key, id)` pairs sorted ascending by `(key, id)`
    /// (ties by id for determinism).  Never panics: NaN keys cannot enter
    /// the heap, and the comparator is total regardless.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Select the indices of the `p` largest values (top-p classes by score),
/// ordered from largest to smallest.  Ties broken by smaller index.
/// NaN values sort last: a NaN-scored class is never selected, and fewer
/// than `p` indices are returned when NaN leaves too few candidates.
pub fn top_p_largest(values: &[f32], p: usize) -> Vec<u32> {
    let mut sel = TopK::new(p.min(values.len()).max(1));
    for (i, &v) in values.iter().enumerate() {
        sel.push(-v, i as u32); // negate: TopK keeps smallest
    }
    sel.into_sorted().into_iter().map(|(_, i)| i).collect()
}

/// In-place lexicographic `(key, id)` minimum update — the candidate
/// scans' shared selection rule (strictly smaller key wins; equal keys
/// resolve to the smaller id; NaN keys never win).  Both the native
/// class-grouped scan and the PJRT scan fold through this exact
/// function, which is what keeps their tie-breaking identical.
#[inline]
pub fn lex_min_update(best: &mut (f32, u32), key: f32, id: u32) {
    if key < best.0 || (key == best.0 && id < best.1) {
        *best = (key, id);
    }
}

/// Invert a per-query polled-class map into (class → querying batch
/// members): `result[c]` lists the batch indices whose polled set
/// contains class `c`, in batch order.  The pivot of the class-grouped
/// candidate scan.
pub fn invert_polled(polled: &[Vec<u32>], n_classes: usize) -> Vec<Vec<u32>> {
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for (bi, pol) in polled.iter().enumerate() {
        for &ci in pol {
            by_class[ci as usize].push(bi as u32);
        }
    }
    by_class
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, &v) in [5., 1., 9., 3., 7., 2., 8.].iter().enumerate() {
            t.push(v, i as u32);
        }
        let got = t.into_sorted();
        let keys: Vec<f32> = got.iter().map(|x| x.0).collect();
        assert_eq!(keys, vec![1., 2., 3.]);
        let ids: Vec<u32> = got.iter().map(|x| x.1).collect();
        assert_eq!(ids, vec![1, 5, 3]);
    }

    #[test]
    fn matches_full_sort_prefix() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20) as usize;
            let vals: Vec<f32> = (0..n).map(|_| (rng.uniform() * 100.0) as f32).collect();
            let mut t = TopK::new(k);
            for (i, &v) in vals.iter().enumerate() {
                t.push(v, i as u32);
            }
            let got: Vec<f32> = t.into_sorted().iter().map(|x| x.0).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want: Vec<f32> = sorted.into_iter().take(k).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn tie_keys_keep_smaller_ids() {
        // exact (key, id) lexicographic selection, important for the
        // batched scan's TopK(1) accumulators: equal keys resolve to the
        // smaller id no matter the push order
        let mut t = TopK::new(1);
        t.push(2.0, 7);
        t.push(2.0, 3);
        t.push(2.0, 5);
        assert_eq!(t.into_sorted(), vec![(2.0, 3)]);
        let mut t = TopK::new(2);
        for &(k, id) in &[(5.0f32, 9u32), (5.0, 1), (5.0, 4), (6.0, 0)] {
            t.push(k, id);
        }
        assert_eq!(t.into_sorted(), vec![(5.0, 1), (5.0, 4)]);
    }

    #[test]
    fn threshold_only_when_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), None);
        t.push(5.0, 0);
        assert_eq!(t.threshold(), None);
        t.push(3.0, 1);
        assert_eq!(t.threshold(), Some(5.0));
        t.push(1.0, 2);
        assert_eq!(t.threshold(), Some(3.0));
    }

    #[test]
    fn top_p_largest_ordering() {
        let scores = [0.5f32, 9.0, 3.0, 9.0, 1.0];
        assert_eq!(top_p_largest(&scores, 3), vec![1, 3, 2]);
        assert_eq!(top_p_largest(&scores, 1), vec![1]);
        // p larger than len clamps
        assert_eq!(top_p_largest(&scores, 10).len(), 5);
    }

    #[test]
    fn nan_keys_are_never_selected_and_never_panic() {
        // regression: partial_cmp(...).unwrap() used to panic whenever a
        // NaN distance/score entered the heap
        let mut t = TopK::new(3);
        for (i, &v) in [5.0f32, f32::NAN, 1.0, f32::NAN, 3.0].iter().enumerate() {
            t.push(v, i as u32);
        }
        let got = t.into_sorted(); // must not panic
        assert_eq!(got, vec![(1.0, 2), (3.0, 4), (5.0, 0)]);

        // NaN-scored classes are skipped by top-p selection
        let scores = [f32::NAN, 2.0, f32::NAN, 1.0];
        assert_eq!(top_p_largest(&scores, 3), vec![1, 3]);

        // all-NaN input selects nothing (and must not panic)
        let all_nan = [f32::NAN; 4];
        assert!(top_p_largest(&all_nan, 2).is_empty());

        // a NaN pushed into a full heap must not evict anything
        let mut t = TopK::new(1);
        t.push(2.0, 0);
        t.push(f32::NAN, 1);
        assert_eq!(t.into_sorted(), vec![(2.0, 0)]);
    }

    #[test]
    fn lex_min_update_matches_scan_rule() {
        let mut best = (f32::INFINITY, u32::MAX);
        lex_min_update(&mut best, 3.0, 7);
        assert_eq!(best, (3.0, 7));
        lex_min_update(&mut best, 3.0, 9); // larger id on tie: no change
        assert_eq!(best, (3.0, 7));
        lex_min_update(&mut best, 3.0, 2); // smaller id on tie: wins
        assert_eq!(best, (3.0, 2));
        lex_min_update(&mut best, f32::NAN, 0); // NaN never wins
        assert_eq!(best, (3.0, 2));
        lex_min_update(&mut best, 1.0, 5);
        assert_eq!(best, (1.0, 5));
    }

    #[test]
    fn invert_polled_builds_class_major_map() {
        let polled = vec![vec![0u32, 2], vec![2], vec![], vec![1, 2, 0]];
        let by_class = invert_polled(&polled, 4);
        assert_eq!(by_class[0], vec![0, 3]);
        assert_eq!(by_class[1], vec![3]);
        assert_eq!(by_class[2], vec![0, 1, 3]);
        assert!(by_class[3].is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_k_panics() {
        TopK::new(0);
    }
}
