//! Bounded top-k selection.
//!
//! `TopK` keeps the k smallest-keyed items seen so far (a bounded
//! max-heap); used for candidate-scan results (k smallest distances) and,
//! with negated keys, top-p class selection.

/// Bounded "k smallest" selector.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// max-heap on key, so the root is the current worst of the best-k
    heap: Vec<(f32, u32)>,
}

impl TopK {
    /// New selector keeping the `k` smallest keys. `k` must be > 0.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TopK { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Current number of kept items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been pushed.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Largest kept key (the current cutoff), if full.
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Offer an item.
    #[inline]
    pub fn push(&mut self, key: f32, id: u32) {
        if self.heap.len() < self.k {
            self.heap.push((key, id));
            self.sift_up(self.heap.len() - 1);
        } else if key < self.heap[0].0 {
            self.heap[0] = (key, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 > self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && self.heap[l].0 > self.heap[largest].0 {
                largest = l;
            }
            if r < n && self.heap[r].0 > self.heap[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Consume into `(key, id)` pairs sorted ascending by key (ties by id
    /// for determinism).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Select the indices of the `p` largest values (top-p classes by score),
/// ordered from largest to smallest.  Ties broken by smaller index.
pub fn top_p_largest(values: &[f32], p: usize) -> Vec<u32> {
    let mut sel = TopK::new(p.min(values.len()).max(1));
    for (i, &v) in values.iter().enumerate() {
        sel.push(-v, i as u32); // negate: TopK keeps smallest
    }
    sel.into_sorted().into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, &v) in [5., 1., 9., 3., 7., 2., 8.].iter().enumerate() {
            t.push(v, i as u32);
        }
        let got = t.into_sorted();
        let keys: Vec<f32> = got.iter().map(|x| x.0).collect();
        assert_eq!(keys, vec![1., 2., 3.]);
        let ids: Vec<u32> = got.iter().map(|x| x.1).collect();
        assert_eq!(ids, vec![1, 5, 3]);
    }

    #[test]
    fn matches_full_sort_prefix() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20) as usize;
            let vals: Vec<f32> = (0..n).map(|_| (rng.uniform() * 100.0) as f32).collect();
            let mut t = TopK::new(k);
            for (i, &v) in vals.iter().enumerate() {
                t.push(v, i as u32);
            }
            let got: Vec<f32> = t.into_sorted().iter().map(|x| x.0).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want: Vec<f32> = sorted.into_iter().take(k).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn threshold_only_when_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), None);
        t.push(5.0, 0);
        assert_eq!(t.threshold(), None);
        t.push(3.0, 1);
        assert_eq!(t.threshold(), Some(5.0));
        t.push(1.0, 2);
        assert_eq!(t.threshold(), Some(3.0));
    }

    #[test]
    fn top_p_largest_ordering() {
        let scores = [0.5f32, 9.0, 3.0, 9.0, 1.0];
        assert_eq!(top_p_largest(&scores, 3), vec![1, 3, 2]);
        assert_eq!(top_p_largest(&scores, 1), vec![1]);
        // p larger than len clamps
        assert_eq!(top_p_largest(&scores, 10).len(), 5);
    }

    #[test]
    #[should_panic]
    fn zero_k_panics() {
        TopK::new(0);
    }
}
