//! Bounded top-k selection.
//!
//! `TopK` keeps the k smallest items seen so far under the lexicographic
//! `(key, id)` order (a bounded max-heap); used as the fused per-query
//! `TopK(k)` accumulator of every candidate scan (its [`TopK::bound`] is
//! the early-abandon threshold, the current k-th best), for candidate-scan
//! results (k smallest distances), and, with negated keys, top-p class
//! selection.
//!
//! NaN keys sort last: they are never admitted to the heap, so a NaN
//! distance or score can never be selected and never poisons the
//! comparisons (`into_sorted` cannot panic on NaN).

use std::cmp::Ordering;

/// One ranked answer of a k-NN search: a database id and its distance
/// under the index metric.  Results are reported as `Vec<Neighbor>`
/// sorted ascending by `(distance, id)`; an empty vector means no
/// candidate was scanned.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Database id of the candidate.
    pub id: u32,
    /// Its distance under the index metric (smaller is closer).
    pub distance: f32,
}

/// The 1-NN view of a k-NN result: the best `(id, distance)` pair, or
/// the historical `(u32::MAX, f32::INFINITY)` sentinel when no candidate
/// was scanned.  The single place the sentinel convention lives.
pub fn one_nn(neighbors: &[Neighbor]) -> (u32, f32) {
    neighbors
        .first()
        .map_or((u32::MAX, f32::INFINITY), |n| (n.id, n.distance))
}

/// Bounded "k smallest by `(key, id)`" selector.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// max-heap on `(key, id)`, so the root is the current worst of the
    /// best-k
    heap: Vec<(f32, u32)>,
}

/// Lexicographic `(key, id)` greater-than; keys never contain NaN inside
/// the heap (NaN is rejected at [`TopK::push`]).
#[inline]
fn lex_gt(a: (f32, u32), b: (f32, u32)) -> bool {
    match a.0.partial_cmp(&b.0) {
        Some(Ordering::Greater) => true,
        Some(Ordering::Equal) => a.1 > b.1,
        _ => false,
    }
}

impl TopK {
    /// New selector keeping the `k` smallest keys. `k` must be > 0.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TopK { k, heap: Vec::with_capacity(k + 1) }
    }

    /// Current number of kept items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been pushed.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The selection size `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Largest kept key (the current cutoff), if full.  Used as the
    /// pruning threshold by the batched candidate scan.
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Early-abandon bound of the fused top-k scan: the current k-th best
    /// key once `k` items are held, `+inf` before that.  A candidate whose
    /// key provably exceeds this bound can never enter the selection (ties
    /// survive for the id tie-break).  At k = 1 this degenerates bitwise
    /// to the former `(best, best_id)` pair's `best`.
    #[inline]
    pub fn bound(&self) -> f32 {
        self.threshold().unwrap_or(f32::INFINITY)
    }

    /// Fold another selector into this one (used to merge the per-class
    /// accumulators of the class-major batched scan into the per-query
    /// result).  The merge commutes with push order: the k smallest under
    /// the total `(key, id)` order are kept no matter how candidates were
    /// split across selectors.
    pub fn merge(&mut self, other: TopK) {
        for (key, id) in other.heap {
            self.push(key, id);
        }
    }

    /// Offer an item.  NaN keys sort last and are never kept.
    #[inline]
    pub fn push(&mut self, key: f32, id: u32) {
        if key.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((key, id));
            self.sift_up(self.heap.len() - 1);
        } else if lex_gt(self.heap[0], (key, id)) {
            self.heap[0] = (key, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if lex_gt(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && lex_gt(self.heap[l], self.heap[largest]) {
                largest = l;
            }
            if r < n && lex_gt(self.heap[r], self.heap[largest]) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Consume into `(key, id)` pairs sorted ascending by `(key, id)`
    /// (ties by id for determinism).  Never panics: NaN keys cannot enter
    /// the heap, and the comparator is total regardless.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        self.heap
    }

    /// Consume into [`Neighbor`]s sorted ascending by `(distance, id)` —
    /// the k-NN result contract of every search path.
    pub fn into_neighbors(self) -> Vec<Neighbor> {
        self.into_sorted()
            .into_iter()
            .map(|(distance, id)| Neighbor { id, distance })
            .collect()
    }
}

/// Select the indices of the `p` largest values (top-p classes by score),
/// ordered from largest to smallest.  Ties broken by smaller index.
/// NaN values sort last: a NaN-scored class is never selected, and fewer
/// than `p` indices are returned when NaN leaves too few candidates.
pub fn top_p_largest(values: &[f32], p: usize) -> Vec<u32> {
    let mut sel = TopK::new(p.min(values.len()).max(1));
    for (i, &v) in values.iter().enumerate() {
        sel.push(-v, i as u32); // negate: TopK keeps smallest
    }
    sel.into_sorted().into_iter().map(|(_, i)| i).collect()
}

/// Invert a per-query polled-class map into (class → querying batch
/// members): `result[c]` lists the batch indices whose polled set
/// contains class `c`, in batch order.  The pivot of the class-grouped
/// candidate scan.
pub fn invert_polled(polled: &[Vec<u32>], n_classes: usize) -> Vec<Vec<u32>> {
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for (bi, pol) in polled.iter().enumerate() {
        for &ci in pol {
            by_class[ci as usize].push(bi as u32);
        }
    }
    by_class
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, &v) in [5., 1., 9., 3., 7., 2., 8.].iter().enumerate() {
            t.push(v, i as u32);
        }
        let got = t.into_sorted();
        let keys: Vec<f32> = got.iter().map(|x| x.0).collect();
        assert_eq!(keys, vec![1., 2., 3.]);
        let ids: Vec<u32> = got.iter().map(|x| x.1).collect();
        assert_eq!(ids, vec![1, 5, 3]);
    }

    #[test]
    fn matches_full_sort_prefix() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20) as usize;
            let vals: Vec<f32> = (0..n).map(|_| (rng.uniform() * 100.0) as f32).collect();
            let mut t = TopK::new(k);
            for (i, &v) in vals.iter().enumerate() {
                t.push(v, i as u32);
            }
            let got: Vec<f32> = t.into_sorted().iter().map(|x| x.0).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want: Vec<f32> = sorted.into_iter().take(k).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn tie_keys_keep_smaller_ids() {
        // exact (key, id) lexicographic selection, important for the
        // batched scan's TopK(1) accumulators: equal keys resolve to the
        // smaller id no matter the push order
        let mut t = TopK::new(1);
        t.push(2.0, 7);
        t.push(2.0, 3);
        t.push(2.0, 5);
        assert_eq!(t.into_sorted(), vec![(2.0, 3)]);
        let mut t = TopK::new(2);
        for &(k, id) in &[(5.0f32, 9u32), (5.0, 1), (5.0, 4), (6.0, 0)] {
            t.push(k, id);
        }
        assert_eq!(t.into_sorted(), vec![(5.0, 1), (5.0, 4)]);
    }

    #[test]
    fn threshold_only_when_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), None);
        t.push(5.0, 0);
        assert_eq!(t.threshold(), None);
        t.push(3.0, 1);
        assert_eq!(t.threshold(), Some(5.0));
        t.push(1.0, 2);
        assert_eq!(t.threshold(), Some(3.0));
    }

    #[test]
    fn top_p_largest_ordering() {
        let scores = [0.5f32, 9.0, 3.0, 9.0, 1.0];
        assert_eq!(top_p_largest(&scores, 3), vec![1, 3, 2]);
        assert_eq!(top_p_largest(&scores, 1), vec![1]);
        // p larger than len clamps
        assert_eq!(top_p_largest(&scores, 10).len(), 5);
    }

    #[test]
    fn nan_keys_are_never_selected_and_never_panic() {
        // regression: partial_cmp(...).unwrap() used to panic whenever a
        // NaN distance/score entered the heap
        let mut t = TopK::new(3);
        for (i, &v) in [5.0f32, f32::NAN, 1.0, f32::NAN, 3.0].iter().enumerate() {
            t.push(v, i as u32);
        }
        let got = t.into_sorted(); // must not panic
        assert_eq!(got, vec![(1.0, 2), (3.0, 4), (5.0, 0)]);

        // NaN-scored classes are skipped by top-p selection
        let scores = [f32::NAN, 2.0, f32::NAN, 1.0];
        assert_eq!(top_p_largest(&scores, 3), vec![1, 3]);

        // all-NaN input selects nothing (and must not panic)
        let all_nan = [f32::NAN; 4];
        assert!(top_p_largest(&all_nan, 2).is_empty());

        // a NaN pushed into a full heap must not evict anything
        let mut t = TopK::new(1);
        t.push(2.0, 0);
        t.push(f32::NAN, 1);
        assert_eq!(t.into_sorted(), vec![(2.0, 0)]);
    }

    #[test]
    fn topk1_matches_legacy_scan_rule() {
        // the rule the pre-k-NN (best, best_id) pair implemented:
        // strictly smaller key wins, equal keys resolve to the smaller
        // id, NaN never wins — TopK(1) must reproduce it exactly
        let mut t = TopK::new(1);
        t.push(3.0, 7);
        assert_eq!(t.clone().into_sorted(), vec![(3.0, 7)]);
        t.push(3.0, 9); // larger id on tie: no change
        assert_eq!(t.clone().into_sorted(), vec![(3.0, 7)]);
        t.push(3.0, 2); // smaller id on tie: wins
        assert_eq!(t.clone().into_sorted(), vec![(3.0, 2)]);
        t.push(f32::NAN, 0); // NaN never wins
        assert_eq!(t.clone().into_sorted(), vec![(3.0, 2)]);
        t.push(1.0, 5);
        assert_eq!(t.into_sorted(), vec![(1.0, 5)]);
    }

    #[test]
    fn invert_polled_builds_class_major_map() {
        let polled = vec![vec![0u32, 2], vec![2], vec![], vec![1, 2, 0]];
        let by_class = invert_polled(&polled, 4);
        assert_eq!(by_class[0], vec![0, 3]);
        assert_eq!(by_class[1], vec![3]);
        assert_eq!(by_class[2], vec![0, 1, 3]);
        assert!(by_class[3].is_empty());
    }

    #[test]
    fn bound_degenerates_to_best_at_k1() {
        let mut t = TopK::new(1);
        assert_eq!(t.bound(), f32::INFINITY);
        t.push(5.0, 0);
        assert_eq!(t.bound(), 5.0);
        t.push(2.0, 1);
        assert_eq!(t.bound(), 2.0);
        t.push(9.0, 2); // worse: bound unchanged
        assert_eq!(t.bound(), 2.0);
    }

    #[test]
    fn merge_equals_single_accumulator() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(17);
        for _ in 0..30 {
            let n = 1 + rng.below(100) as usize;
            let k = 1 + rng.below(12) as usize;
            let parts = 1 + rng.below(5) as usize;
            let vals: Vec<f32> = (0..n).map(|_| rng.below(15) as f32).collect();
            let mut single = TopK::new(k);
            let mut split: Vec<TopK> = (0..parts).map(|_| TopK::new(k)).collect();
            for (i, &v) in vals.iter().enumerate() {
                single.push(v, i as u32);
                split[i % parts].push(v, i as u32);
            }
            let mut merged = TopK::new(k);
            for part in split {
                merged.merge(part);
            }
            assert_eq!(merged.into_sorted(), single.into_sorted());
        }
    }

    #[test]
    fn into_neighbors_sorted_ascending() {
        let mut t = TopK::new(3);
        for (i, &v) in [4.0f32, 1.0, 3.0, 2.0].iter().enumerate() {
            t.push(v, i as u32);
        }
        let ns = t.into_neighbors();
        assert_eq!(
            ns,
            vec![
                Neighbor { id: 1, distance: 1.0 },
                Neighbor { id: 3, distance: 2.0 },
                Neighbor { id: 2, distance: 3.0 },
            ]
        );
    }

    #[test]
    #[should_panic]
    fn zero_k_panics() {
        TopK::new(0);
    }
}
