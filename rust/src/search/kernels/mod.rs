//! Runtime-dispatched distance kernels.
//!
//! Every scan in the system — exact f32, SQ8, PQ/ADC, hamming, and the
//! bilinear scoring stage's wide dot product — bottoms out in one of the
//! operations on [`Kernels`].  A backend is selected **once** per index
//! (at build or load, see [`Kernels::select`]) from one-time CPU feature
//! detection, and reported in server/router STATS as `kernel.backend`.
//!
//! # The bitwise contract
//!
//! Every backend is **bitwise identical** to the scalar reference for
//! every operation (pinned by `to_bits` proptests in
//! `tests/proptests.rs`).  The scalar loops were written with 4
//! independent accumulator lanes folded as `((s0 + s1) + s2) + s3`
//! precisely so a 4-wide vector register whose lane `l` *is* `s_l` can
//! replay the identical per-lane addition chains with vertical adds, and
//! the horizontal fold extracts lanes and adds them in the scalar order.
//! No FMA is used anywhere — contraction would change results.  The
//! early-abandon variants probe at the same 32-term cadence as
//! [`crate::search::accumulate_pruned`], so the tie/abandon contract
//! (`None` iff strictly greater than the bound) is unchanged.
//!
//! A consequence worth knowing when reading the dispatch table: under
//! this fold-order constraint, single-row f32 distances are bound by the
//! latency of the one serial 4-wide accumulator chain, so 256-bit
//! vectors buy nothing over 128-bit for them (measured: see
//! `BENCH_kernels.json`).  The f32 ops therefore use the 128-bit kernels
//! on both the `sse2` and `avx2` backends, while AVX2 earns its keep
//! where it has real headroom: the 8-wide integer SQ8 kernel, the
//! 8-wide hamming compare, and the 32-lane `dot_wide` used by batched
//! scoring (independent lanes, no serial fold).
//!
//! # Backends
//!
//! | Backend  | Where                | Detection                          |
//! | -------- | -------------------- | ---------------------------------- |
//! | `scalar` | everywhere           | always available (reference)       |
//! | `sse2`   | x86_64               | baseline — statically guaranteed   |
//! | `avx2`   | x86_64               | `is_x86_feature_detected!("avx2")` |
//! | `neon`   | aarch64              | baseline — statically guaranteed   |
//!
//! The `AMSEARCH_KERNEL` environment variable (`scalar` / `sse2` /
//! `avx2` / `neon`) overrides selection for benchmarks and tests; an
//! unknown or unavailable name falls back to the detected best, never
//! panics.  Backends without a dedicated implementation of some
//! operation fall back to the scalar reference for that operation —
//! still bitwise-equal by definition.

mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

use crate::search::distance::{self, Metric};

pub use scalar::{AdcTerms, Sq8Terms};

/// A concrete kernel implementation family (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Portable 4-lane scalar loops — the reference every other backend
    /// must match bitwise.
    Scalar,
    /// 128-bit SSE2 vectors (x86_64 baseline, no runtime check needed).
    Sse2,
    /// 256-bit AVX2 where it wins (integer SQ8, hamming, `dot_wide`);
    /// 128-bit f32 ops shared with `sse2` (see module docs).
    Avx2,
    /// 128-bit NEON f32 vectors (aarch64 baseline).
    Neon,
}

impl Backend {
    /// Stable lowercase name, used by `AMSEARCH_KERNEL` and the
    /// `kernel.backend` STATS row.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    fn parse(s: &str) -> Option<Backend> {
        match s {
            "scalar" => Some(Backend::Scalar),
            "sse2" => Some(Backend::Sse2),
            "avx2" => Some(Backend::Avx2),
            "neon" => Some(Backend::Neon),
            _ => None,
        }
    }

    /// Whether this backend can run on the current machine.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Sse2 => cfg!(target_arch = "x86_64"),
            Backend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Backend::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// One-time CPU detection: the best backend this machine supports.
fn detected() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            Backend::Avx2
        } else {
            Backend::Sse2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Backend::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Backend::Scalar
    }
}

fn detected_cached() -> Backend {
    static DETECTED: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();
    *DETECTED.get_or_init(detected)
}

/// The dispatch handle every scan layer carries: a [`Backend`] chosen
/// once, exposing every distance operation with the scalar reference's
/// exact bitwise semantics.  `Copy` and two bytes — cheap to embed in an
/// index or a per-query lookup table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Kernels {
    backend: Backend,
}

impl Default for Kernels {
    fn default() -> Self {
        Kernels::select()
    }
}

impl Kernels {
    /// The selected backend: the detected best for this machine, unless
    /// the `AMSEARCH_KERNEL` environment variable names an available
    /// override.  Called once at index build/load — the detection itself
    /// is cached process-wide.
    pub fn select() -> Kernels {
        let best = detected_cached();
        let backend = match std::env::var("AMSEARCH_KERNEL") {
            Ok(name) => match Backend::parse(name.trim()) {
                Some(b) if b.available() => b,
                // unknown or unavailable override: fall back, don't fail
                _ => best,
            },
            Err(_) => best,
        };
        Kernels { backend }
    }

    /// The always-available scalar reference.
    pub fn scalar() -> Kernels {
        Kernels { backend: Backend::Scalar }
    }

    /// A specific backend, or `None` if this machine can't run it
    /// (benchmarks and the bitwise-equivalence tests sweep these).
    pub fn with_backend(backend: Backend) -> Option<Kernels> {
        backend.available().then_some(Kernels { backend })
    }

    /// The selected backend.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Stable backend name for STATS (`kernel.backend`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Squared Euclidean distance; bitwise equal to
    /// [`crate::search::distance::sq_l2`] on every backend.
    #[inline]
    pub fn sq_l2(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "sq_l2 operand shapes");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 | Backend::Avx2 => x86::sq_l2(a, b),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => neon::sq_l2(a, b),
            _ => distance::sq_l2(a, b),
        }
    }

    /// Early-abandoning squared-L2: same probe cadence and tie contract
    /// as [`crate::search::accumulate_pruned`] (`None` iff strictly
    /// greater than `bound`), `Some(d)` bitwise equal to [`Self::sq_l2`].
    #[inline]
    pub fn sq_l2_pruned(&self, a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
        assert_eq!(a.len(), b.len(), "sq_l2 operand shapes");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 | Backend::Avx2 => x86::sq_l2_pruned(a, b, bound),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => neon::sq_l2_pruned(a, b, bound),
            _ => distance::sq_l2_pruned(a, b, bound),
        }
    }

    /// Dot product; bitwise equal to [`crate::search::distance::dot`].
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot operand shapes");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 | Backend::Avx2 => x86::dot(a, b),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => neon::dot(a, b),
            _ => distance::dot(a, b),
        }
    }

    /// The 32-lane dot product used by the batched scoring stage
    /// (`memory::score`): 32 independent accumulator lanes over 32-term
    /// chunks, folded sequentially, then an 8-wide and a scalar tail.
    /// Unlike the 4-lane distance kernels this has no serial vector
    /// chain, so AVX2 runs four genuine 256-bit accumulators.
    #[inline]
    pub fn dot_wide(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot_wide operand shapes");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => {
                // SAFETY: this handle only carries Backend::Avx2 when
                // `is_x86_feature_detected!("avx2")` held at selection
                // (Kernels::select / Backend::available), so the
                // target-feature contract of `dot_wide_avx2` is met.
                unsafe { x86::dot_wide_avx2(a, b) }
            }
            _ => scalar::dot_wide(a, b),
        }
    }

    /// Hamming distance (count of differing coordinates); exactly equal
    /// to [`crate::search::distance::hamming`] — integer counts carry no
    /// rounding, so any summation order is the same count.
    #[inline]
    pub fn hamming(&self, a: &[f32], b: &[f32]) -> u32 {
        assert_eq!(a.len(), b.len(), "hamming operand shapes");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 => x86::hamming_sse2(a, b),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => {
                // SAFETY: Backend::Avx2 is only constructed after the
                // runtime `is_x86_feature_detected!("avx2")` check
                // (Kernels::select / Backend::available).
                unsafe { x86::hamming_avx2(a, b) }
            }
            _ => distance::hamming(a, b),
        }
    }

    /// Metric distance — mirrors [`Metric::distance`] bitwise.
    #[inline]
    pub fn distance(&self, metric: Metric, a: &[f32], b: &[f32]) -> f32 {
        match metric {
            Metric::SqL2 => self.sq_l2(a, b),
            Metric::NegDot => -self.dot(a, b),
            Metric::Hamming => self.hamming(a, b) as f32,
        }
    }

    /// Metric distance with early abandoning — mirrors
    /// [`crate::search::distance_pruned`] bitwise: `None` iff strictly
    /// greater than `bound`; squared-L2 abandons mid-accumulation, the
    /// other metrics compute fully before comparing.
    #[inline]
    pub fn distance_pruned(
        &self,
        metric: Metric,
        a: &[f32],
        b: &[f32],
        bound: f32,
    ) -> Option<f32> {
        match metric {
            Metric::SqL2 => self.sq_l2_pruned(a, b, bound),
            _ => {
                let d = self.distance(metric, a, b);
                if d > bound {
                    None
                } else {
                    Some(d)
                }
            }
        }
    }

    /// SQ8 asymmetric distance in the integer domain:
    /// `Σ_j ((qcode[j] − code[j])² as f32) · step2[j]`.  The byte
    /// difference squared is at most `255² = 65025`, exact in `i32` and
    /// exact when converted to `f32`, so the only rounding is the one
    /// `f32` multiply per term — which every backend performs
    /// identically.
    #[inline]
    pub fn sq8(&self, qcode: &[u8], code: &[u8], step2: &[f32]) -> f32 {
        assert_eq!(qcode.len(), code.len(), "sq8 code shapes");
        assert_eq!(step2.len(), code.len(), "sq8 step table shape");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => {
                // SAFETY: Backend::Avx2 is only constructed after the
                // runtime `is_x86_feature_detected!("avx2")` check
                // (Kernels::select / Backend::available).
                unsafe { x86::sq8_avx2(qcode, code, step2) }
            }
            _ => scalar::sq8(qcode, code, step2),
        }
    }

    /// Early-abandoning [`Self::sq8`] with the standard 32-term probe
    /// cadence and tie contract.
    #[inline]
    pub fn sq8_pruned(
        &self,
        qcode: &[u8],
        code: &[u8],
        step2: &[f32],
        bound: f32,
    ) -> Option<f32> {
        assert_eq!(qcode.len(), code.len(), "sq8 code shapes");
        assert_eq!(step2.len(), code.len(), "sq8 step table shape");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => {
                // SAFETY: Backend::Avx2 is only constructed after the
                // runtime `is_x86_feature_detected!("avx2")` check
                // (Kernels::select / Backend::available).
                unsafe { x86::sq8_pruned_avx2(qcode, code, step2, bound) }
            }
            _ => scalar::sq8_pruned(qcode, code, step2, bound),
        }
    }

    /// ADC distance over a power-of-two padded lookup table:
    /// `Σ_s lut[(s << shift) | code[s]]`.  The pad makes every row the
    /// same `1 << shift` floats, so the address is a shift and an OR —
    /// no multiply, no gather: the vector backends issue four scalar L1
    /// loads and pack them (gather-free sequential lookup).
    #[inline]
    pub fn adc(&self, lut: &[f32], shift: u32, code: &[u8]) -> f32 {
        debug_assert!(lut.len() >= code.len() << shift, "adc table shape");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 | Backend::Avx2 => x86::adc(lut, shift, code),
            _ => scalar::adc(lut, shift, code),
        }
    }

    /// Early-abandoning [`Self::adc`] with the standard 32-term probe
    /// cadence and tie contract.
    #[inline]
    pub fn adc_pruned(
        &self,
        lut: &[f32],
        shift: u32,
        code: &[u8],
        bound: f32,
    ) -> Option<f32> {
        debug_assert!(lut.len() >= code.len() << shift, "adc table shape");
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Sse2 | Backend::Avx2 => x86::adc_pruned(lut, shift, code, bound),
            _ => scalar::adc_pruned(lut, shift, code, bound),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn backends() -> Vec<Kernels> {
        [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon]
            .into_iter()
            .filter_map(Kernels::with_backend)
            .collect()
    }

    #[test]
    fn scalar_backend_is_always_available() {
        assert_eq!(Kernels::scalar().backend(), Backend::Scalar);
        assert!(Backend::Scalar.available());
        assert_eq!(Kernels::with_backend(Backend::Scalar), Some(Kernels::scalar()));
    }

    #[test]
    fn selected_backend_is_available() {
        let k = Kernels::select();
        assert!(k.backend().available());
        // name round-trips through the override parser
        assert_eq!(Backend::parse(k.backend_name()), Some(k.backend()));
    }

    #[test]
    fn every_backend_matches_scalar_bitwise_smoke() {
        // quick cross-op smoke; the exhaustive sweep lives in
        // tests/proptests.rs
        let mut rng = Rng::new(41);
        let scalar = Kernels::scalar();
        for n in [0usize, 1, 3, 4, 7, 31, 32, 33, 64, 100, 128, 129] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let qc: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let cc: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let s2: Vec<f32> = (0..n).map(|_| (rng.normal() as f32).abs()).collect();
            for k in backends() {
                let name = k.backend_name();
                assert_eq!(
                    k.sq_l2(&a, &b).to_bits(),
                    scalar.sq_l2(&a, &b).to_bits(),
                    "sq_l2 {name} n={n}"
                );
                assert_eq!(
                    k.dot(&a, &b).to_bits(),
                    scalar.dot(&a, &b).to_bits(),
                    "dot {name} n={n}"
                );
                assert_eq!(
                    k.dot_wide(&a, &b).to_bits(),
                    scalar.dot_wide(&a, &b).to_bits(),
                    "dot_wide {name} n={n}"
                );
                assert_eq!(k.hamming(&a, &b), scalar.hamming(&a, &b), "hamming {name}");
                assert_eq!(
                    k.sq8(&qc, &cc, &s2).to_bits(),
                    scalar.sq8(&qc, &cc, &s2).to_bits(),
                    "sq8 {name} n={n}"
                );
                let full = scalar.sq_l2(&a, &b);
                assert_eq!(
                    k.sq_l2_pruned(&a, &b, full).map(f32::to_bits),
                    Some(full.to_bits()),
                    "pruned tie {name} n={n}"
                );
                if full > 0.0 {
                    assert_eq!(
                        k.sq_l2_pruned(&a, &b, full * 0.999),
                        None,
                        "pruned abandon {name} n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn adc_backends_agree_on_padded_tables() {
        let mut rng = Rng::new(42);
        let scalar = Kernels::scalar();
        for (m, shift) in [(0usize, 2u32), (1, 2), (8, 4), (13, 4), (16, 8), (33, 8)] {
            let lut: Vec<f32> =
                (0..m << shift).map(|_| (rng.normal() as f32).abs()).collect();
            let code: Vec<u8> = (0..m)
                .map(|_| (rng.next_u64() & ((1 << shift) - 1)) as u8)
                .collect();
            let want = scalar.adc(&lut, shift, &code);
            for k in backends() {
                assert_eq!(
                    k.adc(&lut, shift, &code).to_bits(),
                    want.to_bits(),
                    "adc {} m={m}",
                    k.backend_name()
                );
                assert_eq!(
                    k.adc_pruned(&lut, shift, &code, want).map(f32::to_bits),
                    Some(want.to_bits()),
                    "adc_pruned {} m={m}",
                    k.backend_name()
                );
            }
        }
    }

    #[test]
    fn unknown_override_falls_back_to_detected() {
        // Backend::parse is what the env override goes through; the
        // fallback path must not panic and must stay available
        assert_eq!(Backend::parse("quantum"), None);
        let k = Kernels::select();
        assert!(k.backend().available());
    }
}
