//! aarch64 NEON kernels for the f32 distance loops.  NEON is a baseline
//! feature of the `aarch64` targets we build for, so there is no runtime
//! check — `Backend::Neon` is always available there.
//!
//! Same bitwise contract as the x86 file: one `float32x4_t` accumulator
//! whose lanes are the scalar `s0..s3`, vertical adds per 4-term chunk,
//! lanes extracted and folded in the scalar order `((l0 + l1) + l2) + l3`.
//! `vmulq`/`vaddq` are separate (non-fused) instructions, matching the
//! scalar mul-then-add.
//!
//! The quantized kernels (SQ8/ADC) fall back to scalar on aarch64 for
//! now; only the f32 hot loops are vectorized here.

#[allow(clippy::wildcard_imports)]
use std::arch::aarch64::*;

/// Horizontal fold in the scalar order: `((l0 + l1) + l2) + l3`.
#[inline(always)]
fn fold4(acc: float32x4_t) -> f32 {
    ((vgetq_lane_f32::<0>(acc) + vgetq_lane_f32::<1>(acc)) + vgetq_lane_f32::<2>(acc))
        + vgetq_lane_f32::<3>(acc)
}

/// Squared-L2, bitwise equal to [`crate::search::distance::sq_l2`].
#[inline]
pub(crate) fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * 4;
        // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so both
        // 16-byte unaligned loads stay inside their slices; NEON is
        // baseline on aarch64.
        acc = unsafe {
            let d = vsubq_f32(vld1q_f32(a.as_ptr().add(j)), vld1q_f32(b.as_ptr().add(j)));
            vaddq_f32(acc, vmulq_f32(d, d))
        };
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Early-abandoning [`sq_l2`]; replays `accumulate_pruned`'s probe
/// schedule and tie contract exactly.
#[inline]
pub(crate) fn sq_l2_pruned(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    let mut s = 0f32;
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i < stop {
            let j = i * 4;
            // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so
            // both 16-byte unaligned loads stay inside their slices;
            // NEON is baseline on aarch64.
            acc = unsafe {
                let d =
                    vsubq_f32(vld1q_f32(a.as_ptr().add(j)), vld1q_f32(b.as_ptr().add(j)));
                vaddq_f32(acc, vmulq_f32(d, d))
            };
            i += 1;
        }
        s = fold4(acc);
        if s > bound {
            return None;
        }
    }
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

/// Dot product, bitwise equal to [`crate::search::distance::dot`].
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * 4;
        // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so both
        // 16-byte unaligned loads stay inside their slices; NEON is
        // baseline on aarch64.
        acc = unsafe {
            vaddq_f32(
                acc,
                vmulq_f32(vld1q_f32(a.as_ptr().add(j)), vld1q_f32(b.as_ptr().add(j))),
            )
        };
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}
