//! Scalar reference kernels — the bitwise ground truth every vector
//! backend must reproduce (see the module docs in [`super`]).
//!
//! The f32 squared-L2 / dot / hamming references live in
//! [`crate::search::distance`] (they predate this module and the
//! baselines call them directly); this file adds the term producers for
//! the quantized representations and the 32-lane `dot_wide` used by the
//! batched scorer.

use crate::search::distance::{accumulate, accumulate_pruned, DistanceKernel};

/// SQ8 asymmetric-distance terms in the integer domain: the query is
/// encoded with the same per-dimension affine quantizer as the database
/// (`qcode`), and
/// `term(j) = ((qcode[j] − code[j])² as f32) · step2[j]`
/// with `step2[j] = step[j]²`.  The byte difference squared is ≤ 65025 —
/// exact in `i32` and exact in the `i32 → f32` convert — so the single
/// rounding per term is the final multiply, which scalar and vector
/// backends perform identically.  Terms are non-negative, satisfying the
/// [`DistanceKernel`] early-abandon contract.
pub struct Sq8Terms<'a> {
    /// Encoded query.
    pub qcode: &'a [u8],
    /// Encoded candidate.
    pub code: &'a [u8],
    /// Per-dimension squared quantization steps.
    pub step2: &'a [f32],
}

impl DistanceKernel for Sq8Terms<'_> {
    #[inline(always)]
    fn terms(&self) -> usize {
        self.code.len()
    }
    #[inline(always)]
    fn term(&self, j: usize) -> f32 {
        let d = i32::from(self.qcode[j]) - i32::from(self.code[j]);
        ((d * d) as f32) * self.step2[j]
    }
}

/// ADC terms over a power-of-two padded lookup table: subspace `s`'s row
/// starts at `s << shift` (row stride `1 << shift` floats, padded with
/// zeros that in-range codes never address), so
/// `term(s) = lut[(s << shift) | code[s]]` — a shift and an OR, no
/// multiply.  Table entries are exact squared subspace distances, hence
/// non-negative.
pub struct AdcTerms<'a> {
    /// Padded `[m << shift]` lookup table.
    pub lut: &'a [f32],
    /// log2 of the row stride.
    pub shift: u32,
    /// One centroid id per subspace.
    pub code: &'a [u8],
}

impl DistanceKernel for AdcTerms<'_> {
    #[inline(always)]
    fn terms(&self) -> usize {
        self.code.len()
    }
    #[inline(always)]
    fn term(&self, j: usize) -> f32 {
        self.lut[(j << self.shift) | self.code[j] as usize]
    }
}

/// Scalar SQ8 distance — [`accumulate`] over [`Sq8Terms`].
#[inline]
pub fn sq8(qcode: &[u8], code: &[u8], step2: &[f32]) -> f32 {
    accumulate(&Sq8Terms { qcode, code, step2 })
}

/// Early-abandoning scalar SQ8 — [`accumulate_pruned`] over
/// [`Sq8Terms`].
#[inline]
pub fn sq8_pruned(qcode: &[u8], code: &[u8], step2: &[f32], bound: f32) -> Option<f32> {
    accumulate_pruned(&Sq8Terms { qcode, code, step2 }, bound)
}

/// Scalar ADC distance — [`accumulate`] over [`AdcTerms`].
#[inline]
pub fn adc(lut: &[f32], shift: u32, code: &[u8]) -> f32 {
    accumulate(&AdcTerms { lut, shift, code })
}

/// Early-abandoning scalar ADC — [`accumulate_pruned`] over
/// [`AdcTerms`].
#[inline]
pub fn adc_pruned(lut: &[f32], shift: u32, code: &[u8], bound: f32) -> Option<f32> {
    accumulate_pruned(&AdcTerms { lut, shift, code }, bound)
}

/// The scoring stage's wide dot product: 32 scalar lanes (= 4
/// independent 8-wide vector accumulators when auto-vectorized) over
/// 32-term chunks, lanes folded sequentially, then an 8-wide tail and a
/// scalar tail.  Moved verbatim from `memory::score::dot8` — this exact
/// operation order is the reference the AVX2 `dot_wide` reproduces.
#[inline(always)]
pub(crate) fn dot_wide(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0f32; 32];
    let ac = a.chunks_exact(32);
    let bc = b.chunks_exact(32);
    let (atail, btail) = (ac.remainder(), bc.remainder());
    for (ra, rb) in ac.zip(bc) {
        for i in 0..32 {
            lanes[i] += ra[i] * rb[i];
        }
    }
    let mut acc = 0f32;
    for l in lanes {
        acc += l;
    }
    dot_wide_tail(acc, atail, btail)
}

/// The sub-32-term tail of [`dot_wide`]: 8-wide lanes then scalar,
/// folded into `acc` in the reference order.  Shared with the AVX2
/// `dot_wide` so both paths run the byte-identical tail sequence.
#[inline(always)]
pub(crate) fn dot_wide_tail(mut acc: f32, atail: &[f32], btail: &[f32]) -> f32 {
    let atc = atail.chunks_exact(8);
    let btc = btail.chunks_exact(8);
    let (at2, bt2) = (atc.remainder(), btc.remainder());
    let mut tail_lanes = [0f32; 8];
    for (ra, rb) in atc.zip(btc) {
        for i in 0..8 {
            tail_lanes[i] += ra[i] * rb[i];
        }
    }
    for l in tail_lanes {
        acc += l;
    }
    for (x, y) in at2.iter().zip(bt2) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::search::distance::dot;

    #[test]
    fn sq8_terms_are_exact_integer_domain() {
        // one term: (7-3)^2 * 0.25 = 4.0, exactly representable
        let q = [7u8];
        let c = [3u8];
        let s2 = [0.25f32];
        assert_eq!(sq8(&q, &c, &s2), 4.0);
        // max byte difference stays exact in i32 and f32
        let q = [255u8];
        let c = [0u8];
        let s2 = [1.0f32];
        assert_eq!(sq8(&q, &c, &s2), 65025.0);
    }

    #[test]
    fn sq8_pruned_matches_full_and_keeps_ties() {
        let mut rng = Rng::new(9);
        for n in [0usize, 1, 5, 32, 33, 129] {
            let q: Vec<u8> = (0..n).map(|i| (i * 37 % 256) as u8).collect();
            let c: Vec<u8> = (0..n).map(|i| (i * 101 % 256) as u8).collect();
            let s2: Vec<f32> = (0..n).map(|_| (rng.normal() as f32).abs()).collect();
            let full = sq8(&q, &c, &s2);
            assert_eq!(
                sq8_pruned(&q, &c, &s2, full).map(f32::to_bits),
                Some(full.to_bits())
            );
            if full > 0.0 {
                assert_eq!(sq8_pruned(&q, &c, &s2, full * 0.999), None);
            }
        }
    }

    #[test]
    fn adc_walks_padded_rows() {
        // 2 subspaces, stride 4 (shift=2): rows [1,2,3,0] and [5,6,7,0]
        let lut = [1f32, 2., 3., 0., 5., 6., 7., 0.];
        assert_eq!(adc(&lut, 2, &[0, 0]), 6.0);
        assert_eq!(adc(&lut, 2, &[2, 1]), 9.0);
        assert_eq!(adc_pruned(&lut, 2, &[2, 1], 9.0), Some(9.0));
        assert_eq!(adc_pruned(&lut, 2, &[2, 1], 8.9), None);
    }

    #[test]
    fn dot_wide_matches_plain_dot_closely() {
        // different summation orders — not bitwise, but must agree to
        // float tolerance on well-conditioned data
        let mut rng = Rng::new(10);
        for n in [0usize, 7, 8, 31, 32, 33, 64, 100, 357] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let wide = dot_wide(&a, &b);
            let narrow = dot(&a, &b);
            assert!((wide - narrow).abs() < 1e-3 * (1.0 + narrow.abs()), "n={n}");
        }
    }
}
