//! x86_64 vector kernels: SSE2 (baseline, no runtime check) and AVX2
//! (runtime-detected) implementations of the scalar reference loops in
//! [`crate::search::distance`] and [`super::scalar`].
//!
//! Bitwise design (see the module docs in [`super`]): the 4 independent
//! scalar accumulator lanes `s0..s3` become the 4 lanes of one `__m128`
//! accumulator; each 4-term chunk is one vertical `addps`, so lane `l`
//! replays the scalar chain `s_l += term(4i + l)` in the identical
//! order, and the horizontal fold extracts lanes and adds them as
//! `((l0 + l1) + l2) + l3` — the scalar fold.  Where 256-bit vectors are
//! used (SQ8), the two 128-bit halves of each 8-term block are added
//! into that same 4-wide accumulator low-half-first, preserving every
//! per-lane chain.  No FMA is ever emitted: `_mm_add_ps(_mm_mul_ps(..))`
//! are separate intrinsics and rustc does not contract them.
//!
//! The pruned variants replay `accumulate_pruned`'s exact probe
//! schedule: a horizontal fold compared against the bound after every
//! group of ≤ 8 chunks (32 terms), then the scalar tail and the final
//! strictly-greater check.
//!
//! Unsafety is confined to raw-pointer loads/stores whose bounds are
//! established by the surrounding chunk arithmetic; all lane arithmetic
//! uses value intrinsics, which are safe under the statically-enabled
//! sse2 baseline (or the `#[target_feature(enable = "avx2")]` scope).

#[allow(clippy::wildcard_imports)]
use std::arch::x86_64::*;

/// Horizontal fold in the scalar order: `((l0 + l1) + l2) + l3`.
#[inline(always)]
fn fold4(acc: __m128) -> f32 {
    let mut l = [0f32; 4];
    // SAFETY: `l` is a live 16-byte buffer; `_mm_storeu_ps` is an
    // unaligned store, and an sse baseline instruction on x86_64.
    unsafe { _mm_storeu_ps(l.as_mut_ptr(), acc) };
    ((l[0] + l[1]) + l[2]) + l[3]
}

/// Squared-L2, bitwise equal to [`crate::search::distance::sq_l2`]
/// (128-bit; used by both the `sse2` and `avx2` backends — the serial
/// 4-wide fold chain leaves 256-bit vectors no faster for single rows).
#[inline]
pub(crate) fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        let j = i * 4;
        // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so both
        // 16-byte unaligned loads stay inside their slices; sse2 is the
        // x86_64 baseline.
        acc = unsafe {
            let d = _mm_sub_ps(
                _mm_loadu_ps(a.as_ptr().add(j)),
                _mm_loadu_ps(b.as_ptr().add(j)),
            );
            _mm_add_ps(acc, _mm_mul_ps(d, d))
        };
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Early-abandoning [`sq_l2`]; replays `accumulate_pruned`'s probe
/// schedule and tie contract exactly.
#[inline]
pub(crate) fn sq_l2_pruned(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    let mut s = 0f32;
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i < stop {
            let j = i * 4;
            // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so
            // both 16-byte unaligned loads stay inside their slices;
            // sse2 is the x86_64 baseline.
            acc = unsafe {
                let d = _mm_sub_ps(
                    _mm_loadu_ps(a.as_ptr().add(j)),
                    _mm_loadu_ps(b.as_ptr().add(j)),
                );
                _mm_add_ps(acc, _mm_mul_ps(d, d))
            };
            i += 1;
        }
        // probe only reads the lanes; accumulation state is untouched
        s = fold4(acc);
        if s > bound {
            return None;
        }
    }
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

/// Dot product, bitwise equal to [`crate::search::distance::dot`].
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        let j = i * 4;
        // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so both
        // 16-byte unaligned loads stay inside their slices; sse2 is the
        // x86_64 baseline.
        acc = unsafe {
            _mm_add_ps(
                acc,
                _mm_mul_ps(
                    _mm_loadu_ps(a.as_ptr().add(j)),
                    _mm_loadu_ps(b.as_ptr().add(j)),
                ),
            )
        };
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Hamming distance via 4-wide `cmpneq` + movemask + popcount.  The
/// `NEQ_UQ` predicate matches Rust's `f32 !=` exactly (NaN compares
/// unequal to everything, `0.0 == -0.0`), and integer counts carry no
/// rounding, so this equals the scalar count for any input.
#[inline]
pub(crate) fn hamming_sse2(a: &[f32], b: &[f32]) -> u32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut count = 0u32;
    for i in 0..chunks {
        let j = i * 4;
        // SAFETY: `j + 4 <= chunks * 4 <= n <= a.len(), b.len()`, so both
        // 16-byte unaligned loads stay inside their slices; sse2 is the
        // x86_64 baseline.
        let mask = unsafe {
            let ne = _mm_cmpneq_ps(
                _mm_loadu_ps(a.as_ptr().add(j)),
                _mm_loadu_ps(b.as_ptr().add(j)),
            );
            _mm_movemask_ps(ne)
        };
        count += (mask as u32).count_ones();
    }
    for j in chunks * 4..n {
        count += u32::from(a[j] != b[j]);
    }
    count
}

// SAFETY: requires avx2 — every caller is gated by the one-time
// `is_x86_feature_detected!("avx2")` check in `Kernels::select` /
// `Backend::available` (Backend::Avx2 is never constructed without it).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn hamming_avx2(a: &[f32], b: &[f32]) -> u32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut count = 0u32;
    for i in 0..chunks {
        let j = i * 8;
        // SAFETY: `j + 8 <= chunks * 8 <= n <= a.len(), b.len()`, so both
        // 32-byte unaligned loads stay inside their slices; the avx
        // instructions are gated by this fn's `target_feature` contract.
        let mask = unsafe {
            let ne = _mm256_cmp_ps::<_CMP_NEQ_UQ>(
                _mm256_loadu_ps(a.as_ptr().add(j)),
                _mm256_loadu_ps(b.as_ptr().add(j)),
            );
            _mm256_movemask_ps(ne)
        };
        count += (mask as u32).count_ones();
    }
    for j in chunks * 8..n {
        count += u32::from(a[j] != b[j]);
    }
    count
}

/// Four SQ8 terms computed scalar and packed lane-for-lane — the odd
/// trailing 4-term chunk of the 8-wide loops (each term is produced by
/// the exact scalar expression, so the packed vertical add extends every
/// per-lane chain identically).
#[inline(always)]
fn sq8_terms4(qcode: &[u8], code: &[u8], step2: &[f32], j: usize) -> __m128 {
    let t = |k: usize| {
        let d = i32::from(qcode[j + k]) - i32::from(code[j + k]);
        ((d * d) as f32) * step2[j + k]
    };
    _mm_set_ps(t(3), t(2), t(1), t(0))
}

// SAFETY: requires avx2 — every caller is gated by the one-time
// `is_x86_feature_detected!("avx2")` check in `Kernels::select` /
// `Backend::available` (Backend::Avx2 is never constructed without it).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sq8_avx2(qcode: &[u8], code: &[u8], step2: &[f32]) -> f32 {
    let n = code.len();
    let chunks = n / 4;
    let pairs = chunks / 2;
    let mut acc = _mm_setzero_ps();
    for p in 0..pairs {
        let j = p * 8;
        // SAFETY: `j + 8 <= pairs * 8 <= n`, and the dispatch layer
        // asserts `qcode`, `code`, `step2` all have length `n`, so the
        // two 8-byte and one 32-byte unaligned loads stay in bounds; the
        // avx2 instructions are gated by this fn's `target_feature`
        // contract.
        let t: __m256 = unsafe {
            let vq = _mm256_cvtepu8_epi32(_mm_loadl_epi64(qcode.as_ptr().add(j).cast()));
            let vc = _mm256_cvtepu8_epi32(_mm_loadl_epi64(code.as_ptr().add(j).cast()));
            let d = _mm256_sub_epi32(vq, vc);
            _mm256_mul_ps(
                _mm256_cvtepi32_ps(_mm256_mullo_epi32(d, d)),
                _mm256_loadu_ps(step2.as_ptr().add(j)),
            )
        };
        // low half first, then high: lane l's chain gains term(8p + l)
        // then term(8p + 4 + l), matching the scalar chunk order
        acc = _mm_add_ps(acc, _mm256_castps256_ps128(t));
        acc = _mm_add_ps(acc, _mm256_extractf128_ps::<1>(t));
    }
    if chunks % 2 == 1 {
        acc = _mm_add_ps(acc, sq8_terms4(qcode, code, step2, (chunks - 1) * 4));
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = i32::from(qcode[j]) - i32::from(code[j]);
        s += ((d * d) as f32) * step2[j];
    }
    s
}

// SAFETY: requires avx2 — every caller is gated by the one-time
// `is_x86_feature_detected!("avx2")` check in `Kernels::select` /
// `Backend::available` (Backend::Avx2 is never constructed without it).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sq8_pruned_avx2(
    qcode: &[u8],
    code: &[u8],
    step2: &[f32],
    bound: f32,
) -> Option<f32> {
    let n = code.len();
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    let mut s = 0f32;
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i + 2 <= stop {
            let j = i * 4;
            // SAFETY: `j + 8 <= chunks * 4 <= n`, and the dispatch layer
            // asserts `qcode`, `code`, `step2` all have length `n`, so
            // the two 8-byte and one 32-byte unaligned loads stay in
            // bounds; the avx2 instructions are gated by this fn's
            // `target_feature` contract.
            let t: __m256 = unsafe {
                let vq =
                    _mm256_cvtepu8_epi32(_mm_loadl_epi64(qcode.as_ptr().add(j).cast()));
                let vc =
                    _mm256_cvtepu8_epi32(_mm_loadl_epi64(code.as_ptr().add(j).cast()));
                let d = _mm256_sub_epi32(vq, vc);
                _mm256_mul_ps(
                    _mm256_cvtepi32_ps(_mm256_mullo_epi32(d, d)),
                    _mm256_loadu_ps(step2.as_ptr().add(j)),
                )
            };
            acc = _mm_add_ps(acc, _mm256_castps256_ps128(t));
            acc = _mm_add_ps(acc, _mm256_extractf128_ps::<1>(t));
            i += 2;
        }
        if i < stop {
            acc = _mm_add_ps(acc, sq8_terms4(qcode, code, step2, i * 4));
            i += 1;
        }
        // the same 32-term probe boundary as `accumulate_pruned`
        s = fold4(acc);
        if s > bound {
            return None;
        }
    }
    for j in chunks * 4..n {
        let d = i32::from(qcode[j]) - i32::from(code[j]);
        s += ((d * d) as f32) * step2[j];
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

/// Four ADC terms looked up scalar (gather-free: four L1 loads off the
/// padded shift/OR addresses) and packed lane-for-lane.
#[inline(always)]
fn adc_terms4(lut: &[f32], shift: u32, code: &[u8], j: usize) -> __m128 {
    let t = |k: usize| lut[((j + k) << shift) | code[j + k] as usize];
    _mm_set_ps(t(3), t(2), t(1), t(0))
}

/// ADC over the padded table: packed sequential lookups, one vertical
/// add per 4 subspaces (no gather instruction anywhere).
#[inline]
pub(crate) fn adc(lut: &[f32], shift: u32, code: &[u8]) -> f32 {
    let m = code.len();
    let chunks = m / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        acc = _mm_add_ps(acc, adc_terms4(lut, shift, code, i * 4));
    }
    let mut s = fold4(acc);
    for j in chunks * 4..m {
        s += lut[(j << shift) | code[j] as usize];
    }
    s
}

/// Early-abandoning [`adc`] with `accumulate_pruned`'s probe schedule.
#[inline]
pub(crate) fn adc_pruned(lut: &[f32], shift: u32, code: &[u8], bound: f32) -> Option<f32> {
    let m = code.len();
    let chunks = m / 4;
    let mut acc = _mm_setzero_ps();
    let mut s = 0f32;
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i < stop {
            acc = _mm_add_ps(acc, adc_terms4(lut, shift, code, i * 4));
            i += 1;
        }
        s = fold4(acc);
        if s > bound {
            return None;
        }
    }
    for j in chunks * 4..m {
        s += lut[(j << shift) | code[j] as usize];
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

// SAFETY: requires avx2 — every caller is gated by the one-time
// `is_x86_feature_detected!("avx2")` check in `Kernels::select` /
// `Backend::available` (Backend::Avx2 is never constructed without it).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_wide_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 32;
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    for c in 0..chunks {
        let j = c * 32;
        // SAFETY: `j + 32 <= chunks * 32 <= n <= a.len(), b.len()`, so
        // all eight 32-byte unaligned loads stay inside their slices;
        // the avx instructions are gated by this fn's `target_feature`
        // contract.
        unsafe {
            acc0 = _mm256_add_ps(
                acc0,
                _mm256_mul_ps(
                    _mm256_loadu_ps(a.as_ptr().add(j)),
                    _mm256_loadu_ps(b.as_ptr().add(j)),
                ),
            );
            acc1 = _mm256_add_ps(
                acc1,
                _mm256_mul_ps(
                    _mm256_loadu_ps(a.as_ptr().add(j + 8)),
                    _mm256_loadu_ps(b.as_ptr().add(j + 8)),
                ),
            );
            acc2 = _mm256_add_ps(
                acc2,
                _mm256_mul_ps(
                    _mm256_loadu_ps(a.as_ptr().add(j + 16)),
                    _mm256_loadu_ps(b.as_ptr().add(j + 16)),
                ),
            );
            acc3 = _mm256_add_ps(
                acc3,
                _mm256_mul_ps(
                    _mm256_loadu_ps(a.as_ptr().add(j + 24)),
                    _mm256_loadu_ps(b.as_ptr().add(j + 24)),
                ),
            );
        }
    }
    // the accumulators' 32 lanes are exactly the scalar `lanes[0..32]`
    // (acc0 = lanes 0..8, …), folded in the identical sequential order
    let mut lanes = [0f32; 32];
    // SAFETY: `lanes` is a live 128-byte buffer, each store writes one
    // disjoint 32-byte span; unaligned stores, avx per this fn's
    // `target_feature` contract.
    unsafe {
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(16), acc2);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(24), acc3);
    }
    let mut acc = 0f32;
    for l in lanes {
        acc += l;
    }
    super::scalar::dot_wide_tail(acc, &a[chunks * 32..n], &b[chunks * 32..n])
}
