//! Adaptive polling policy — "improving the method further" (paper
//! conclusion): instead of a fixed `p`, poll classes until the top
//! scores account for a target fraction of the total score mass.  Easy
//! queries (one dominant class) scan one class; ambiguous queries widen
//! automatically.

/// Adaptive poll-depth policy.
#[derive(Debug, Clone, Copy)]
pub struct AdaptivePolicy {
    /// Lower bound on the poll depth.
    pub min_p: usize,
    /// Upper bound on the poll depth.
    pub max_p: usize,
    /// Target cumulative score-mass fraction in (0, 1].
    pub mass: f64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy { min_p: 1, max_p: 8, mass: 0.5 }
    }
}

impl AdaptivePolicy {
    /// Choose the poll depth for a score vector: the smallest `p` with
    /// `Σ top-p shifted-scores ≥ mass · Σ shifted-scores`, clamped to
    /// `[min_p, max_p]`.  Scores are shifted by their minimum so the
    /// mass criterion is invariant to the bilinear form's offset (dense
    /// ±1 scores can be large and nearly uniform).
    ///
    /// A perfectly uniform score vector (all shifted scores zero) is the
    /// *most* ambiguous query — no class stands out at all — so the
    /// degenerate case polls the widest, `max_p`, not `min_p`.
    pub fn choose_p(&self, scores: &[f32]) -> usize {
        let q = scores.len();
        if q == 0 {
            return self.min_p.max(1);
        }
        let min = scores.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        let mut sorted: Vec<f64> =
            scores.iter().map(|&s| (s as f64 - min).max(0.0)).collect();
        sorted.sort_by(|a, b| b.total_cmp(a));
        let total: f64 = sorted.iter().sum();
        if total <= 0.0 {
            // uniform scores: maximally ambiguous -> poll widest
            return self.max_p.clamp(1, q);
        }
        let target = self.mass.clamp(0.0, 1.0) * total;
        let mut acc = 0.0;
        let mut p = 0usize;
        for s in &sorted {
            acc += s;
            p += 1;
            if acc >= target {
                break;
            }
        }
        p.clamp(self.min_p.max(1), self.max_p.min(q).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominant_class_polls_min() {
        let pol = AdaptivePolicy { min_p: 1, max_p: 8, mass: 0.5 };
        // one huge score -> p = 1
        assert_eq!(pol.choose_p(&[100.0, 1.0, 1.0, 1.0]), 1);
    }

    #[test]
    fn uniform_scores_poll_wide() {
        let pol = AdaptivePolicy { min_p: 1, max_p: 8, mass: 0.5 };
        // perfectly uniform scores: no class stands out, the most
        // ambiguous case -> the degenerate branch must poll max_p wide
        let scores = vec![10.0f32; 16];
        assert_eq!(pol.choose_p(&scores), 8);
        // max_p wider than q clamps to q
        let narrow = vec![3.0f32; 4];
        assert_eq!(pol.choose_p(&narrow), 4);
        let scores: Vec<f32> = (0..16).map(|i| 10.0 + (i % 2) as f32).collect();
        let p = pol.choose_p(&scores);
        assert!(p > 1 && p <= 8, "p={p}");
    }

    #[test]
    fn respects_bounds() {
        let pol = AdaptivePolicy { min_p: 2, max_p: 3, mass: 0.99 };
        assert_eq!(pol.choose_p(&[100.0, 0.0, 0.0, 0.0, 0.0]), 2); // min
        let uniform: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(pol.choose_p(&uniform), 3); // max
    }

    #[test]
    fn monotone_in_mass() {
        let scores: Vec<f32> = vec![9.0, 7.0, 5.0, 3.0, 1.0, 0.5, 0.2, 0.1];
        let mut last = 0;
        for mass in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
            let pol = AdaptivePolicy { min_p: 1, max_p: 8, mass };
            let p = pol.choose_p(&scores);
            assert!(p >= last, "mass={mass}: p={p} < {last}");
            last = p;
        }
    }

    #[test]
    fn empty_and_degenerate() {
        let pol = AdaptivePolicy::default();
        assert_eq!(pol.choose_p(&[]), 1);
        // a single class is uniform by definition: max_p clamps to q = 1
        assert_eq!(pol.choose_p(&[5.0]), 1);
        // two identical scores: ambiguous -> max_p clamped to q = 2
        assert_eq!(pol.choose_p(&[0.0, 0.0]), 2);
    }
}
