//! Distance / similarity primitives used by the candidate scan and the
//! baselines.  The squared-L2 kernel is the hot loop of the exhaustive
//! stage; it is written with 4-way unrolled accumulators so LLVM
//! auto-vectorizes it without a SIMD dependency.

/// Squared Euclidean distance.
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// `sq_l2` with threshold-based early abandoning, used by the batched
/// class-grouped candidate scan: the 4-lane accumulation is *identical*
/// to [`sq_l2`] (same operations in the same order), probed every 32
/// coordinates.  Squared differences are non-negative, so every partial
/// lane sum is a lower bound on the final distance; a probe exceeding
/// `bound` proves the full distance does too and the candidate can be
/// abandoned without changing any reported value bitwise.
///
/// Returns `None` iff the distance is strictly greater than `bound`
/// (ties survive, preserving the scan's `dist == best && id < best_id`
/// tie-break), otherwise `Some(d)` with `d` bitwise identical to
/// `sq_l2(a, b)`.
#[inline]
fn sq_l2_pruned(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i < stop {
            let j = i * 4;
            let d0 = a[j] - b[j];
            let d1 = a[j + 1] - b[j + 1];
            let d2 = a[j + 2] - b[j + 2];
            let d3 = a[j + 3] - b[j + 3];
            s0 += d0 * d0;
            s1 += d1 * d1;
            s2 += d2 * d2;
            s3 += d3 * d3;
            i += 1;
        }
        // probe only reads the lanes; accumulation state is untouched
        if s0 + s1 + s2 + s3 > bound {
            return None;
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

/// Metric distance with early abandoning against `bound`.
///
/// Contract: returns `None` iff `metric.distance(a, b) > bound`
/// (strictly), otherwise `Some(d)` with `d` bitwise identical to
/// [`Metric::distance`].  Squared-L2 abandons mid-accumulation; the
/// other metrics are not monotone in their partial sums and compute
/// fully before comparing.
#[inline]
pub fn distance_pruned(metric: Metric, a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    match metric {
        Metric::SqL2 => sq_l2_pruned(a, b, bound),
        _ => {
            let d = metric.distance(a, b);
            if d > bound {
                None
            } else {
                Some(d)
            }
        }
    }
}

/// Dot product (similarity for ±1 / normalized data).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Hamming distance between binary (0/1 or ±1) vectors, counting
/// coordinates that differ.
#[inline]
pub fn hamming(a: &[f32], b: &[f32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count() as u32
}

/// Metric selector used across index and baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Squared Euclidean distance (smaller is closer).
    SqL2,
    /// Negative dot product (smaller is closer) — equivalent to cosine
    /// on unit-normalized data.
    NegDot,
    /// Hamming distance (smaller is closer).
    Hamming,
}

impl std::str::FromStr for Metric {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sq_l2" | "l2" => Ok(Metric::SqL2),
            "neg_dot" | "dot" => Ok(Metric::NegDot),
            "hamming" => Ok(Metric::Hamming),
            other => Err(crate::error::Error::Config(format!(
                "unknown metric '{other}' (sq_l2|neg_dot|hamming)"
            ))),
        }
    }
}

impl std::fmt::Display for Metric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Metric::SqL2 => write!(f, "sq_l2"),
            Metric::NegDot => write!(f, "neg_dot"),
            Metric::Hamming => write!(f, "hamming"),
        }
    }
}

impl Metric {
    /// Distance under this metric; always "smaller is closer".
    #[inline]
    pub fn distance(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::SqL2 => sq_l2(a, b),
            Metric::NegDot => -dot(a, b),
            Metric::Hamming => hamming(a, b) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_l2_known() {
        assert_eq!(sq_l2(&[0., 0.], &[3., 4.]), 25.0);
        assert_eq!(sq_l2(&[1., 2., 3., 4., 5.], &[1., 2., 3., 4., 5.]), 0.0);
    }

    #[test]
    fn sq_l2_matches_naive_on_odd_lengths() {
        for n in [1, 3, 5, 7, 13, 16, 127] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..n).map(|i| (n - i) as f32 * 0.25).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sq_l2(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        for n in [1, 4, 9, 130] {
            let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32 - 3.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32 - 2.0).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn hamming_counts_diffs() {
        assert_eq!(hamming(&[1., -1., 1.], &[1., 1., -1.]), 2);
        assert_eq!(hamming(&[0., 1.], &[0., 1.]), 0);
    }

    #[test]
    fn pruned_distance_is_bitwise_identical_when_kept() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(77);
        for n in [1usize, 4, 7, 16, 31, 32, 33, 64, 127, 128, 369] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let full = sq_l2(&a, &b);
            // unbounded: always kept, bitwise equal
            let kept = sq_l2_pruned(&a, &b, f32::INFINITY).unwrap();
            assert_eq!(kept.to_bits(), full.to_bits(), "n={n}");
            // bound exactly at the distance: ties survive
            assert_eq!(sq_l2_pruned(&a, &b, full), Some(full), "n={n}");
            // bound strictly below: abandoned
            if full > 0.0 {
                assert_eq!(sq_l2_pruned(&a, &b, full * 0.999), None, "n={n}");
            }
            for metric in [Metric::SqL2, Metric::NegDot, Metric::Hamming] {
                let d = metric.distance(&a, &b);
                assert_eq!(distance_pruned(metric, &a, &b, f32::INFINITY), Some(d));
                assert_eq!(distance_pruned(metric, &a, &b, d), Some(d));
            }
        }
    }

    #[test]
    fn pruned_distance_abandons_early_on_long_vectors() {
        // a huge difference in the first coordinates must trip the probe
        let mut a = vec![0f32; 512];
        let b = vec![0f32; 512];
        a[0] = 1000.0;
        assert_eq!(sq_l2_pruned(&a, &b, 10.0), None);
        assert_eq!(sq_l2_pruned(&a, &b, 1e7), Some(1e6));
    }

    #[test]
    fn metric_orderings_agree_for_unit_vectors() {
        // on unit vectors, sq_l2 = 2 - 2 dot, so rankings agree
        let q = [0.6f32, 0.8];
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let l2_order = Metric::SqL2.distance(&q, &a) < Metric::SqL2.distance(&q, &b);
        let dot_order =
            Metric::NegDot.distance(&q, &a) < Metric::NegDot.distance(&q, &b);
        assert_eq!(l2_order, dot_order);
    }
}
