//! Distance / similarity primitives used by the candidate scan and the
//! baselines.  The squared-L2 kernel is the hot loop of the exhaustive
//! stage; it is written with 4-way unrolled accumulators so LLVM
//! auto-vectorizes it without a SIMD dependency.
//!
//! Every distance whose per-coordinate terms are non-negative shares one
//! early-abandon loop through the [`DistanceKernel`] seam: `sq_l2`, the
//! SQ8 integer kernel, and the PQ ADC lookup kernel (see
//! [`crate::quant`]) are all the same 4-lane accumulation over a
//! different term producer, so the pruning logic — and its bitwise
//! guarantees — lives in exactly one place.

/// A distance expressible as a sum of **non-negative** terms, so every
/// partial prefix sum is a lower bound on the full distance.  This is
/// the contract the shared early-abandon loop
/// ([`accumulate_pruned`]) relies on: a partial sum exceeding the bound
/// proves the full distance does too.
///
/// `term(j)` must be pure (same value on every call) — the accumulation
/// loops call it exactly once per index, in ascending order within each
/// 4-lane block.
pub trait DistanceKernel {
    /// Number of terms in the sum.
    fn terms(&self) -> usize;
    /// The `j`-th non-negative term.
    fn term(&self, j: usize) -> f32;
}

/// Squared-L2 terms over two f32 slices: `term(j) = (a[j] - b[j])²`.
pub struct SqL2Terms<'a> {
    /// Left operand.
    pub a: &'a [f32],
    /// Right operand.
    pub b: &'a [f32],
}

impl DistanceKernel for SqL2Terms<'_> {
    #[inline(always)]
    fn terms(&self) -> usize {
        self.a.len()
    }
    #[inline(always)]
    fn term(&self, j: usize) -> f32 {
        let d = self.a[j] - self.b[j];
        d * d
    }
}

/// Full accumulation of a kernel's terms: 4 unrolled lanes folded at the
/// end, remainder scalar — the exact operation order of the historical
/// `sq_l2`, so [`sq_l2`] stays bitwise stable across the refactor.
#[inline]
pub fn accumulate<K: DistanceKernel>(kernel: &K) -> f32 {
    let n = kernel.terms();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += kernel.term(j);
        s1 += kernel.term(j + 1);
        s2 += kernel.term(j + 2);
        s3 += kernel.term(j + 3);
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += kernel.term(j);
    }
    s
}

/// [`accumulate`] with threshold-based early abandoning: identical lane
/// accumulation (same operations in the same order), probed every 32
/// terms.  Terms are non-negative by the [`DistanceKernel`] contract, so
/// every partial lane sum is a lower bound on the final distance; a
/// probe exceeding `bound` proves the full distance does too and the
/// candidate can be abandoned without changing any reported value
/// bitwise.
///
/// Returns `None` iff the distance is strictly greater than `bound`
/// (ties survive, preserving the scan's `dist == best && id < best_id`
/// tie-break), otherwise `Some(d)` with `d` bitwise identical to
/// `accumulate(kernel)`.
#[inline]
pub fn accumulate_pruned<K: DistanceKernel>(kernel: &K, bound: f32) -> Option<f32> {
    let n = kernel.terms();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut i = 0usize;
    while i < chunks {
        let stop = (i + 8).min(chunks);
        while i < stop {
            let j = i * 4;
            s0 += kernel.term(j);
            s1 += kernel.term(j + 1);
            s2 += kernel.term(j + 2);
            s3 += kernel.term(j + 3);
            i += 1;
        }
        // probe only reads the lanes; accumulation state is untouched
        if s0 + s1 + s2 + s3 > bound {
            return None;
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += kernel.term(j);
    }
    if s > bound {
        None
    } else {
        Some(s)
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    accumulate(&SqL2Terms { a, b })
}

/// `sq_l2` with threshold-based early abandoning, used by the batched
/// class-grouped candidate scan (see [`accumulate_pruned`] for the
/// bitwise contract).
#[inline]
pub(crate) fn sq_l2_pruned(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    accumulate_pruned(&SqL2Terms { a, b }, bound)
}

/// Metric distance with early abandoning against `bound`.
///
/// Contract: returns `None` iff `metric.distance(a, b) > bound`
/// (strictly), otherwise `Some(d)` with `d` bitwise identical to
/// [`Metric::distance`].  Squared-L2 abandons mid-accumulation; the
/// other metrics are not monotone in their partial sums and compute
/// fully before comparing.
#[inline]
pub fn distance_pruned(metric: Metric, a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    match metric {
        Metric::SqL2 => sq_l2_pruned(a, b, bound),
        _ => {
            let d = metric.distance(a, b);
            if d > bound {
                None
            } else {
                Some(d)
            }
        }
    }
}

/// Dot product (similarity for ±1 / normalized data).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Hamming distance between binary (0/1 or ±1) vectors, counting
/// coordinates that differ.  Written as a 4-wide chunked count (like the
/// distance loops) so LLVM vectorizes the compares; counts are integers,
/// so any evaluation order yields the identical result.
#[inline]
pub fn hamming(a: &[f32], b: &[f32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..chunks {
        let j = i * 4;
        c0 += u32::from(a[j] != b[j]);
        c1 += u32::from(a[j + 1] != b[j + 1]);
        c2 += u32::from(a[j + 2] != b[j + 2]);
        c3 += u32::from(a[j + 3] != b[j + 3]);
    }
    let mut c = c0 + c1 + c2 + c3;
    for j in chunks * 4..n {
        c += u32::from(a[j] != b[j]);
    }
    c
}

/// Metric selector used across index and baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Squared Euclidean distance (smaller is closer).
    SqL2,
    /// Negative dot product (smaller is closer) — equivalent to cosine
    /// on unit-normalized data.
    NegDot,
    /// Hamming distance (smaller is closer).
    Hamming,
}

impl std::str::FromStr for Metric {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sq_l2" | "l2" => Ok(Metric::SqL2),
            "neg_dot" | "dot" => Ok(Metric::NegDot),
            "hamming" => Ok(Metric::Hamming),
            other => Err(crate::error::Error::Config(format!(
                "unknown metric '{other}' (sq_l2|neg_dot|hamming)"
            ))),
        }
    }
}

impl std::fmt::Display for Metric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Metric::SqL2 => write!(f, "sq_l2"),
            Metric::NegDot => write!(f, "neg_dot"),
            Metric::Hamming => write!(f, "hamming"),
        }
    }
}

impl Metric {
    /// Distance under this metric; always "smaller is closer".
    #[inline]
    pub fn distance(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::SqL2 => sq_l2(a, b),
            Metric::NegDot => -dot(a, b),
            Metric::Hamming => hamming(a, b) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_l2_known() {
        assert_eq!(sq_l2(&[0., 0.], &[3., 4.]), 25.0);
        assert_eq!(sq_l2(&[1., 2., 3., 4., 5.], &[1., 2., 3., 4., 5.]), 0.0);
    }

    #[test]
    fn sq_l2_matches_naive_on_odd_lengths() {
        for n in [1, 3, 5, 7, 13, 16, 127] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..n).map(|i| (n - i) as f32 * 0.25).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sq_l2(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        for n in [1, 4, 9, 130] {
            let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32 - 3.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32 - 2.0).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn hamming_counts_diffs() {
        assert_eq!(hamming(&[1., -1., 1.], &[1., 1., -1.]), 2);
        assert_eq!(hamming(&[0., 1.], &[0., 1.]), 0);
    }

    #[test]
    fn pruned_distance_is_bitwise_identical_when_kept() {
        use crate::data::rng::Rng;
        let mut rng = Rng::new(77);
        for n in [1usize, 4, 7, 16, 31, 32, 33, 64, 127, 128, 369] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let full = sq_l2(&a, &b);
            // unbounded: always kept, bitwise equal
            let kept = sq_l2_pruned(&a, &b, f32::INFINITY).unwrap();
            assert_eq!(kept.to_bits(), full.to_bits(), "n={n}");
            // bound exactly at the distance: ties survive
            assert_eq!(sq_l2_pruned(&a, &b, full), Some(full), "n={n}");
            // bound strictly below: abandoned
            if full > 0.0 {
                assert_eq!(sq_l2_pruned(&a, &b, full * 0.999), None, "n={n}");
            }
            for metric in [Metric::SqL2, Metric::NegDot, Metric::Hamming] {
                let d = metric.distance(&a, &b);
                assert_eq!(distance_pruned(metric, &a, &b, f32::INFINITY), Some(d));
                assert_eq!(distance_pruned(metric, &a, &b, d), Some(d));
            }
        }
    }

    #[test]
    fn pruned_distance_abandons_early_on_long_vectors() {
        // a huge difference in the first coordinates must trip the probe
        let mut a = vec![0f32; 512];
        let b = vec![0f32; 512];
        a[0] = 1000.0;
        assert_eq!(sq_l2_pruned(&a, &b, 10.0), None);
        assert_eq!(sq_l2_pruned(&a, &b, 1e7), Some(1e6));
    }

    #[test]
    fn generic_kernel_loop_matches_dedicated_sq_l2() {
        // the DistanceKernel seam must be an exact refactor: the generic
        // loops over SqL2Terms reproduce sq_l2 / sq_l2_pruned bitwise
        use crate::data::rng::Rng;
        let mut rng = Rng::new(123);
        for n in [0usize, 1, 5, 32, 33, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let k = SqL2Terms { a: &a, b: &b };
            assert_eq!(accumulate(&k).to_bits(), sq_l2(&a, &b).to_bits());
            assert_eq!(
                accumulate_pruned(&k, f32::INFINITY).map(f32::to_bits),
                Some(sq_l2(&a, &b).to_bits())
            );
        }
    }

    /// A toy kernel over precomputed non-negative terms — stands in for
    /// the quant ADC kernels, which sum table lookups the same way.
    struct TermSlice<'a>(&'a [f32]);
    impl DistanceKernel for TermSlice<'_> {
        fn terms(&self) -> usize {
            self.0.len()
        }
        fn term(&self, j: usize) -> f32 {
            self.0[j]
        }
    }

    #[test]
    fn pruned_accumulation_abandons_and_keeps_correctly_for_any_kernel() {
        let terms: Vec<f32> = (0..70).map(|i| (i % 7) as f32).collect();
        let full: f32 = accumulate(&TermSlice(&terms));
        assert_eq!(accumulate_pruned(&TermSlice(&terms), full), Some(full));
        assert_eq!(accumulate_pruned(&TermSlice(&terms), full - 0.5), None);
        // a huge early term must trip the 32-term probe
        let mut spiked = vec![0f32; 512];
        spiked[0] = 1e9;
        assert_eq!(accumulate_pruned(&TermSlice(&spiked), 10.0), None);
    }

    #[test]
    fn metric_orderings_agree_for_unit_vectors() {
        // on unit vectors, sq_l2 = 2 - 2 dot, so rankings agree
        let q = [0.6f32, 0.8];
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let l2_order = Metric::SqL2.distance(&q, &a) < Metric::SqL2.distance(&q, &b);
        let dot_order =
            Metric::NegDot.distance(&q, &a) < Metric::NegDot.distance(&q, &b);
        assert_eq!(l2_order, dot_order);
    }
}
