//! The TCP front door: an accept loop feeding a bounded pool of
//! connection-handler threads, layered on any [`Serveable`] backend —
//! the single-node [`SearchServer`] or the cluster tier's
//! scatter-gather router.
//!
//! ```text
//! accept loop ──► bounded conn queue ──► handler pool (N threads)
//!                                          │  per connection:
//!                                          │   reader: decode frames,
//!                                          │     validate, submit to the
//!                                          │     coordinator (shared
//!                                          │     response funnel, many
//!                                          │     requests in flight)
//!                                          │   writer: encode responses
//!                                          │     as they complete,
//!                                          │     matched by request id
//! ```
//!
//! * **Pipelining** — a connection may have up to
//!   [`NetConfig::max_inflight`] searches outstanding; responses are
//!   written in *completion* order and matched by the client via the
//!   echoed request id.  The reader stops pulling new frames while the
//!   window is full, so a flooding client is throttled by TCP itself.
//! * **Backpressure** — submissions go through the coordinator's
//!   bounded request queue; when it is full the reader blocks, the
//!   socket's receive buffer fills, and the client's `write` stalls.
//! * **Graceful shutdown** — a SHUTDOWN frame (or
//!   [`NetServer::shutdown`]) stops the accept loop and tells every
//!   connection to stop *reading*; responses for everything already
//!   submitted still drain through the writers before the sockets
//!   close.  Only after [`NetServer::join`] returns should the owner
//!   shut the underlying [`SearchServer`] down — that ordering is what
//!   guarantees in-flight network requests are never dropped.
//! * **Dual encoding** — the first byte of a connection selects the
//!   protocol: `{` switches to JSON-lines (debug mode), anything else
//!   must begin a binary `AMNP` frame.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::{SearchResponse, SearchServer};
use crate::error::{Error, Result};
use crate::obs::{prom, Registry};
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};
use crate::util::Json;

use super::wire::{
    self, Frame, FrameBuffer, WireError, WireRequest, WireResponse, ERR_BAD_DIM,
    ERR_BAD_FRAME, ERR_INTERNAL, ERR_OVERLOADED, ERR_SHUTTING_DOWN,
};

/// The backend a TCP front door serves.  The front door adds transport
/// only; the backend defines the search semantics.  Implemented by the
/// single-node [`SearchServer`] (coordinator pipeline) and by the
/// cluster tier's scatter-gather router
/// ([`ClusterRouter`](crate::cluster::ClusterRouter)), so one wire
/// protocol and one server loop cover both roles.
pub trait Serveable: Send + Sync {
    /// Submit a k-NN query without blocking for its result; exactly one
    /// response (success *or* explicit error) must be delivered on
    /// `resp` with `id` echoed.  Same contract as
    /// [`SearchServer::submit`].  `trace_id` = 0 means untraced; a
    /// non-zero id arrived on the wire (router → shard propagation) and
    /// must be honoured so the tiers' span records stitch.
    fn submit(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        id: u64,
        trace_id: u64,
        resp: SyncSender<SearchResponse>,
    ) -> Result<()>;

    /// Metrics snapshot — the payload of the STATS admin op.  Must be a
    /// JSON object carrying at least `dim` and `n_vectors` (load
    /// generators discover the query shape from it).
    fn stats_json(&self) -> Json;

    /// Prometheus-style registry — the payload of the METRICS admin op.
    /// Must derive from the same snapshot as [`Self::stats_json`] so
    /// the two export surfaces never disagree.
    fn metrics_registry(&self) -> Registry;

    /// Replay one query with full per-stage introspection — the payload
    /// of the EXPLAIN admin op.  Runs synchronously off the serving
    /// pipeline (a fresh engine / fresh shard links), so traffic is
    /// never perturbed.  `exact` additionally runs the tier's
    /// ground-truth re-execution and reports the diff.
    fn explain(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        exact: bool,
    ) -> Result<Json>;
}

impl Serveable for SearchServer {
    fn submit(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        id: u64,
        trace_id: u64,
        resp: SyncSender<SearchResponse>,
    ) -> Result<()> {
        SearchServer::submit(self, vector, top_p, top_k, id, trace_id, resp)
    }

    fn stats_json(&self) -> Json {
        SearchServer::stats_json(self)
    }

    fn metrics_registry(&self) -> Registry {
        SearchServer::metrics_registry(self)
    }

    fn explain(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        exact: bool,
    ) -> Result<Json> {
        SearchServer::explain(self, vector, top_p, top_k, exact)
    }
}

/// Network front-door configuration.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Connection-handler pool size (concurrent connections served;
    /// further accepted connections wait in a queue of the same size,
    /// beyond which they are refused with an `ERR_OVERLOADED` frame).
    pub max_connections: usize,
    /// Maximum pipelined (in-flight) searches per connection.
    pub max_inflight: usize,
    /// Read-poll interval: how often blocked reads wake to check for
    /// shutdown.
    pub poll_ms: u64,
    /// Role label injected into STATS replies (overrides the backend's
    /// own `role` field when set) — lets a cluster harness label its
    /// in-process shard servers "shard" while the router front door
    /// keeps the backend's "router".
    pub role: Option<&'static str>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { max_connections: 64, max_inflight: 128, poll_ms: 25, role: None }
    }
}

impl NetConfig {
    /// Validate the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.max_connections == 0 {
            return Err(Error::Config("net.max_connections must be > 0".into()));
        }
        if self.max_inflight == 0 {
            return Err(Error::Config("net.max_inflight must be > 0".into()));
        }
        if self.poll_ms == 0 {
            return Err(Error::Config("net.poll_ms must be > 0".into()));
        }
        Ok(())
    }
}

/// State shared between the accept loop and every connection handler.
struct Shared {
    backend: Arc<dyn Serveable>,
    cfg: NetConfig,
    down: AtomicBool,
    /// Our own listen address, used to self-connect once so a blocked
    /// `accept` wakes up and observes the shutdown flag.
    addr: SocketAddr,
    /// Connections refused with `ERR_OVERLOADED` (handler pool + queue
    /// exhausted) — exported in STATS so routers can do overload-aware
    /// shard selection.
    refused: AtomicU64,
    /// Searches currently pipelined across all connections (claimed
    /// window slots whose responses have not been written yet).
    inflight: AtomicU64,
}

impl Shared {
    fn down(&self) -> bool {
        self.down.load(Ordering::SeqCst)
    }

    fn begin_shutdown(&self) {
        if !self.down.swap(true, Ordering::SeqCst) {
            let _ = TcpStream::connect(self.addr); // wake the accept loop
        }
    }
}

/// Handle to a running TCP front door.
pub struct NetServer {
    shared: Arc<Shared>,
    local: SocketAddr,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `backend` over it.  The backend must outlive the
    /// front door and must only be shut down after [`Self::join`]
    /// returns.
    pub fn bind(
        backend: Arc<dyn Serveable>,
        addr: impl ToSocketAddrs,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Coordinator(format!("net: bind failed: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("net: local_addr: {e}")))?;
        let shared = Arc::new(Shared {
            backend,
            cfg,
            down: AtomicBool::new(false),
            addr: local,
            refused: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("amsearch-net-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .map_err(|e| Error::Coordinator(format!("spawn accept loop: {e}")))?
        };
        Ok(NetServer { shared, local, accept: Mutex::new(Some(accept)) })
    }

    /// The bound address (resolves the port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// True once shutdown has begun (via [`Self::shutdown`] or a
    /// SHUTDOWN frame from any client).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.down()
    }

    /// Block until the front door has fully drained and closed — either
    /// because a client sent a SHUTDOWN frame or because
    /// [`Self::shutdown`] was called from another thread.
    pub fn join(&self) {
        let handle = lock_unpoisoned(&self.accept).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, stop reading new requests,
    /// drain every in-flight response, close all connections.
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
        self.join();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Accept loop + handler pool (runs on the accept thread; joins the
/// pool before returning so `NetServer::join` means "fully drained").
fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let pool = shared.cfg.max_connections;
    let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(pool);
    let conn_rx: Arc<Mutex<Receiver<TcpStream>>> = Arc::new(Mutex::new(conn_rx));
    let mut handlers = Vec::with_capacity(pool);
    for hi in 0..pool {
        let rx = conn_rx.clone();
        let shared = shared.clone();
        let h = std::thread::Builder::new()
            .name(format!("amsearch-net-conn-{hi}"))
            .spawn(move || loop {
                // take one connection under the lock, release before work
                let stream = {
                    let guard = lock_unpoisoned(&rx);
                    // amlint: allow(lock_blocking, reason = "the guard IS the hand-off: idle handlers queue on this lock until a connection arrives")
                    match guard.recv() {
                        Ok(s) => s,
                        Err(_) => return,
                    }
                };
                handle_connection(stream, &shared);
            });
        match h {
            Ok(h) => handlers.push(h),
            // thread exhaustion: serve with however many handlers did
            // start (zero is handled below)
            Err(_) => {}
        }
    }
    if handlers.is_empty() {
        // nothing can ever service a connection; accepting would strand
        // clients in the queue forever
        return;
    }
    for conn in listener.incoming() {
        if shared.down() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(shared.cfg.poll_ms));
                continue;
            }
        };
        match conn_tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(mut stream)) => {
                // refuse with a stable error code instead of an opaque
                // reset (best effort; the client may already be gone)
                shared.refused.fetch_add(1, Ordering::Relaxed);
                let frame = Frame::Error(WireError {
                    id: 0,
                    code: ERR_OVERLOADED,
                    message: "connection-handler pool exhausted".into(),
                });
                // amlint: allow(store_io, reason = "refusal notice to an overloaded client is best-effort; the socket closes either way")
                let _ = stream.write_all(&frame.encode());
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    drop(conn_tx); // handlers finish their current connection and exit
    for h in handlers {
        let _ = h.join();
    }
}

/// Serializing writer over one socket: whole frames only, so the reader
/// thread (admin replies, validation errors) and the writer thread
/// (search responses) can interleave safely.
#[derive(Clone)]
struct ConnWriter {
    stream: Arc<Mutex<TcpStream>>,
    json: bool,
}

impl ConnWriter {
    /// Write one frame; errors are ignored (a vanished client must not
    /// abort the drain — in-flight responses still need to be consumed
    /// so the coordinator-side senders are released).
    fn send(&self, frame: &Frame) {
        let bytes = if self.json {
            frame.to_json_line().into_bytes()
        } else {
            frame.encode()
        };
        // recover from poisoning: a panicked writer must not silently
        // eat every later frame on the connection (the stream itself is
        // just an fd; there is no torn state to fear beyond a possibly
        // truncated frame, which only this client observes)
        let mut s = lock_unpoisoned(&self.stream);
        // amlint: allow(lock_blocking, reason = "this mutex exists to serialize whole frames onto the socket; the 30s write timeout bounds the hold")
        // amlint: allow(store_io, reason = "a vanished client must not abort the drain; see the doc comment above")
        let _ = s.write_all(&bytes);
    }
}

/// Pipelining window: current in-flight count + wakeup for the reader.
type Inflight = Arc<(Mutex<usize>, Condvar)>;

fn release_slot(inflight: &Inflight, shared: &Shared) {
    let (m, cv) = &**inflight;
    let mut n = lock_unpoisoned(m);
    *n = n.saturating_sub(1);
    cv.notify_all();
    // the server-wide gauge moves in lockstep with the per-connection
    // windows: every release pairs with exactly one claim
    shared.inflight.fetch_sub(1, Ordering::Relaxed);
}

/// One accepted connection: sniff the encoding from the first byte,
/// then run the reader loop until EOF, fatal corruption, or shutdown.
fn handle_connection(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    // a stalled client that stops reading must not wedge a handler
    // thread forever (writes would otherwise block once the socket
    // buffer fills and shutdown could never join the pool); after the
    // timeout its stream is abandoned mid-frame, which only that
    // client observes
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    if stream
        .set_read_timeout(Some(Duration::from_millis(shared.cfg.poll_ms)))
        .is_err()
    {
        return;
    }
    // mode sniff: peek (not consume) the first byte
    let mut first = [0u8; 1];
    loop {
        match stream.peek(&mut first) {
            Ok(0) => return, // closed before sending anything
            Ok(_) => break,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shared.down() {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    let json = first[0] == b'{';
    let write_half = match stream.try_clone() {
        Ok(s) => Arc::new(Mutex::new(s)),
        Err(_) => return,
    };
    let out = ConnWriter { stream: write_half, json };

    // the shared response funnel: every in-flight search on this
    // connection completes onto this channel; capacity == the window
    // size, so coordinator workers can never block on a slow client
    let (resp_tx, resp_rx) =
        mpsc::sync_channel::<SearchResponse>(shared.cfg.max_inflight);
    let inflight: Inflight = Arc::new((Mutex::new(0usize), Condvar::new()));

    let writer = {
        let out = out.clone();
        let inflight = inflight.clone();
        let shared = shared.clone();
        let spawned = std::thread::Builder::new()
            .name("amsearch-net-writer".into())
            .spawn(move || {
                while let Ok(resp) = resp_rx.recv() {
                    out.send(&response_frame(resp));
                    release_slot(&inflight, &shared);
                }
            });
        match spawned {
            Ok(h) => h,
            // no writer means no response could ever be delivered;
            // refuse the connection cleanly before any request is read
            Err(_) => return,
        }
    };

    if json {
        read_loop_json(&stream, shared, &out, &resp_tx, &inflight);
    } else {
        read_loop_binary(&stream, shared, &out, &resp_tx, &inflight);
    }

    // drain: dropping our funnel sender leaves only the in-flight
    // requests' clones; once the coordinator answers them all, the
    // writer's recv disconnects and the thread exits — every accepted
    // request got its response frame before the socket closes
    drop(resp_tx);
    let _ = writer.join();
}

/// Convert a coordinator response into its wire frame.  Every error
/// that travels the response funnel is a serving-pipeline failure
/// (engine error, worker pool gone), so it is `ERR_INTERNAL` by
/// construction; shutdown refusals are coded where they are *typed* —
/// at submit time in [`dispatch_search`] — never inferred from message
/// text.
fn response_frame(resp: SearchResponse) -> Frame {
    match resp.error {
        Some(message) => Frame::Error(WireError {
            id: resp.id,
            code: ERR_INTERNAL,
            message,
        }),
        None => Frame::Result(WireResponse {
            id: resp.id,
            neighbors: resp.neighbors,
            polled: resp.polled,
            candidates: resp.candidates as u64,
            ops: resp.ops,
            service_ns: resp.service_ns,
        }),
    }
}

/// Handle one parsed (or unparseable) client frame.  Returns `false`
/// when the connection should stop reading (shutdown initiated).
fn dispatch(
    parsed: std::result::Result<Frame, WireError>,
    shared: &Shared,
    out: &ConnWriter,
    resp_tx: &SyncSender<SearchResponse>,
    inflight: &Inflight,
) -> bool {
    let frame = match parsed {
        Ok(f) => f,
        Err(we) => {
            // recoverable: the frame/line boundary kept the stream in
            // sync, so answer with a typed error and keep serving
            out.send(&Frame::Error(we));
            return true;
        }
    };
    match frame {
        Frame::Ping { id } => {
            out.send(&Frame::Pong { id });
            true
        }
        Frame::Stats { id } => {
            let mut stats = shared.backend.stats_json();
            if let Json::Obj(map) = &mut stats {
                if let Some(role) = shared.cfg.role {
                    map.insert("role".to_string(), Json::Str(role.to_string()));
                }
                // net-layer counters ride alongside the backend snapshot:
                // refusals + current pipelined depth (overload signals
                // for the cluster router's shard selection)
                let mut net = std::collections::BTreeMap::new();
                net.insert(
                    "refused_connections".to_string(),
                    Json::Num(shared.refused.load(Ordering::Relaxed) as f64),
                );
                net.insert(
                    "inflight".to_string(),
                    Json::Num(shared.inflight.load(Ordering::Relaxed) as f64),
                );
                net.insert(
                    "max_connections".to_string(),
                    Json::Num(shared.cfg.max_connections as f64),
                );
                net.insert(
                    "max_inflight".to_string(),
                    Json::Num(shared.cfg.max_inflight as f64),
                );
                map.insert("net".to_string(), Json::Obj(net));
            }
            out.send(&Frame::StatsReply { id, json: stats.to_string() });
            true
        }
        Frame::Metrics { id } => {
            // same discipline as STATS: one backend snapshot, plus the
            // net layer's own transport families, rendered as
            // Prometheus text exposition
            let mut reg = shared.backend.metrics_registry();
            reg.counter(
                prom::M_NET_REFUSED,
                &[],
                shared.refused.load(Ordering::Relaxed),
            );
            reg.gauge(
                prom::M_NET_INFLIGHT,
                &[],
                shared.inflight.load(Ordering::Relaxed) as f64,
            );
            if let Some(role) = shared.cfg.role {
                reg.relabel("role", role);
            }
            out.send(&Frame::MetricsReply { id, text: reg.render() });
            true
        }
        Frame::Shutdown { id } => {
            out.send(&Frame::ShutdownOk { id });
            shared.begin_shutdown();
            false
        }
        Frame::Search(req) => {
            dispatch_search(req, shared, out, resp_tx, inflight);
            true
        }
        Frame::Explain(req) => {
            // synchronous admin op, like STATS: the backend replays the
            // query off its serving pipeline and reports per-stage detail
            let id = req.id;
            match shared.backend.explain(
                req.vector,
                req.top_p as usize,
                req.top_k as usize,
                req.exact,
            ) {
                Ok(json) => {
                    out.send(&Frame::ExplainReply { id, json: json.to_string() })
                }
                Err(e) => {
                    let code = match &e {
                        Error::Shape(_) => ERR_BAD_DIM,
                        _ => ERR_INTERNAL,
                    };
                    out.send(&Frame::Error(WireError {
                        id,
                        code,
                        message: e.to_string(),
                    }));
                }
            }
            true
        }
        other => {
            out.send(&Frame::Error(WireError {
                id: other.id(),
                code: ERR_BAD_FRAME,
                message: "frame type is not a client request".into(),
            }));
            true
        }
    }
}

fn dispatch_search(
    req: WireRequest,
    shared: &Shared,
    out: &ConnWriter,
    resp_tx: &SyncSender<SearchResponse>,
    inflight: &Inflight,
) {
    if shared.down() {
        out.send(&Frame::Error(WireError {
            id: req.id,
            code: ERR_SHUTTING_DOWN,
            message: "server is shutting down".into(),
        }));
        return;
    }
    // claim a pipelining slot; the window bounds how many responses can
    // ever queue on the funnel, which is what lets the funnel capacity
    // guarantee non-blocking completion for coordinator workers
    {
        let (m, cv) = &**inflight;
        let mut n = lock_unpoisoned(m);
        while *n >= shared.cfg.max_inflight {
            let (guard, _) = wait_timeout_unpoisoned(
                cv,
                n,
                Duration::from_millis(shared.cfg.poll_ms),
            );
            n = guard;
        }
        *n += 1;
    }
    shared.inflight.fetch_add(1, Ordering::Relaxed);
    let result = shared.backend.submit(
        req.vector,
        req.top_p as usize,
        req.top_k as usize,
        req.id,
        req.trace_id,
        resp_tx.clone(),
    );
    if let Err(e) = result {
        release_slot(inflight, shared);
        let code = match &e {
            Error::Shape(_) => ERR_BAD_DIM,
            _ => ERR_SHUTTING_DOWN,
        };
        out.send(&Frame::Error(WireError {
            id: req.id,
            code,
            message: e.to_string(),
        }));
    }
}

/// Is this io error just the poll-interval read timeout?
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

fn read_loop_binary(
    stream: &TcpStream,
    shared: &Shared,
    out: &ConnWriter,
    resp_tx: &SyncSender<SearchResponse>,
    inflight: &Inflight,
) {
    let mut fb = FrameBuffer::new();
    let mut chunk = vec![0u8; 16 * 1024];
    loop {
        // drain complete frames before reading more bytes
        loop {
            match fb.next_raw() {
                Ok(None) => break,
                Ok(Some(raw)) => {
                    let parsed = wire::parse(&raw);
                    if !dispatch(parsed, shared, out, resp_tx, inflight) {
                        return;
                    }
                }
                Err(e) => {
                    // stream lost sync: report once, then hang up
                    out.send(&Frame::Error(WireError {
                        id: 0,
                        code: ERR_BAD_FRAME,
                        message: e.to_string(),
                    }));
                    return;
                }
            }
        }
        if shared.down() {
            return;
        }
        match (&*stream).read(&mut chunk) {
            Ok(0) => return, // EOF
            Ok(n) => fb.extend(&chunk[..n]),
            Err(e) if is_timeout(&e) => {} // poll tick; re-check shutdown
            Err(_) => return,
        }
    }
}

fn read_loop_json(
    stream: &TcpStream,
    shared: &Shared,
    out: &ConnWriter,
    resp_tx: &SyncSender<SearchResponse>,
    inflight: &Inflight,
) {
    let mut lbuf: Vec<u8> = Vec::new();
    let mut chunk = vec![0u8; 16 * 1024];
    loop {
        while let Some(pos) = lbuf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = lbuf.drain(..=pos).collect();
            let text = String::from_utf8_lossy(&line);
            let text = text.trim();
            if text.is_empty() {
                continue;
            }
            let parsed = Json::parse(text)
                .map_err(|e| WireError {
                    id: 0,
                    code: ERR_BAD_FRAME,
                    message: e.to_string(),
                })
                .and_then(|v| Frame::from_json(&v));
            if !dispatch(parsed, shared, out, resp_tx, inflight) {
                return;
            }
        }
        // lbuf now holds at most one incomplete line: bound it like a
        // binary payload so a newline-free stream cannot grow server
        // memory without limit
        if lbuf.len() > super::wire::MAX_PAYLOAD as usize {
            out.send(&Frame::Error(WireError {
                id: 0,
                code: ERR_BAD_FRAME,
                message: "json line exceeds maximum frame size".into(),
            }));
            return;
        }
        if shared.down() {
            return;
        }
        match (&*stream).read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => lbuf.extend_from_slice(&chunk[..n]),
            Err(e) if is_timeout(&e) => {}
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        NetConfig::default().validate().unwrap();
        assert!(NetConfig { max_connections: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(NetConfig { max_inflight: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(NetConfig { poll_ms: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn response_frame_maps_errors_to_stable_codes() {
        let ok = SearchResponse {
            id: 3,
            neighbors: vec![],
            polled: vec![1],
            candidates: 0,
            ops: 1,
            service_ns: 2,
            error: None,
        };
        assert!(matches!(response_frame(ok), Frame::Result(r) if r.id == 3));
        // every funnel-delivered failure is a pipeline failure: typed
        // ERR_INTERNAL regardless of message wording (shutdown refusals
        // are coded at submit time, not here)
        let internal = SearchResponse::failed(5, "batch execution failed: boom");
        let Frame::Error(e) = response_frame(internal) else { panic!("not error") };
        assert_eq!(e.code, ERR_INTERNAL);
        assert_eq!(e.id, 5);
        let worded = SearchResponse::failed(6, "engine said: shutting down the GPU");
        let Frame::Error(e) = response_frame(worded) else { panic!("not error") };
        assert_eq!(e.code, ERR_INTERNAL, "message text must not drive the code");
    }

    /// A backend that refuses every submit with a non-shape error — the
    /// deterministic stand-in for a coordinator that is already
    /// draining.  Lets the `ERR_SHUTTING_DOWN` dispatch path be pinned
    /// without racing a real shutdown.
    struct RefusingBackend;

    impl Serveable for RefusingBackend {
        fn submit(
            &self,
            _vector: Vec<f32>,
            _top_p: usize,
            _top_k: usize,
            _id: u64,
            _trace_id: u64,
            _resp: SyncSender<SearchResponse>,
        ) -> Result<()> {
            Err(Error::Coordinator("server is draining".into()))
        }

        fn stats_json(&self) -> Json {
            let mut o = std::collections::BTreeMap::new();
            o.insert("dim".to_string(), Json::Num(2.0));
            o.insert("n_vectors".to_string(), Json::Num(0.0));
            Json::Obj(o)
        }

        fn metrics_registry(&self) -> Registry {
            let mut reg = Registry::new();
            reg.counter(prom::M_REQUESTS, &[], 0);
            reg.histogram(
                prom::M_LATENCY,
                &[],
                &crate::metrics::LatencyHistogram::new(),
            );
            reg.histogram(
                prom::M_WINDOW_LATENCY,
                &[],
                &crate::metrics::LatencyHistogram::new(),
            );
            reg
        }

        fn explain(
            &self,
            _vector: Vec<f32>,
            _top_p: usize,
            _top_k: usize,
            _exact: bool,
        ) -> Result<Json> {
            Err(Error::Coordinator("backend is draining".into()))
        }
    }

    #[test]
    fn refused_submit_surfaces_as_typed_shutting_down_frame() {
        let server = NetServer::bind(
            Arc::new(RefusingBackend),
            "127.0.0.1:0",
            NetConfig::default(),
        )
        .unwrap();
        let mut client =
            crate::net::NetClient::connect(server.local_addr()).unwrap();
        let id = client.submit(&[0.0, 1.0], 0, 0).unwrap();
        let resp = client.wait_detailed(id).unwrap();
        let e = resp.expect_err("refused submit must produce an ERROR frame");
        assert_eq!(e.id, id);
        assert_eq!(e.code, ERR_SHUTTING_DOWN);
        drop(client);
        server.shutdown();
    }

    #[test]
    fn explain_backend_failure_surfaces_as_typed_internal_frame() {
        let server = NetServer::bind(
            Arc::new(RefusingBackend),
            "127.0.0.1:0",
            NetConfig::default(),
        )
        .unwrap();
        let mut client =
            crate::net::NetClient::connect(server.local_addr()).unwrap();
        let err = client.explain(&[0.0, 1.0], 0, 0, true).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
        drop(client);
        server.shutdown();
    }

    #[test]
    fn metrics_frame_returns_valid_exposition_with_net_families() {
        let server = NetServer::bind(
            Arc::new(RefusingBackend),
            "127.0.0.1:0",
            NetConfig { role: Some("shard"), ..Default::default() },
        )
        .unwrap();
        let mut client =
            crate::net::NetClient::connect(server.local_addr()).unwrap();
        let text = client.metrics_text().unwrap();
        prom::validate(&text, &crate::obs::REQUIRED_FAMILIES).unwrap();
        // the net layer's own families ride along ...
        assert!(text.contains(prom::M_NET_REFUSED), "{text}");
        assert!(text.contains(prom::M_NET_INFLIGHT), "{text}");
        // ... and the configured role is stamped onto every sample
        assert!(
            text.contains("amsearch_requests_total{role=\"shard\"}"),
            "{text}"
        );
        drop(client);
        server.shutdown();
    }
}
