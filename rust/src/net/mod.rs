//! The network serving subsystem: a TCP front door over the
//! coordinator, built entirely on `std::net` + threads (the offline
//! build has no async runtime or protocol crates).
//!
//! * [`wire`] — versioned little-endian length-prefixed binary frames
//!   (+ a JSON-lines debug encoding), typed validation with stable
//!   error codes
//! * [`server`] — accept loop, bounded connection-handler pool,
//!   per-connection request pipelining, graceful drain; generic over a
//!   [`Serveable`] backend (single-node coordinator or cluster router)
//! * [`client`] — blocking client with connection reuse and pipelined
//!   `search_k`/admin calls
//! * [`loadgen`] — closed-loop multi-connection load generator
//!   reporting throughput and latency quantiles
//!
//! The front door adds *transport* only: validation, defaulting, and
//! clamping semantics are exactly the in-process
//! [`SearchServer`](crate::coordinator::SearchServer) boundary rules,
//! so a network response is bitwise-identical to the in-process answer
//! for the same query (pinned by `tests/net_e2e.rs`).

pub mod client;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use client::{NetClient, RetryPolicy};
pub use loadgen::{LoadGenConfig, LoadReport};
pub use server::{NetConfig, NetServer, Serveable};
pub use wire::{Frame, WireError, WireRequest, WireResponse};
