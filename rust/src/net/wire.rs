//! The `amsearch` network wire protocol: a versioned little-endian
//! length-prefixed binary framing in the same style as the index file
//! format (`index/persist.rs`), plus an equivalent JSON-lines encoding
//! for debuggability (`telnet`/`nc`-friendly; reuses `util::json`).
//!
//! Binary frame layout (all integers little-endian):
//!
//! ```text
//! magic    4B   "AMNP"
//! version  u8   1, or 2 for a SEARCH frame carrying a trace id
//! type     u8   frame type (see below)
//! reserved u16  0
//! id       u64  request id, echoed verbatim in the matching response
//! len      u32  payload length in bytes (<= MAX_PAYLOAD)
//! payload  len bytes
//! ```
//!
//! Frame types and payloads:
//!
//! ```text
//! 0x01 SEARCH        top_p u32, top_k u32, dim u32, dim * f32
//!                    [, trace_id u64 — version 2 only]
//! 0x02 RESULT        n u32, n * (id u32, distance f32),
//!                    n_polled u32, n_polled * u32,
//!                    candidates u64, ops u64, service_ns u64
//! 0x03 ERROR         code u16, utf-8 message (rest of payload)
//! 0x04 PING          (empty)
//! 0x05 PONG          (empty)
//! 0x06 STATS         (empty)
//! 0x07 STATS_REPLY   utf-8 JSON document (server metrics snapshot)
//! 0x08 SHUTDOWN      (empty)
//! 0x09 SHUTDOWN_OK   (empty)
//! 0x0A METRICS       (empty)
//! 0x0B METRICS_REPLY utf-8 Prometheus text exposition
//! 0x0C EXPLAIN       flags u32 (bit 0 = exact ground-truth diff),
//!                    top_p u32, top_k u32, dim u32, dim * f32
//! 0x0D EXPLAIN_REPLY utf-8 JSON document (introspection report)
//! ```
//!
//! Version 2 exists only to carry the optional 8-byte trace id on
//! SEARCH: an encoder emits version 1 whenever the trace id is 0 (the
//! overwhelmingly common case), so untraced traffic is byte-identical
//! to what v1-only peers produce and accept.  A decoder accepts both
//! versions and tells the two SEARCH layouts apart by payload length.
//!
//! Corruption handling is two-level, mirroring how a TCP stream can
//! fail: header-level damage (bad magic/version, oversized length
//! prefix, truncation) means the stream has lost sync and is
//! **connection-fatal** ([`read_raw`] / [`FrameBuffer::next_raw`] return
//! `Err`); a well-framed payload that fails structural validation is
//! **recoverable** ([`parse`] returns a [`WireError`] carrying the
//! frame's id and a stable error code, which the server sends back as an
//! ERROR frame without dropping the connection).
//!
//! The JSON-lines mode is auto-detected by the server from the first
//! byte of a connection (`{` cannot start a binary frame): one JSON
//! object per `\n`-terminated line, `{"op": "search", "id": 1,
//! "vector": [...], "top_p": 2, "top_k": 3}` in,
//! `{"op": "result", ...}` / `{"op": "error", ...}` out.

use std::collections::BTreeMap;
use std::io::Read;

use crate::error::{Error, Result};
use crate::search::Neighbor;
use crate::util::json::Json;

/// Frame magic ("AMsearch Net Protocol").
pub const MAGIC: [u8; 4] = *b"AMNP";
/// Protocol version emitted for every frame without a trace id.
pub const VERSION: u8 = 1;
/// Protocol version emitted for a SEARCH frame carrying a trace id
/// (its payload ends with an extra `trace_id u64`).  Decoders accept
/// both versions; encoders only use this one when `trace_id != 0`, so
/// untraced streams stay v1-compatible byte for byte.
pub const TRACED_VERSION: u8 = 2;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Maximum payload size (16 MiB) — larger length prefixes are treated
/// as stream corruption, not as something to allocate.
pub const MAX_PAYLOAD: u32 = 1 << 24;
/// Maximum `top_k` accepted at the network boundary (DoS guard for the
/// per-request top-k accumulators; in-process callers are only clamped
/// to the database size).
pub const MAX_WIRE_TOP_K: u32 = 65_536;

/// Frame type: k-NN search request.
pub const FT_SEARCH: u8 = 0x01;
/// Frame type: search result.
pub const FT_RESULT: u8 = 0x02;
/// Frame type: error response.
pub const FT_ERROR: u8 = 0x03;
/// Frame type: liveness probe.
pub const FT_PING: u8 = 0x04;
/// Frame type: liveness reply.
pub const FT_PONG: u8 = 0x05;
/// Frame type: metrics snapshot request.
pub const FT_STATS: u8 = 0x06;
/// Frame type: metrics snapshot reply (JSON payload).
pub const FT_STATS_REPLY: u8 = 0x07;
/// Frame type: graceful server shutdown request.
pub const FT_SHUTDOWN: u8 = 0x08;
/// Frame type: shutdown acknowledgement.
pub const FT_SHUTDOWN_OK: u8 = 0x09;
/// Frame type: Prometheus metrics request.
pub const FT_METRICS: u8 = 0x0A;
/// Frame type: Prometheus metrics reply (text exposition payload).
pub const FT_METRICS_REPLY: u8 = 0x0B;
/// Frame type: query-introspection request (replay one query with full
/// per-stage detail).
pub const FT_EXPLAIN: u8 = 0x0C;
/// Frame type: query-introspection reply (JSON payload).
pub const FT_EXPLAIN_REPLY: u8 = 0x0D;

/// EXPLAIN flag bit: also run the exact exhaustive scan and report the
/// ground-truth diff.  Other bits are reserved and rejected, so a
/// future flag cannot be silently ignored by an old server.
pub const EXPLAIN_FLAG_EXACT: u32 = 1;

/// Error code: malformed or zero-length frame payload.
pub const ERR_BAD_FRAME: u16 = 1;
/// Error code: query dimension does not match the served index.
pub const ERR_BAD_DIM: u16 = 2;
/// Error code: `top_k` exceeds [`MAX_WIRE_TOP_K`].
pub const ERR_BAD_K: u16 = 3;
/// Error code: the server is draining and no longer accepts searches.
pub const ERR_SHUTTING_DOWN: u16 = 4;
/// Error code: internal serving failure (engine/batch error).
pub const ERR_INTERNAL: u16 = 5;
/// Error code: connection-handler pool exhausted.
pub const ERR_OVERLOADED: u16 = 6;

/// A k-NN search request as it travels on the wire.  Unlike the
/// in-process `coordinator::SearchRequest` it is plain data (no
/// rendezvous channel, no timestamps) and the id is chosen by the
/// *client* — responses on a connection are matched by this id, so it
/// must be unique among that connection's in-flight requests.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Client-chosen request id (echoed in the response).
    pub id: u64,
    /// Classes to poll (`0` = index default).
    pub top_p: u32,
    /// Neighbors to return (`0` = index default; at most
    /// [`MAX_WIRE_TOP_K`]).
    pub top_k: u32,
    /// Query vector.
    pub vector: Vec<f32>,
    /// Distributed trace id (`0` = untraced; encodes as wire v1).  Set
    /// by a router so shard-side span records stitch to its own.
    pub trace_id: u64,
}

/// A search result as it travels on the wire (the network image of
/// `coordinator::SearchResponse`).
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    /// Echo of the request id.
    pub id: u64,
    /// Neighbors sorted ascending by `(distance, id)`; empty = no
    /// candidates were scanned.
    pub neighbors: Vec<Neighbor>,
    /// Classes polled, best first.
    pub polled: Vec<u32>,
    /// Candidates scanned.
    pub candidates: u64,
    /// Elementary operations spent (paper cost model).
    pub ops: u64,
    /// Service time attributed to this request.
    pub service_ns: u64,
}

/// A query-introspection request as it travels on the wire
/// ([`FT_EXPLAIN`]): one query to replay through the serving pipeline
/// with full per-stage detail.  Same shape as [`WireRequest`] plus the
/// flags word; never traced (it is an admin verb, not traffic).
#[derive(Debug, Clone, PartialEq)]
pub struct WireExplain {
    /// Client-chosen request id (echoed in the reply).
    pub id: u64,
    /// Also run the exact exhaustive scan and report the ground-truth
    /// diff ([`EXPLAIN_FLAG_EXACT`]).
    pub exact: bool,
    /// Classes to poll (`0` = index default).
    pub top_p: u32,
    /// Neighbors to return (`0` = index default; at most
    /// [`MAX_WIRE_TOP_K`]).
    pub top_k: u32,
    /// Query vector.
    pub vector: Vec<f32>,
}

/// An error response: the request id it answers, a stable numeric code
/// (`ERR_*`), and a human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    /// Echo of the offending request id (0 when no id could be read).
    pub id: u64,
    /// Stable error code (`ERR_*`).
    pub code: u16,
    /// Human-readable detail.
    pub message: String,
}

/// One decoded protocol frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// k-NN search request.
    Search(WireRequest),
    /// Search result.
    Result(WireResponse),
    /// Error response.
    Error(WireError),
    /// Liveness probe.
    Ping {
        /// Request id.
        id: u64,
    },
    /// Liveness reply.
    Pong {
        /// Echo of the probe id.
        id: u64,
    },
    /// Metrics snapshot request.
    Stats {
        /// Request id.
        id: u64,
    },
    /// Metrics snapshot reply.
    StatsReply {
        /// Echo of the request id.
        id: u64,
        /// Server metrics snapshot as a JSON document.
        json: String,
    },
    /// Graceful shutdown request.
    Shutdown {
        /// Request id.
        id: u64,
    },
    /// Shutdown acknowledgement (sent before the server begins
    /// draining).
    ShutdownOk {
        /// Echo of the request id.
        id: u64,
    },
    /// Prometheus metrics request.
    Metrics {
        /// Request id.
        id: u64,
    },
    /// Prometheus metrics reply.
    MetricsReply {
        /// Echo of the request id.
        id: u64,
        /// Text exposition rendered by [`crate::obs::Registry`].
        text: String,
    },
    /// Query-introspection request.
    Explain(WireExplain),
    /// Query-introspection reply.
    ExplainReply {
        /// Echo of the request id.
        id: u64,
        /// Introspection report as a JSON document.
        json: String,
    },
}

impl Frame {
    /// The request id this frame carries.
    pub fn id(&self) -> u64 {
        match self {
            Frame::Search(r) => r.id,
            Frame::Result(r) => r.id,
            Frame::Error(e) => e.id,
            Frame::Ping { id }
            | Frame::Pong { id }
            | Frame::Stats { id }
            | Frame::StatsReply { id, .. }
            | Frame::Shutdown { id }
            | Frame::ShutdownOk { id }
            | Frame::Metrics { id }
            | Frame::MetricsReply { id, .. }
            | Frame::ExplainReply { id, .. } => *id,
            Frame::Explain(e) => e.id,
        }
    }

    fn ftype(&self) -> u8 {
        match self {
            Frame::Search(_) => FT_SEARCH,
            Frame::Result(_) => FT_RESULT,
            Frame::Error(_) => FT_ERROR,
            Frame::Ping { .. } => FT_PING,
            Frame::Pong { .. } => FT_PONG,
            Frame::Stats { .. } => FT_STATS,
            Frame::StatsReply { .. } => FT_STATS_REPLY,
            Frame::Shutdown { .. } => FT_SHUTDOWN,
            Frame::ShutdownOk { .. } => FT_SHUTDOWN_OK,
            Frame::Metrics { .. } => FT_METRICS,
            Frame::MetricsReply { .. } => FT_METRICS_REPLY,
            Frame::Explain(_) => FT_EXPLAIN,
            Frame::ExplainReply { .. } => FT_EXPLAIN_REPLY,
        }
    }

    /// Encode to a complete binary frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        match self {
            Frame::Search(r) => {
                payload.extend_from_slice(&r.top_p.to_le_bytes());
                payload.extend_from_slice(&r.top_k.to_le_bytes());
                payload.extend_from_slice(&(r.vector.len() as u32).to_le_bytes());
                for &x in &r.vector {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
                if r.trace_id != 0 {
                    payload.extend_from_slice(&r.trace_id.to_le_bytes());
                }
            }
            Frame::Result(r) => {
                payload.extend_from_slice(&(r.neighbors.len() as u32).to_le_bytes());
                for n in &r.neighbors {
                    payload.extend_from_slice(&n.id.to_le_bytes());
                    payload.extend_from_slice(&n.distance.to_le_bytes());
                }
                payload.extend_from_slice(&(r.polled.len() as u32).to_le_bytes());
                for &c in &r.polled {
                    payload.extend_from_slice(&c.to_le_bytes());
                }
                payload.extend_from_slice(&r.candidates.to_le_bytes());
                payload.extend_from_slice(&r.ops.to_le_bytes());
                payload.extend_from_slice(&r.service_ns.to_le_bytes());
            }
            Frame::Error(e) => {
                payload.extend_from_slice(&e.code.to_le_bytes());
                payload.extend_from_slice(e.message.as_bytes());
            }
            Frame::StatsReply { json, .. } => payload.extend_from_slice(json.as_bytes()),
            Frame::MetricsReply { text, .. } => payload.extend_from_slice(text.as_bytes()),
            Frame::Explain(e) => {
                let flags = if e.exact { EXPLAIN_FLAG_EXACT } else { 0 };
                payload.extend_from_slice(&flags.to_le_bytes());
                payload.extend_from_slice(&e.top_p.to_le_bytes());
                payload.extend_from_slice(&e.top_k.to_le_bytes());
                payload.extend_from_slice(&(e.vector.len() as u32).to_le_bytes());
                for &x in &e.vector {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            Frame::ExplainReply { json, .. } => {
                payload.extend_from_slice(json.as_bytes())
            }
            Frame::Ping { .. }
            | Frame::Pong { .. }
            | Frame::Stats { .. }
            | Frame::Shutdown { .. }
            | Frame::ShutdownOk { .. }
            | Frame::Metrics { .. } => {}
        }
        // only a trace-carrying SEARCH needs the v2 layout; everything
        // else stays v1 so old peers keep decoding untraced streams
        let version = match self {
            Frame::Search(r) if r.trace_id != 0 => TRACED_VERSION,
            _ => VERSION,
        };
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(version);
        out.push(self.ftype());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.id().to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// A frame whose header was read and whose payload bytes are intact but
/// not yet interpreted.
#[derive(Debug, Clone, PartialEq)]
pub struct RawFrame {
    /// Frame type byte.
    pub ftype: u8,
    /// Request id from the header.
    pub id: u64,
    /// Raw payload bytes.
    pub payload: Vec<u8>,
}

/// Validate a 20-byte header; returns `(ftype, id, payload_len)`.
fn check_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u64, usize)> {
    if h[0..4] != MAGIC {
        return Err(Error::Data(format!(
            "wire: bad magic {:02x}{:02x}{:02x}{:02x} (not an AMNP stream)",
            h[0], h[1], h[2], h[3]
        )));
    }
    if h[4] != VERSION && h[4] != TRACED_VERSION {
        return Err(Error::Data(format!("wire: unsupported version {}", h[4])));
    }
    let ftype = h[5];
    // amlint: allow(panic, reason = "h is a fixed [u8; 20]; 8..16 is 8 bytes by construction")
    let id = u64::from_le_bytes(h[8..16].try_into().expect("8 bytes"));
    // amlint: allow(panic, reason = "h is a fixed [u8; 20]; 16..20 is 4 bytes by construction")
    let len = u32::from_le_bytes(h[16..20].try_into().expect("4 bytes"));
    if len > MAX_PAYLOAD {
        return Err(Error::Data(format!(
            "wire: oversized length prefix {len} (max {MAX_PAYLOAD})"
        )));
    }
    Ok((ftype, id, len as usize))
}

/// Read exactly one frame from a blocking reader.  Errors are
/// connection-fatal: `Error::Data` for corruption (bad magic/version,
/// oversized length prefix), `Error::Io` for truncation / closed peer.
pub fn read_raw<R: Read>(r: &mut R) -> Result<RawFrame> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let (ftype, id, len) = check_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(RawFrame { ftype, id, payload })
}

/// Read and fully decode one frame (client side; a payload that fails
/// structural validation is reported as `Error::Data`).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let raw = read_raw(r)?;
    parse(&raw).map_err(|e| {
        Error::Data(format!("wire: bad frame (code {}): {}", e.code, e.message))
    })
}

/// Incremental frame decoder for non-blocking / timeout-polled reads:
/// feed whatever bytes arrived, pop complete frames.  `Err` from
/// [`FrameBuffer::next_raw`] means the stream is corrupt and the
/// connection must be dropped.
#[derive(Debug, Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
}

impl FrameBuffer {
    /// Empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append bytes received from the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (incomplete frame tail).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no bytes are buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Pop the next complete frame, `Ok(None)` when more bytes are
    /// needed, `Err` when the stream is corrupt (connection-fatal).
    pub fn next_raw(&mut self) -> Result<Option<RawFrame>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let header: [u8; HEADER_LEN] =
            // amlint: allow(panic, reason = "buffered len >= HEADER_LEN checked above; the slice is exactly HEADER_LEN bytes")
            self.buf[..HEADER_LEN].try_into().expect("length checked");
        let (ftype, id, len) = check_header(&header)?;
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some(RawFrame { ftype, id, payload }))
    }
}

/// Little-endian payload cursor (decode helper).
struct Cur<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Cur { bytes, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.bytes.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }
    fn u16(&mut self) -> Option<u16> {
        self.take(2).and_then(|b| b.try_into().ok()).map(u16::from_le_bytes)
    }
    fn u32(&mut self) -> Option<u32> {
        self.take(4).and_then(|b| b.try_into().ok()).map(u32::from_le_bytes)
    }
    fn u64(&mut self) -> Option<u64> {
        self.take(8).and_then(|b| b.try_into().ok()).map(u64::from_le_bytes)
    }
    fn f32(&mut self) -> Option<f32> {
        self.take(4).and_then(|b| b.try_into().ok()).map(f32::from_le_bytes)
    }
    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
    fn done(&self) -> bool {
        self.pos == self.bytes.len()
    }
}

fn bad(id: u64, message: impl Into<String>) -> WireError {
    WireError { id, code: ERR_BAD_FRAME, message: message.into() }
}

/// Interpret a raw frame's payload.  A structural problem is
/// *recoverable*: the returned [`WireError`] carries the frame's id and
/// a stable code, ready to be sent back as an ERROR frame (the length
/// prefix was already consumed, so the stream stays in sync).
pub fn parse(raw: &RawFrame) -> std::result::Result<Frame, WireError> {
    let id = raw.id;
    let mut c = Cur::new(&raw.payload);
    match raw.ftype {
        FT_SEARCH => {
            if raw.payload.is_empty() {
                return Err(bad(id, "zero-length search frame"));
            }
            let top_p = c.u32().ok_or_else(|| bad(id, "search: truncated top_p"))?;
            let top_k = c.u32().ok_or_else(|| bad(id, "search: truncated top_k"))?;
            let dim = c.u32().ok_or_else(|| bad(id, "search: truncated dim"))?;
            if top_k > MAX_WIRE_TOP_K {
                return Err(WireError {
                    id,
                    code: ERR_BAD_K,
                    message: format!("top_k {top_k} exceeds wire limit {MAX_WIRE_TOP_K}"),
                });
            }
            if dim == 0 {
                return Err(WireError {
                    id,
                    code: ERR_BAD_DIM,
                    message: "empty query vector (dim = 0)".into(),
                });
            }
            // declared count must match the bytes actually present
            // BEFORE any allocation is sized from it: an untrusted
            // dim = u32::MAX in a tiny frame must not reserve gigabytes.
            // The two admissible layouts (v1: floats only, v2: floats
            // then trace_id u64) are told apart by exact length.
            let floats = dim as u64 * 4;
            let traced = match c.remaining() as u64 {
                r if r == floats => false,
                r if r == floats + 8 => true,
                _ => return Err(bad(id, "search: dim disagrees with payload length")),
            };
            let mut vector = Vec::with_capacity(dim as usize);
            for _ in 0..dim {
                vector.push(c.f32().ok_or_else(|| bad(id, "search: truncated vector"))?);
            }
            let trace_id = if traced {
                c.u64().ok_or_else(|| bad(id, "search: truncated trace id"))?
            } else {
                0
            };
            Ok(Frame::Search(WireRequest { id, top_p, top_k, vector, trace_id }))
        }
        FT_RESULT => {
            let n = c.u32().ok_or_else(|| bad(id, "result: truncated count"))?;
            // bound every count by the bytes present before allocating
            if n as u64 * 8 > c.remaining() as u64 {
                return Err(bad(id, "result: neighbor count exceeds payload"));
            }
            let mut neighbors = Vec::with_capacity(n as usize);
            for _ in 0..n {
                let nid = c.u32().ok_or_else(|| bad(id, "result: truncated neighbor"))?;
                let distance =
                    c.f32().ok_or_else(|| bad(id, "result: truncated neighbor"))?;
                neighbors.push(Neighbor { id: nid, distance });
            }
            let np = c.u32().ok_or_else(|| bad(id, "result: truncated polled count"))?;
            if np as u64 * 4 > c.remaining() as u64 {
                return Err(bad(id, "result: polled count exceeds payload"));
            }
            let mut polled = Vec::with_capacity(np as usize);
            for _ in 0..np {
                polled.push(c.u32().ok_or_else(|| bad(id, "result: truncated polled"))?);
            }
            let candidates =
                c.u64().ok_or_else(|| bad(id, "result: truncated candidates"))?;
            let ops = c.u64().ok_or_else(|| bad(id, "result: truncated ops"))?;
            let service_ns =
                c.u64().ok_or_else(|| bad(id, "result: truncated service_ns"))?;
            if !c.done() {
                return Err(bad(id, "result: trailing payload bytes"));
            }
            Ok(Frame::Result(WireResponse {
                id,
                neighbors,
                polled,
                candidates,
                ops,
                service_ns,
            }))
        }
        FT_ERROR => {
            let code = c.u16().ok_or_else(|| bad(id, "error: truncated code"))?;
            let message = String::from_utf8(raw.payload[c.pos..].to_vec())
                .map_err(|_| bad(id, "error: message is not utf-8"))?;
            Ok(Frame::Error(WireError { id, code, message }))
        }
        FT_STATS_REPLY => {
            let json = String::from_utf8(raw.payload.clone())
                .map_err(|_| bad(id, "stats reply is not utf-8"))?;
            Ok(Frame::StatsReply { id, json })
        }
        FT_METRICS_REPLY => {
            let text = String::from_utf8(raw.payload.clone())
                .map_err(|_| bad(id, "metrics reply is not utf-8"))?;
            Ok(Frame::MetricsReply { id, text })
        }
        FT_EXPLAIN => {
            if raw.payload.is_empty() {
                return Err(bad(id, "zero-length explain frame"));
            }
            let flags = c.u32().ok_or_else(|| bad(id, "explain: truncated flags"))?;
            if flags & !EXPLAIN_FLAG_EXACT != 0 {
                return Err(bad(id, format!("explain: unknown flags {flags:#x}")));
            }
            let top_p = c.u32().ok_or_else(|| bad(id, "explain: truncated top_p"))?;
            let top_k = c.u32().ok_or_else(|| bad(id, "explain: truncated top_k"))?;
            let dim = c.u32().ok_or_else(|| bad(id, "explain: truncated dim"))?;
            if top_k > MAX_WIRE_TOP_K {
                return Err(WireError {
                    id,
                    code: ERR_BAD_K,
                    message: format!("top_k {top_k} exceeds wire limit {MAX_WIRE_TOP_K}"),
                });
            }
            if dim == 0 {
                return Err(WireError {
                    id,
                    code: ERR_BAD_DIM,
                    message: "empty query vector (dim = 0)".into(),
                });
            }
            // same declared-count-vs-bytes-present discipline as SEARCH:
            // the length must agree before any allocation is sized
            if c.remaining() as u64 != dim as u64 * 4 {
                return Err(bad(id, "explain: dim disagrees with payload length"));
            }
            let mut vector = Vec::with_capacity(dim as usize);
            for _ in 0..dim {
                vector.push(
                    c.f32().ok_or_else(|| bad(id, "explain: truncated vector"))?,
                );
            }
            Ok(Frame::Explain(WireExplain {
                id,
                exact: flags & EXPLAIN_FLAG_EXACT != 0,
                top_p,
                top_k,
                vector,
            }))
        }
        FT_EXPLAIN_REPLY => {
            let json = String::from_utf8(raw.payload.clone())
                .map_err(|_| bad(id, "explain reply is not utf-8"))?;
            Ok(Frame::ExplainReply { id, json })
        }
        FT_PING | FT_PONG | FT_STATS | FT_SHUTDOWN | FT_SHUTDOWN_OK | FT_METRICS => {
            if !raw.payload.is_empty() {
                return Err(bad(id, "unexpected payload on admin frame"));
            }
            Ok(match raw.ftype {
                FT_PING => Frame::Ping { id },
                FT_PONG => Frame::Pong { id },
                FT_STATS => Frame::Stats { id },
                FT_SHUTDOWN => Frame::Shutdown { id },
                FT_METRICS => Frame::Metrics { id },
                _ => Frame::ShutdownOk { id },
            })
        }
        other => Err(bad(id, format!("unknown frame type {other:#04x}"))),
    }
}

// ---------------------------------------------------------------------
// JSON-lines encoding (debug mode)
// ---------------------------------------------------------------------

fn jnum(n: f64) -> Json {
    Json::Num(n)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

impl Frame {
    fn op(&self) -> &'static str {
        match self {
            Frame::Search(_) => "search",
            Frame::Result(_) => "result",
            Frame::Error(_) => "error",
            Frame::Ping { .. } => "ping",
            Frame::Pong { .. } => "pong",
            Frame::Stats { .. } => "stats",
            Frame::StatsReply { .. } => "stats_reply",
            Frame::Shutdown { .. } => "shutdown",
            Frame::ShutdownOk { .. } => "shutdown_ok",
            Frame::Metrics { .. } => "metrics",
            Frame::MetricsReply { .. } => "metrics_reply",
            Frame::Explain(_) => "explain",
            Frame::ExplainReply { .. } => "explain_reply",
        }
    }

    /// Encode as a JSON object (the JSON-lines image of this frame).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("op".to_string(), jstr(self.op()));
        m.insert("id".to_string(), jnum(self.id() as f64));
        match self {
            Frame::Search(r) => {
                m.insert("top_p".to_string(), jnum(r.top_p as f64));
                m.insert("top_k".to_string(), jnum(r.top_k as f64));
                m.insert(
                    "vector".to_string(),
                    Json::Arr(r.vector.iter().map(|&x| jnum(x as f64)).collect()),
                );
                // mirrors the binary encoding: the field only exists
                // when the request is traced
                if r.trace_id != 0 {
                    m.insert("trace_id".to_string(), jnum(r.trace_id as f64));
                }
            }
            Frame::Result(r) => {
                m.insert(
                    "neighbors".to_string(),
                    Json::Arr(
                        r.neighbors
                            .iter()
                            .map(|n| {
                                let mut nm = BTreeMap::new();
                                nm.insert("id".to_string(), jnum(n.id as f64));
                                nm.insert(
                                    "distance".to_string(),
                                    jnum(n.distance as f64),
                                );
                                Json::Obj(nm)
                            })
                            .collect(),
                    ),
                );
                m.insert(
                    "polled".to_string(),
                    Json::Arr(r.polled.iter().map(|&c| jnum(c as f64)).collect()),
                );
                m.insert("candidates".to_string(), jnum(r.candidates as f64));
                m.insert("ops".to_string(), jnum(r.ops as f64));
                m.insert("service_ns".to_string(), jnum(r.service_ns as f64));
            }
            Frame::Error(e) => {
                m.insert("code".to_string(), jnum(e.code as f64));
                m.insert("message".to_string(), jstr(&e.message));
            }
            Frame::StatsReply { json, .. } => {
                // embed the stats document itself, not a quoted string
                let v = Json::parse(json).unwrap_or_else(|_| jstr(json));
                m.insert("stats".to_string(), v);
            }
            Frame::MetricsReply { text, .. } => {
                // the exposition is plain text, so it stays a string
                m.insert("text".to_string(), jstr(text));
            }
            Frame::Explain(e) => {
                if e.exact {
                    m.insert("exact".to_string(), Json::Bool(true));
                }
                m.insert("top_p".to_string(), jnum(e.top_p as f64));
                m.insert("top_k".to_string(), jnum(e.top_k as f64));
                m.insert(
                    "vector".to_string(),
                    Json::Arr(e.vector.iter().map(|&x| jnum(x as f64)).collect()),
                );
            }
            Frame::ExplainReply { json, .. } => {
                // embed the report itself, like stats_reply
                let v = Json::parse(json).unwrap_or_else(|_| jstr(json));
                m.insert("report".to_string(), v);
            }
            _ => {}
        }
        Json::Obj(m)
    }

    /// Encode as one `\n`-terminated JSON line.
    pub fn to_json_line(&self) -> String {
        let mut s = self.to_json().to_string();
        s.push('\n');
        s
    }

    /// Decode from a parsed JSON object (one JSON-lines message).
    pub fn from_json(v: &Json) -> std::result::Result<Frame, WireError> {
        let id = v.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
        let op = v
            .get("op")
            .and_then(|x| x.as_str())
            .ok_or_else(|| bad(id, "json: missing 'op'"))?;
        match op {
            "search" => {
                let arr = v
                    .get("vector")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| bad(id, "json search: missing 'vector'"))?;
                let mut vector = Vec::with_capacity(arr.len());
                for x in arr {
                    vector.push(x.as_f64().ok_or_else(|| {
                        bad(id, "json search: non-numeric vector element")
                    })? as f32);
                }
                let top_p =
                    v.get("top_p").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                let top_k =
                    v.get("top_k").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                if top_k > MAX_WIRE_TOP_K {
                    return Err(WireError {
                        id,
                        code: ERR_BAD_K,
                        message: format!(
                            "top_k {top_k} exceeds wire limit {MAX_WIRE_TOP_K}"
                        ),
                    });
                }
                if vector.is_empty() {
                    return Err(WireError {
                        id,
                        code: ERR_BAD_DIM,
                        message: "empty query vector (dim = 0)".into(),
                    });
                }
                let trace_id =
                    v.get("trace_id").and_then(|x| x.as_u64()).unwrap_or(0);
                Ok(Frame::Search(WireRequest { id, top_p, top_k, vector, trace_id }))
            }
            "result" => {
                let mut neighbors = Vec::new();
                if let Some(arr) = v.get("neighbors").and_then(|x| x.as_arr()) {
                    for n in arr {
                        let nid = n.get("id").and_then(|x| x.as_u64()).ok_or_else(
                            || bad(id, "json result: neighbor missing 'id'"),
                        )? as u32;
                        let distance =
                            n.get("distance").and_then(|x| x.as_f64()).ok_or_else(
                                || bad(id, "json result: neighbor missing 'distance'"),
                            )? as f32;
                        neighbors.push(Neighbor { id: nid, distance });
                    }
                }
                let mut polled = Vec::new();
                if let Some(arr) = v.get("polled").and_then(|x| x.as_arr()) {
                    for c in arr {
                        polled.push(c.as_u64().ok_or_else(|| {
                            bad(id, "json result: non-integer polled class")
                        })? as u32);
                    }
                }
                Ok(Frame::Result(WireResponse {
                    id,
                    neighbors,
                    polled,
                    candidates: v
                        .get("candidates")
                        .and_then(|x| x.as_u64())
                        .unwrap_or(0),
                    ops: v.get("ops").and_then(|x| x.as_u64()).unwrap_or(0),
                    service_ns: v
                        .get("service_ns")
                        .and_then(|x| x.as_u64())
                        .unwrap_or(0),
                }))
            }
            "error" => Ok(Frame::Error(WireError {
                id,
                code: v.get("code").and_then(|x| x.as_u64()).unwrap_or(0) as u16,
                message: v
                    .get("message")
                    .and_then(|x| x.as_str())
                    .unwrap_or("")
                    .to_string(),
            })),
            "ping" => Ok(Frame::Ping { id }),
            "pong" => Ok(Frame::Pong { id }),
            "stats" => Ok(Frame::Stats { id }),
            "stats_reply" => Ok(Frame::StatsReply {
                id,
                json: v.get("stats").map(|s| s.to_string()).unwrap_or_default(),
            }),
            "shutdown" => Ok(Frame::Shutdown { id }),
            "shutdown_ok" => Ok(Frame::ShutdownOk { id }),
            "metrics" => Ok(Frame::Metrics { id }),
            "metrics_reply" => Ok(Frame::MetricsReply {
                id,
                text: v
                    .get("text")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .to_string(),
            }),
            "explain" => {
                let arr = v
                    .get("vector")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| bad(id, "json explain: missing 'vector'"))?;
                let mut vector = Vec::with_capacity(arr.len());
                for x in arr {
                    vector.push(x.as_f64().ok_or_else(|| {
                        bad(id, "json explain: non-numeric vector element")
                    })? as f32);
                }
                let top_p =
                    v.get("top_p").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                let top_k =
                    v.get("top_k").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                if top_k > MAX_WIRE_TOP_K {
                    return Err(WireError {
                        id,
                        code: ERR_BAD_K,
                        message: format!(
                            "top_k {top_k} exceeds wire limit {MAX_WIRE_TOP_K}"
                        ),
                    });
                }
                if vector.is_empty() {
                    return Err(WireError {
                        id,
                        code: ERR_BAD_DIM,
                        message: "empty query vector (dim = 0)".into(),
                    });
                }
                let exact =
                    v.get("exact").and_then(|x| x.as_bool()).unwrap_or(false);
                Ok(Frame::Explain(WireExplain { id, exact, top_p, top_k, vector }))
            }
            "explain_reply" => Ok(Frame::ExplainReply {
                id,
                json: v.get("report").map(|s| s.to_string()).unwrap_or_default(),
            }),
            other => Err(bad(id, format!("json: unknown op '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let mut cur = std::io::Cursor::new(bytes);
        let raw = read_raw(&mut cur).unwrap();
        parse(&raw).unwrap()
    }

    fn sample_result() -> Frame {
        Frame::Result(WireResponse {
            id: 9,
            neighbors: vec![
                Neighbor { id: 3, distance: 0.5 },
                Neighbor { id: 7, distance: 1.25 },
            ],
            polled: vec![2, 0, 5],
            candidates: 128,
            ops: 4096,
            service_ns: 12_345,
        })
    }

    #[test]
    fn header_layout_is_stable() {
        let bytes = Frame::Ping { id: 0x0102_0304_0506_0708 }.encode();
        assert_eq!(bytes.len(), HEADER_LEN);
        assert_eq!(&bytes[0..4], b"AMNP");
        assert_eq!(bytes[4], 1); // version
        assert_eq!(bytes[5], FT_PING);
        assert_eq!(&bytes[6..8], &[0, 0]); // reserved
        assert_eq!(
            u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            0x0102_0304_0506_0708
        );
        assert_eq!(u32::from_le_bytes(bytes[16..20].try_into().unwrap()), 0);
    }

    #[test]
    fn every_frame_type_roundtrips() {
        let frames = vec![
            Frame::Search(WireRequest {
                id: 1,
                top_p: 4,
                top_k: 10,
                vector: vec![0.5, -1.25, 3.75],
                trace_id: 0,
            }),
            Frame::Search(WireRequest {
                id: 12,
                top_p: 4,
                top_k: 10,
                vector: vec![0.5, -1.25],
                trace_id: 0xDEAD_BEEF,
            }),
            sample_result(),
            Frame::Result(WireResponse {
                id: 10,
                neighbors: vec![], // the "no candidates" protocol
                polled: vec![1],
                candidates: 0,
                ops: 7,
                service_ns: 0,
            }),
            Frame::Error(WireError {
                id: 2,
                code: ERR_BAD_DIM,
                message: "query dim 3 != index dim 128".into(),
            }),
            Frame::Ping { id: 3 },
            Frame::Pong { id: 4 },
            Frame::Stats { id: 5 },
            Frame::StatsReply { id: 6, json: r#"{"requests":10}"#.into() },
            Frame::Shutdown { id: 7 },
            Frame::ShutdownOk { id: 8 },
            Frame::Metrics { id: 11 },
            Frame::MetricsReply {
                id: 12,
                text: "# TYPE amsearch_requests_total counter\n".into(),
            },
            Frame::Explain(WireExplain {
                id: 13,
                exact: false,
                top_p: 2,
                top_k: 5,
                vector: vec![0.25, -0.5],
            }),
            Frame::Explain(WireExplain {
                id: 14,
                exact: true,
                top_p: 0,
                top_k: 0,
                vector: vec![1.0],
            }),
            Frame::ExplainReply { id: 15, json: r#"{"poll":{"margin":0.5}}"#.into() },
        ];
        for f in frames {
            assert_eq!(roundtrip(&f), f);
        }
    }

    #[test]
    fn distances_are_bitwise_exact() {
        // f32 payloads travel as raw LE bits: subnormals and odd
        // fractions must come back bit-identical
        let f = Frame::Search(WireRequest {
            id: 1,
            top_p: 0,
            top_k: 0,
            vector: vec![f32::MIN_POSITIVE, 1.0e-40, -0.1, f32::MAX],
            trace_id: 0,
        });
        let Frame::Search(r) = roundtrip(&f) else { panic!("wrong type") };
        let Frame::Search(orig) = f else { unreachable!() };
        for (a, b) in orig.vector.iter().zip(&r.vector) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bad_magic_is_fatal() {
        let mut bytes = Frame::Ping { id: 1 }.encode();
        bytes[0] = b'X';
        let err = read_raw(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn bad_version_is_fatal() {
        let mut bytes = Frame::Ping { id: 1 }.encode();
        bytes[4] = 99;
        let err = read_raw(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn oversized_length_prefix_is_fatal_not_allocated() {
        let mut bytes = Frame::Ping { id: 1 }.encode();
        bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_raw(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
        // same through the incremental decoder
        let mut fb = FrameBuffer::new();
        let mut bytes2 = Frame::Ping { id: 1 }.encode();
        bytes2[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        fb.extend(&bytes2);
        assert!(fb.next_raw().is_err());
    }

    #[test]
    fn truncated_payload_is_io_error() {
        let bytes = sample_result().encode();
        let cut = &bytes[..bytes.len() - 3];
        assert!(read_raw(&mut std::io::Cursor::new(cut.to_vec())).is_err());
    }

    #[test]
    fn frame_buffer_reassembles_byte_at_a_time() {
        let frames = [
            Frame::Search(WireRequest {
                id: 1,
                top_p: 2,
                top_k: 3,
                vector: vec![1.0; 7],
                trace_id: 0,
            }),
            sample_result(),
            Frame::Ping { id: 11 },
        ];
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.encode());
        }
        let mut fb = FrameBuffer::new();
        let mut got = Vec::new();
        for b in stream {
            fb.extend(&[b]);
            while let Some(raw) = fb.next_raw().unwrap() {
                got.push(parse(&raw).unwrap());
            }
        }
        assert_eq!(got, frames);
        assert!(fb.is_empty());
    }

    #[test]
    fn zero_length_search_frame_has_stable_code() {
        let raw = RawFrame { ftype: FT_SEARCH, id: 42, payload: vec![] };
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_FRAME);
        assert_eq!(e.id, 42);
    }

    #[test]
    fn oversized_top_k_has_stable_code() {
        let f = Frame::Search(WireRequest {
            id: 5,
            top_p: 1,
            top_k: MAX_WIRE_TOP_K + 1,
            vector: vec![0.0; 4],
            trace_id: 0,
        });
        let mut cur = std::io::Cursor::new(f.encode());
        let raw = read_raw(&mut cur).unwrap();
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_K);
        assert_eq!(e.id, 5);
    }

    #[test]
    fn zero_dim_search_has_stable_code() {
        let f = Frame::Search(WireRequest {
            id: 6,
            top_p: 1,
            top_k: 1,
            vector: vec![],
            trace_id: 0,
        });
        let mut cur = std::io::Cursor::new(f.encode());
        let raw = read_raw(&mut cur).unwrap();
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_DIM);
    }

    #[test]
    fn inconsistent_search_dim_rejected() {
        // declared dim 8 but only 4 floats present
        let good = Frame::Search(WireRequest {
            id: 7,
            top_p: 1,
            top_k: 1,
            vector: vec![0.0; 4],
            trace_id: 0,
        });
        let mut bytes = good.encode();
        // payload starts at HEADER_LEN; dim field is at offset 8 in payload
        bytes[HEADER_LEN + 8..HEADER_LEN + 12].copy_from_slice(&8u32.to_le_bytes());
        let raw = read_raw(&mut std::io::Cursor::new(bytes)).unwrap();
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_FRAME);
    }

    #[test]
    fn huge_declared_counts_rejected_before_allocation() {
        // a tiny frame declaring dim = u32::MAX must be rejected by the
        // length-consistency check, never sized into an allocation
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes()); // top_p
        payload.extend_from_slice(&1u32.to_le_bytes()); // top_k
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
        payload.extend_from_slice(&0f32.to_le_bytes()); // one lone float
        let raw = RawFrame { ftype: FT_SEARCH, id: 9, payload };
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_FRAME);
        // same for the RESULT counts
        let mut payload = Vec::new();
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // n neighbors
        let raw = RawFrame { ftype: FT_RESULT, id: 10, payload };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
    }

    #[test]
    fn unknown_frame_type_recoverable() {
        let raw = RawFrame { ftype: 0x7F, id: 1, payload: vec![] };
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.code, ERR_BAD_FRAME);
    }

    #[test]
    fn json_lines_roundtrip() {
        let frames = vec![
            Frame::Search(WireRequest {
                id: 1,
                top_p: 2,
                top_k: 3,
                vector: vec![0.5, -1.5],
                trace_id: 0,
            }),
            Frame::Search(WireRequest {
                id: 14,
                top_p: 2,
                top_k: 3,
                vector: vec![0.5],
                trace_id: 77,
            }),
            sample_result(),
            Frame::Metrics { id: 12 },
            Frame::MetricsReply { id: 13, text: "amsearch_net_inflight 0\n".into() },
            Frame::Error(WireError { id: 2, code: ERR_BAD_K, message: "too big".into() }),
            Frame::Ping { id: 3 },
            Frame::Pong { id: 4 },
            Frame::Shutdown { id: 7 },
            Frame::ShutdownOk { id: 8 },
            Frame::Explain(WireExplain {
                id: 15,
                exact: true,
                top_p: 2,
                top_k: 3,
                vector: vec![0.5, -1.5],
            }),
            Frame::Explain(WireExplain {
                id: 16,
                exact: false,
                top_p: 0,
                top_k: 0,
                vector: vec![1.0],
            }),
            Frame::ExplainReply { id: 17, json: r#"{"candidates":16}"#.into() },
        ];
        for f in frames {
            let line = f.to_json_line();
            assert!(line.ends_with('\n'));
            let v = Json::parse(line.trim_end()).unwrap();
            assert_eq!(Frame::from_json(&v).unwrap(), f);
        }
    }

    #[test]
    fn json_search_validation_mirrors_binary() {
        let v = Json::parse(r#"{"op":"search","id":9,"vector":[]}"#).unwrap();
        assert_eq!(Frame::from_json(&v).unwrap_err().code, ERR_BAD_DIM);
        let v = Json::parse(
            r#"{"op":"search","id":9,"vector":[1.0],"top_k":1000000}"#,
        )
        .unwrap();
        assert_eq!(Frame::from_json(&v).unwrap_err().code, ERR_BAD_K);
        let v = Json::parse(r#"{"op":"nope","id":1}"#).unwrap();
        assert_eq!(Frame::from_json(&v).unwrap_err().code, ERR_BAD_FRAME);
    }

    /// The numeric error codes are wire protocol: clients match on
    /// them, the README documents them, and `amlint`'s drift rule
    /// requires every code to be pinned here.  Renumbering is a
    /// protocol break, not a refactor.
    #[test]
    fn error_codes_are_stable() {
        assert_eq!(ERR_BAD_FRAME, 1);
        assert_eq!(ERR_BAD_DIM, 2);
        assert_eq!(ERR_BAD_K, 3);
        assert_eq!(ERR_SHUTTING_DOWN, 4);
        assert_eq!(ERR_INTERNAL, 5);
        assert_eq!(ERR_OVERLOADED, 6);
        assert_eq!(VERSION, 1, "wire version bumps must be deliberate");
        // v2 added deliberately for the SEARCH trace-id field; untraced
        // frames still encode (and must keep encoding) as v1
        assert_eq!(TRACED_VERSION, 2, "wire version bumps must be deliberate");
        // frame type ids are wire protocol too: the EXPLAIN pair landed
        // on the first free ids and must stay there
        assert_eq!(FT_EXPLAIN, 0x0C);
        assert_eq!(FT_EXPLAIN_REPLY, 0x0D);
        assert_eq!(EXPLAIN_FLAG_EXACT, 1);
    }

    #[test]
    fn traced_search_is_v2_untraced_stays_v1() {
        let untraced = Frame::Search(WireRequest {
            id: 1,
            top_p: 2,
            top_k: 3,
            vector: vec![1.0, 2.0],
            trace_id: 0,
        });
        let bytes = untraced.encode();
        assert_eq!(bytes[4], VERSION, "untraced search must stay v1 for old peers");

        let traced = Frame::Search(WireRequest {
            id: 1,
            top_p: 2,
            top_k: 3,
            vector: vec![1.0, 2.0],
            trace_id: u64::MAX,
        });
        let bytes = traced.encode();
        assert_eq!(bytes[4], TRACED_VERSION);
        // payload is exactly 8 bytes longer than the untraced layout
        assert_eq!(bytes.len(), untraced.encode().len() + 8);
        let Frame::Search(r) = roundtrip(&traced) else { panic!("wrong type") };
        assert_eq!(r.trace_id, u64::MAX);
        assert_eq!(r.vector, vec![1.0, 2.0]);
    }

    #[test]
    fn search_with_bad_trailing_length_rejected() {
        // floats + 4 trailing bytes is neither layout: reject, and
        // never size an allocation from the mismatch
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes()); // top_p
        payload.extend_from_slice(&1u32.to_le_bytes()); // top_k
        payload.extend_from_slice(&2u32.to_le_bytes()); // dim
        payload.extend_from_slice(&1f32.to_le_bytes());
        payload.extend_from_slice(&2f32.to_le_bytes());
        payload.extend_from_slice(&[0u8; 4]); // half a trace id
        let raw = RawFrame { ftype: FT_SEARCH, id: 3, payload };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
    }

    #[test]
    fn versions_above_traced_stay_fatal() {
        let mut bytes = Frame::Ping { id: 1 }.encode();
        bytes[4] = TRACED_VERSION + 1;
        let err = read_raw(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn v1_peer_bytes_still_parse() {
        // a hand-built v1 SEARCH frame (no trace id), as an old client
        // would emit it, must decode to trace_id = 0
        let mut payload = Vec::new();
        payload.extend_from_slice(&2u32.to_le_bytes()); // top_p
        payload.extend_from_slice(&3u32.to_le_bytes()); // top_k
        payload.extend_from_slice(&1u32.to_le_bytes()); // dim
        payload.extend_from_slice(&0.5f32.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(1); // literal v1
        bytes.push(FT_SEARCH);
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&8u64.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&payload);
        let raw = read_raw(&mut std::io::Cursor::new(bytes)).unwrap();
        let Frame::Search(r) = parse(&raw).unwrap() else { panic!("wrong type") };
        assert_eq!(r.trace_id, 0);
        assert_eq!(r.id, 8);
        assert_eq!(r.vector, vec![0.5]);
    }

    #[test]
    fn metrics_frames_mirror_stats_behaviour() {
        // payload on the request side is an error, like other admin ops
        let raw = RawFrame { ftype: FT_METRICS, id: 4, payload: vec![1] };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
        // reply must be utf-8
        let raw = RawFrame { ftype: FT_METRICS_REPLY, id: 5, payload: vec![0xFF, 0xFE] };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
    }

    #[test]
    fn explain_validation_mirrors_search() {
        let encode = |flags: u32, dim: u32, floats: usize, top_k: u32| {
            let mut payload = Vec::new();
            payload.extend_from_slice(&flags.to_le_bytes());
            payload.extend_from_slice(&1u32.to_le_bytes()); // top_p
            payload.extend_from_slice(&top_k.to_le_bytes());
            payload.extend_from_slice(&dim.to_le_bytes());
            for _ in 0..floats {
                payload.extend_from_slice(&0f32.to_le_bytes());
            }
            RawFrame { ftype: FT_EXPLAIN, id: 21, payload }
        };
        // zero-length
        let raw = RawFrame { ftype: FT_EXPLAIN, id: 20, payload: vec![] };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
        // unknown flag bits rejected loudly, never silently ignored
        assert_eq!(parse(&encode(0x2, 1, 1, 1)).unwrap_err().code, ERR_BAD_FRAME);
        // dim 0 and oversized top_k keep the SEARCH codes
        assert_eq!(parse(&encode(0, 0, 0, 1)).unwrap_err().code, ERR_BAD_DIM);
        assert_eq!(
            parse(&encode(0, 1, 1, MAX_WIRE_TOP_K + 1)).unwrap_err().code,
            ERR_BAD_K
        );
        // declared dim must match the bytes present before allocation
        assert_eq!(
            parse(&encode(0, u32::MAX, 1, 1)).unwrap_err().code,
            ERR_BAD_FRAME
        );
        assert_eq!(parse(&encode(0, 2, 3, 1)).unwrap_err().code, ERR_BAD_FRAME);
        // a well-formed frame parses with the flag decoded
        let Frame::Explain(e) =
            parse(&encode(EXPLAIN_FLAG_EXACT, 2, 2, 5)).unwrap()
        else {
            panic!("wrong type")
        };
        assert!(e.exact);
        assert_eq!(e.top_k, 5);
        assert_eq!(e.vector.len(), 2);
        // reply must be utf-8, like the other document replies
        let raw =
            RawFrame { ftype: FT_EXPLAIN_REPLY, id: 22, payload: vec![0xFF, 0xFE] };
        assert_eq!(parse(&raw).unwrap_err().code, ERR_BAD_FRAME);
    }
}
