//! Blocking client for the `amsearch` wire protocol, with connection
//! reuse and request pipelining.
//!
//! One [`NetClient`] owns one TCP connection and is used from one
//! thread (spawn one client per concurrent stream — the load-generator
//! pattern).  Requests may be pipelined: [`NetClient::submit`] sends a
//! search without waiting, [`NetClient::wait`] / [`NetClient::wait_any`]
//! collect responses, matching them to requests by the echoed id;
//! responses that arrive for *other* in-flight requests are buffered
//! until claimed, so completion order never confuses the caller.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::Json;

use super::wire::{self, Frame, WireError, WireExplain, WireRequest, WireResponse};

/// Bounded reconnect/backoff policy for clients that must survive
/// server restarts and transient refusals: exponential backoff with
/// jitter between attempts, capped per attempt and in total count.
/// Used by [`NetClient::connect_backoff`] and by the cluster router's
/// shard links.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total connect attempts before giving up (>= 1).
    pub max_attempts: usize,
    /// Backoff before the second attempt; doubled each further attempt.
    pub base_delay: Duration,
    /// Upper bound on any single backoff sleep.
    pub max_delay: Duration,
    /// Read timeout for the PING verification round-trip of each
    /// attempt — bounds how long an accepted-but-wedged endpoint can
    /// hold one attempt.
    pub verify_timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base_delay: Duration::from_millis(20),
            max_delay: Duration::from_millis(500),
            verify_timeout: Duration::from_secs(5),
        }
    }
}

impl RetryPolicy {
    /// Jittered exponential backoff before `attempt` (0-based; the
    /// first attempt never sleeps).  The jitter draws uniformly-ish
    /// from [50%, 100%] of the capped exponential delay using the clock
    /// nanos as entropy — enough to de-synchronize reconnect storms
    /// across links without an RNG dependency.
    pub fn delay(&self, attempt: usize) -> Duration {
        if attempt == 0 {
            return Duration::ZERO;
        }
        let exp = self
            .base_delay
            .saturating_mul(1u32 << (attempt - 1).min(16) as u32)
            .min(self.max_delay);
        let nanos = exp.as_nanos() as u64;
        if nanos == 0 {
            return Duration::ZERO;
        }
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0x9E37)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let jitter = (seed >> 33) % (nanos / 2 + 1);
        Duration::from_nanos(nanos / 2 + jitter)
    }
}

/// A blocking, pipelining-capable client over one TCP connection.
pub struct NetClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
    /// Responses received but not yet claimed by `wait`/`wait_any`,
    /// keyed by request id.
    ready: BTreeMap<u64, std::result::Result<WireResponse, WireError>>,
    /// Number of submitted searches not yet claimed.
    outstanding: usize,
}

impl NetClient {
    /// Connect to a server.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Coordinator(format!("net client: connect: {e}")))?;
        let _ = stream.set_nodelay(true);
        let read_half = stream
            .try_clone()
            .map_err(|e| Error::Coordinator(format!("net client: clone: {e}")))?;
        Ok(NetClient {
            writer: stream,
            reader: BufReader::new(read_half),
            next_id: 1,
            ready: BTreeMap::new(),
            outstanding: 0,
        })
    }

    /// Connect, retrying until `budget` elapses — for racing a server
    /// that is still binding (CI smoke runs, load generators).  Each
    /// attempt goes through the same PING-verified establishment as
    /// [`Self::connect_backoff`] (one implementation, two retry
    /// shapes: deadline-based here, attempt-based there).
    pub fn connect_retry(addr: &str, budget: Duration) -> Result<Self> {
        let deadline = Instant::now() + budget;
        loop {
            // each attempt's verification wait is capped by what is
            // left of the budget, so the deadline cannot be overshot by
            // a wedged endpoint holding the PING
            let remaining = deadline.saturating_duration_since(Instant::now());
            let one_attempt = RetryPolicy {
                max_attempts: 1,
                verify_timeout: remaining
                    .min(Duration::from_secs(5))
                    .max(Duration::from_millis(10)),
                ..Default::default()
            };
            match Self::connect_backoff(addr, &one_attempt) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(Error::Coordinator(format!(
                            "net client: no server at {addr} within {budget:?}: {e}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Connect with bounded, jittered exponential backoff, verifying
    /// each attempt with a PING round-trip.  A connection that is
    /// accepted but immediately answered with `ERR_OVERLOADED` (the
    /// server's handler pool is saturated) or closed by a restarting
    /// server fails the PING and counts as a failed attempt, so the
    /// caller never holds a half-open client — this is what lets
    /// router→shard links survive shard restarts.
    pub fn connect_backoff(addr: &str, policy: &RetryPolicy) -> Result<Self> {
        let attempts = policy.max_attempts.max(1);
        let mut last: Option<Error> = None;
        for attempt in 0..attempts {
            std::thread::sleep(policy.delay(attempt));
            match Self::connect(addr) {
                Ok(mut c) => {
                    // bound the verification so a dead-but-accepting
                    // endpoint fails the attempt instead of hanging it
                    let _ = c.set_timeout(Some(policy.verify_timeout.max(
                        Duration::from_millis(10),
                    )));
                    match c.ping() {
                        Ok(()) => {
                            let _ = c.set_timeout(None);
                            return Ok(c);
                        }
                        Err(e) => last = Some(e),
                    }
                }
                Err(e) => last = Some(e),
            }
        }
        Err(Error::Coordinator(format!(
            "net client: {addr} unavailable after {attempts} attempts: {}",
            last.map(|e| e.to_string()).unwrap_or_else(|| "no attempt made".into())
        )))
    }

    /// Set (or clear) the socket read timeout — a hung server then
    /// surfaces as an error from `wait` instead of blocking forever.
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.writer
            .set_read_timeout(timeout)
            .map_err(|e| Error::Coordinator(format!("net client: timeout: {e}")))
    }

    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.writer
            .write_all(&frame.encode())
            .map_err(|e| Error::Coordinator(format!("net client: send: {e}")))
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Number of submitted searches whose responses have not been
    /// claimed yet (includes buffered ones).
    pub fn in_flight(&self) -> usize {
        self.outstanding
    }

    /// Send a search without waiting for the result; returns the
    /// request id to pass to [`Self::wait`].
    pub fn submit(&mut self, vector: &[f32], top_p: usize, top_k: usize) -> Result<u64> {
        self.submit_traced(vector, top_p, top_k, 0)
    }

    /// [`Self::submit`] carrying a trace id in the SEARCH frame
    /// (`0` = untraced, encodes as wire v1 — how a cluster router
    /// propagates its trace id to shards so their span records stitch).
    pub fn submit_traced(
        &mut self,
        vector: &[f32],
        top_p: usize,
        top_k: usize,
        trace_id: u64,
    ) -> Result<u64> {
        let id = self.fresh_id();
        self.send(&Frame::Search(WireRequest {
            id,
            top_p: top_p as u32,
            top_k: top_k as u32,
            trace_id,
            vector: vector.to_vec(),
        }))?;
        self.outstanding += 1;
        Ok(id)
    }

    /// Read one frame and file it (results and errors keyed by id).
    fn pump(&mut self) -> Result<()> {
        match wire::read_frame(&mut self.reader)? {
            Frame::Result(r) => {
                self.ready.insert(r.id, Ok(r));
                Ok(())
            }
            Frame::Error(e) => {
                self.ready.insert(e.id, Err(e));
                Ok(())
            }
            other => Err(Error::Coordinator(format!(
                "net client: unexpected frame {other:?} while awaiting results"
            ))),
        }
    }

    /// Block until the response for `id` arrives; responses for other
    /// in-flight requests encountered on the way are buffered.
    /// A server-side ERROR frame surfaces as the `Err` arm of the inner
    /// result, carrying its stable code.
    pub fn wait_detailed(
        &mut self,
        id: u64,
    ) -> Result<std::result::Result<WireResponse, WireError>> {
        loop {
            if let Some(r) = self.ready.remove(&id) {
                self.outstanding = self.outstanding.saturating_sub(1);
                return Ok(r);
            }
            self.pump()?;
        }
    }

    /// [`Self::wait_detailed`], flattening server errors into
    /// [`Error::Coordinator`].
    pub fn wait(&mut self, id: u64) -> Result<WireResponse> {
        self.wait_detailed(id)?.map_err(wire_error)
    }

    /// Block until *any* in-flight response arrives and claim it —
    /// the closed-loop load-generator primitive.
    pub fn wait_any_detailed(
        &mut self,
    ) -> Result<(u64, std::result::Result<WireResponse, WireError>)> {
        if self.outstanding == 0 {
            return Err(Error::Coordinator("net client: nothing in flight".into()));
        }
        loop {
            if let Some((id, r)) = self.ready.pop_first() {
                self.outstanding = self.outstanding.saturating_sub(1);
                return Ok((id, r));
            }
            self.pump()?;
        }
    }

    /// Blocking k-NN search: submit + wait.  `top_p`/`top_k` follow the
    /// server-boundary rules (`0` = index default).
    pub fn search_k(
        &mut self,
        vector: &[f32],
        top_p: usize,
        top_k: usize,
    ) -> Result<WireResponse> {
        let id = self.submit(vector, top_p, top_k)?;
        self.wait(id)
    }

    /// Round-trip admin request: send `req`, pump search responses into
    /// the buffer until the matching admin reply arrives.
    fn admin(&mut self, req: Frame, accept: fn(&Frame) -> bool) -> Result<Frame> {
        let want_id = req.id();
        self.send(&req)?;
        loop {
            let frame = wire::read_frame(&mut self.reader)?;
            match frame {
                Frame::Result(r) => {
                    self.ready.insert(r.id, Ok(r));
                }
                Frame::Error(e) if e.id != want_id => {
                    self.ready.insert(e.id, Err(e));
                }
                Frame::Error(e) => return Err(wire_error(e)),
                f if f.id() == want_id && accept(&f) => return Ok(f),
                f => {
                    return Err(Error::Coordinator(format!(
                        "net client: unexpected admin reply {f:?}"
                    )))
                }
            }
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        let id = self.fresh_id();
        self.admin(Frame::Ping { id }, |f| matches!(f, Frame::Pong { .. }))?;
        Ok(())
    }

    /// Fetch the server's metrics snapshot (parsed JSON).
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.fresh_id();
        let reply =
            self.admin(Frame::Stats { id }, |f| matches!(f, Frame::StatsReply { .. }))?;
        let Frame::StatsReply { json, .. } = reply else {
            // admin() only accepts the frame the predicate matched, but
            // a typed error beats a panic inside a serving client
            return Err(Error::Coordinator(
                "net client: stats reply of unexpected type".into(),
            ));
        };
        Json::parse(&json)
    }

    /// Fetch the server's Prometheus text exposition (the METRICS admin
    /// op) — same snapshot discipline as [`Self::stats`], different
    /// rendering.
    pub fn metrics_text(&mut self) -> Result<String> {
        let id = self.fresh_id();
        let reply = self.admin(Frame::Metrics { id }, |f| {
            matches!(f, Frame::MetricsReply { .. })
        })?;
        let Frame::MetricsReply { text, .. } = reply else {
            return Err(Error::Coordinator(
                "net client: metrics reply of unexpected type".into(),
            ));
        };
        Ok(text)
    }

    /// Replay one query through the server with full introspection (the
    /// EXPLAIN admin op); `exact` also runs the ground-truth diff.
    /// `top_p`/`top_k` follow the server-boundary rules (`0` = default).
    /// Returns the parsed introspection report.
    pub fn explain(
        &mut self,
        vector: &[f32],
        top_p: u32,
        top_k: u32,
        exact: bool,
    ) -> Result<Json> {
        let id = self.fresh_id();
        let req = Frame::Explain(WireExplain {
            id,
            exact,
            top_p,
            top_k,
            vector: vector.to_vec(),
        });
        let reply =
            self.admin(req, |f| matches!(f, Frame::ExplainReply { .. }))?;
        let Frame::ExplainReply { json, .. } = reply else {
            return Err(Error::Coordinator(
                "net client: explain reply of unexpected type".into(),
            ));
        };
        Json::parse(&json)
    }

    /// Ask the server to shut down gracefully; returns once the server
    /// acknowledged (it then drains in-flight work and closes).
    pub fn shutdown_server(&mut self) -> Result<()> {
        let id = self.fresh_id();
        self.admin(Frame::Shutdown { id }, |f| {
            matches!(f, Frame::ShutdownOk { .. })
        })?;
        Ok(())
    }
}

fn wire_error(e: WireError) -> Error {
    Error::Coordinator(format!("server error (code {}): {}", e.code, e.message))
}
