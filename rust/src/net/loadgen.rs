//! Closed-loop load generator for the TCP front door.
//!
//! Each of `connections` client threads keeps exactly `depth` searches
//! pipelined on its own connection (a closed loop: a new request is
//! issued only when a response is claimed), measuring per-request
//! latency from submit to response arrival.  Per-connection
//! [`LatencyHistogram`]s merge into one report with throughput and
//! p50/p90/p99 — the end-to-end figure of merit for the serving stack,
//! emitted as `BENCH_net_serving.json` by the CLI / CI smoke run.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::{LatencyHistogram, WindowedHistogram};
use crate::util::{concurrent_map, Json};

use super::client::NetClient;

/// Load-generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct LoadGenConfig {
    /// Concurrent client connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Pipelined requests kept in flight per connection.
    pub depth: usize,
    /// Classes to poll per request (`0` = server default).
    pub top_p: usize,
    /// Neighbors per request (`0` = server default).
    pub top_k: usize,
    /// Budget for the initial connect (retried — the server may still
    /// be binding).
    pub connect_timeout: Duration,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            connections: 4,
            requests: 1000,
            depth: 8,
            top_p: 0,
            top_k: 0,
            connect_timeout: Duration::from_secs(10),
        }
    }
}

/// What a load run measured.
#[derive(Debug)]
pub struct LoadReport {
    /// Requests completed (success or server-side error response).
    pub requests: u64,
    /// Responses that were error frames.
    pub errors: u64,
    /// Wall-clock duration of the whole run.
    pub elapsed_s: f64,
    /// Per-request latency (submit → response arrival), merged across
    /// connections.
    pub latency: LatencyHistogram,
    /// Rolling-window view of the same samples: the tail over the last
    /// ~10 s of the run rather than the whole run (long runs hide
    /// late-run regressions in the cumulative view).
    pub window: WindowedHistogram,
    /// Echo of the run shape.
    pub connections: usize,
    /// Echo of the run shape.
    pub depth: usize,
}

impl LoadReport {
    /// Completed requests per second.
    pub fn qps(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.elapsed_s
        }
    }

    /// The report as a JSON object (reuses
    /// [`LatencyHistogram::to_json`]).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("connections".to_string(), Json::Num(self.connections as f64));
        o.insert("depth".to_string(), Json::Num(self.depth as f64));
        o.insert("requests".to_string(), Json::Num(self.requests as f64));
        o.insert("errors".to_string(), Json::Num(self.errors as f64));
        o.insert("elapsed_s".to_string(), Json::Num(self.elapsed_s));
        o.insert("qps".to_string(), Json::Num(self.qps()));
        o.insert("latency".to_string(), self.latency.to_json());
        o.insert("window".to_string(), self.window.to_json());
        o.insert(
            "window_p99_ns".to_string(),
            Json::Num(self.window.windowed().quantile_ns(0.99) as f64),
        );
        Json::Obj(o)
    }

    /// Console summary.
    pub fn print(&self) {
        println!(
            "loadgen: {} requests ({} errors) over {} connections x depth {} \
             in {:.3}s -> {:.0} qps",
            self.requests,
            self.errors,
            self.connections,
            self.depth,
            self.elapsed_s,
            self.qps()
        );
        println!("latency: {}", self.latency.summary());
        let w = self.window.windowed();
        println!(
            "windowed (last {:.0}s): {} samples, p99 {} ns",
            self.window.window_ns() as f64 / 1e9,
            w.count(),
            w.quantile_ns(0.99)
        );
    }
}

/// Drive `addr` with a closed-loop pipelined load of `cfg.requests`
/// searches drawn round-robin from `queries`.
pub fn run(addr: &str, queries: &[Vec<f32>], cfg: &LoadGenConfig) -> Result<LoadReport> {
    if queries.is_empty() {
        return Err(Error::Config("loadgen: empty query set".into()));
    }
    if cfg.connections == 0 || cfg.depth == 0 {
        return Err(Error::Config("loadgen: connections/depth must be > 0".into()));
    }
    // split the request budget across connections (first r % c get +1)
    let base = cfg.requests / cfg.connections;
    let extra = cfg.requests % cfg.connections;
    let started = Instant::now();
    let results: Vec<Result<(LatencyHistogram, WindowedHistogram, u64)>> =
        concurrent_map(cfg.connections, cfg.connections, |ci| {
            let n = base + usize::from(ci < extra);
            run_connection(addr, queries, cfg, ci, n)
        });
    let elapsed_s = started.elapsed().as_secs_f64();
    let mut latency = LatencyHistogram::new();
    let mut window = WindowedHistogram::new();
    let mut errors = 0u64;
    for r in results {
        let (h, w, e) = r?; // a connection-level failure fails the run
        latency.merge(&h);
        window.merge(&w);
        errors += e;
    }
    Ok(LoadReport {
        requests: latency.count(),
        errors,
        elapsed_s,
        latency,
        window,
        connections: cfg.connections,
        depth: cfg.depth,
    })
}

/// One connection's closed loop: keep `depth` in flight until `n`
/// responses are claimed.
fn run_connection(
    addr: &str,
    queries: &[Vec<f32>],
    cfg: &LoadGenConfig,
    ci: usize,
    n: usize,
) -> Result<(LatencyHistogram, WindowedHistogram, u64)> {
    let mut hist = LatencyHistogram::new();
    let mut window = WindowedHistogram::new();
    let mut errors = 0u64;
    if n == 0 {
        return Ok((hist, window, errors));
    }
    let mut client = NetClient::connect_retry(addr, cfg.connect_timeout)?;
    client.set_timeout(Some(Duration::from_secs(60)))?;
    let mut starts: BTreeMap<u64, Instant> = BTreeMap::new();
    let mut issued = 0usize;
    let mut done = 0usize;
    while done < n {
        while issued < n && starts.len() < cfg.depth {
            // deterministic round-robin interleaved across connections
            let q = &queries[(ci + issued * cfg.connections) % queries.len()];
            let id = client.submit(q, cfg.top_p, cfg.top_k)?;
            starts.insert(id, Instant::now());
            issued += 1;
        }
        let (id, result) = client.wait_any_detailed()?;
        if let Some(t0) = starts.remove(&id) {
            let ns = t0.elapsed().as_nanos() as u64;
            hist.record_ns(ns);
            window.record_ns(ns);
        }
        if result.is_err() {
            errors += 1;
        }
        done += 1;
    }
    Ok((hist, window, errors))
}
