//! Latency histogram with quantile estimation — the serving-side metric
//! the coordinator reports per request class.
//!
//! Log-scaled fixed buckets from 100ns to ~100s: constant-time record,
//! bounded memory, ~4% quantile resolution (plenty for p50/p95/p99
//! dashboards).

/// Number of histogram buckets.
const BUCKETS: usize = 512;
/// Lower edge of the first bucket (ns).
const MIN_NS: f64 = 100.0;
/// Upper edge of the last bucket (ns) ≈ 115 s.
const MAX_NS: f64 = 1.15e11;

/// Log-bucketed latency histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    max_ns: u64,
    min_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_ns: 0.0,
            max_ns: 0,
            min_ns: u64::MAX,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        let x = (ns as f64).max(MIN_NS).min(MAX_NS);
        let frac = (x / MIN_NS).ln() / (MAX_NS / MIN_NS).ln();
        ((frac * (BUCKETS - 1) as f64).round() as usize).min(BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        let frac = i as f64 / (BUCKETS - 1) as f64;
        (MIN_NS * (MAX_NS / MIN_NS).powf(frac)) as u64
    }

    /// Record one duration.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64)
    }

    /// Record one duration in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket_of(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.max_ns = self.max_ns.max(ns);
        self.min_ns = self.min_ns.min(ns);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean latency in ns.
    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    /// Exact observed maximum (ns).
    pub fn max_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.max_ns
        }
    }

    /// Quantile estimate (e.g. 0.5, 0.95, 0.99) in ns.
    pub fn quantile_ns(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        self.max_ns
    }

    /// Merge another histogram.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
    }

    /// The reported statistics as one tuple:
    /// `(count, mean_ns, p50_ns, p90_ns, p99_ns, max_ns)` — the single
    /// source for both [`Self::summary`] and [`Self::to_json`].
    fn snapshot(&self) -> (u64, f64, u64, u64, u64, u64) {
        (
            self.total,
            self.mean_ns(),
            self.quantile_ns(0.5),
            self.quantile_ns(0.9),
            self.quantile_ns(0.99),
            self.max_ns(),
        )
    }

    /// The histogram as a JSON object
    /// (`count`/`mean_ns`/`p50_ns`/`p90_ns`/`p99_ns`/`max_ns`) — reused
    /// by the network STATS op, the load generator report, and the
    /// bench JSON artifacts.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let (count, mean, p50, p90, p99, max) = self.snapshot();
        let mut o = std::collections::BTreeMap::new();
        o.insert("count".to_string(), Json::Num(count as f64));
        o.insert("mean_ns".to_string(), Json::Num(mean));
        o.insert("p50_ns".to_string(), Json::Num(p50 as f64));
        o.insert("p90_ns".to_string(), Json::Num(p90 as f64));
        o.insert("p99_ns".to_string(), Json::Num(p99 as f64));
        o.insert("max_ns".to_string(), Json::Num(max as f64));
        Json::Obj(o)
    }

    /// One-line human summary (same statistics as [`Self::to_json`]).
    pub fn summary(&self) -> String {
        let (count, mean, p50, p90, p99, max) = self.snapshot();
        format!(
            "n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us max={:.1}us",
            count,
            mean / 1e3,
            p50 as f64 / 1e3,
            p90 as f64 / 1e3,
            p99 as f64 / 1e3,
            max as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_ordered_and_close() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1us .. 1ms uniform
        }
        let p50 = h.quantile_ns(0.5);
        let p95 = h.quantile_ns(0.95);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // log-bucket resolution is ~4%; allow 10%
        assert!((p50 as f64 - 500_000.0).abs() / 500_000.0 < 0.1, "p50={p50}");
        assert!((p95 as f64 - 950_000.0).abs() / 950_000.0 < 0.1, "p95={p95}");
    }

    #[test]
    fn mean_and_max_exact() {
        let mut h = LatencyHistogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
        assert_eq!(h.max_ns(), 300);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record_ns(1_000);
            b.record_ns(1_000_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert!(a.quantile_ns(0.25) < 10_000);
        assert!(a.quantile_ns(0.75) > 100_000);
    }

    #[test]
    fn empty_is_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert!(!h.summary().is_empty());
    }

    #[test]
    fn to_json_carries_all_quantiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record_ns(i * 1_000);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(100));
        assert_eq!(j.get("max_ns").unwrap().as_u64(), Some(100_000));
        let p50 = j.get("p50_ns").unwrap().as_f64().unwrap();
        let p90 = j.get("p90_ns").unwrap().as_f64().unwrap();
        let p99 = j.get("p99_ns").unwrap().as_f64().unwrap();
        assert!(p50 <= p90 && p90 <= p99);
        assert!(j.get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        // serializes to a parseable document (bench artifact path)
        let text = j.to_string();
        assert_eq!(crate::util::Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn extreme_values_clamp() {
        let mut h = LatencyHistogram::new();
        h.record_ns(1); // below MIN
        h.record_ns(u64::MAX / 2); // above MAX
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ns(0.0) >= 100);
    }
}
