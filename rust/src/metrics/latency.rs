//! Latency histogram with quantile estimation — the serving-side metric
//! the coordinator reports per request class.
//!
//! Log-scaled fixed buckets from 100ns to ~100s: constant-time record,
//! bounded memory, ~4% quantile resolution (plenty for p50/p95/p99
//! dashboards).

/// Number of histogram buckets.
const BUCKETS: usize = 512;
/// Lower edge of the first bucket (ns).
const MIN_NS: f64 = 100.0;
/// Upper edge of the last bucket (ns) ≈ 115 s.
const MAX_NS: f64 = 1.15e11;

/// Log-bucketed latency histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    max_ns: u64,
    min_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_ns: 0.0,
            max_ns: 0,
            min_ns: u64::MAX,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        let x = (ns as f64).max(MIN_NS).min(MAX_NS);
        let frac = (x / MIN_NS).ln() / (MAX_NS / MIN_NS).ln();
        ((frac * (BUCKETS - 1) as f64).round() as usize).min(BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        let frac = i as f64 / (BUCKETS - 1) as f64;
        (MIN_NS * (MAX_NS / MIN_NS).powf(frac)) as u64
    }

    /// Record one duration.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64)
    }

    /// Record one duration in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket_of(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.max_ns = self.max_ns.max(ns);
        self.min_ns = self.min_ns.min(ns);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded durations in ns (Prometheus `_sum` sample).
    pub fn sum_ns(&self) -> f64 {
        self.sum_ns
    }

    /// Mean latency in ns.
    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    /// Exact observed maximum (ns).
    pub fn max_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.max_ns
        }
    }

    /// Quantile estimate (e.g. 0.5, 0.95, 0.99) in ns.
    pub fn quantile_ns(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        self.max_ns
    }

    /// Merge another histogram.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
    }

    /// The reported statistics as one tuple:
    /// `(count, mean_ns, p50_ns, p90_ns, p99_ns, max_ns)` — the single
    /// source for both [`Self::summary`] and [`Self::to_json`].
    fn snapshot(&self) -> (u64, f64, u64, u64, u64, u64) {
        (
            self.total,
            self.mean_ns(),
            self.quantile_ns(0.5),
            self.quantile_ns(0.9),
            self.quantile_ns(0.99),
            self.max_ns(),
        )
    }

    /// The histogram as a JSON object
    /// (`count`/`mean_ns`/`p50_ns`/`p90_ns`/`p99_ns`/`max_ns`) — reused
    /// by the network STATS op, the load generator report, and the
    /// bench JSON artifacts.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let (count, mean, p50, p90, p99, max) = self.snapshot();
        let mut o = std::collections::BTreeMap::new();
        o.insert("count".to_string(), Json::Num(count as f64));
        o.insert("mean_ns".to_string(), Json::Num(mean));
        o.insert("p50_ns".to_string(), Json::Num(p50 as f64));
        o.insert("p90_ns".to_string(), Json::Num(p90 as f64));
        o.insert("p99_ns".to_string(), Json::Num(p99 as f64));
        o.insert("max_ns".to_string(), Json::Num(max as f64));
        Json::Obj(o)
    }

    /// One-line human summary (same statistics as [`Self::to_json`]).
    pub fn summary(&self) -> String {
        let (count, mean, p50, p90, p99, max) = self.snapshot();
        format!(
            "n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us max={:.1}us",
            count,
            mean / 1e3,
            p50 as f64 / 1e3,
            p90 as f64 / 1e3,
            p99 as f64 / 1e3,
            max as f64 / 1e3,
        )
    }
}

/// Default rolling-window span for [`WindowedHistogram`]: 10 one-second
/// slots, so quantiles cover roughly the last ten seconds of traffic.
const DEFAULT_SLOT_NS: u64 = 1_000_000_000;
/// Default number of ring slots.
const DEFAULT_SLOTS: usize = 10;

/// A ring of [`LatencyHistogram`] slots giving quantiles over the last
/// N seconds instead of since boot — the live-tail estimate a hedging
/// policy (ROADMAP item 3) needs, and what `window` blocks in STATS /
/// the Prometheus exposition report.
///
/// Time is divided into fixed epochs of `slot_ns`; epoch `e` writes to
/// slot `e % slots.len()`, resetting the slot first if it still holds a
/// stale epoch.  Each slot remembers which epoch it holds as
/// `epoch + 1` (`0` = never written) so a genuine epoch 0 is not
/// confused with an empty slot.  All mutating entry points take an
/// explicit `now_ns` (`*_at` variants) so tests and proptests are
/// deterministic; the plain variants read [`crate::util::clock`].
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    slots: Vec<LatencyHistogram>,
    /// `epoch + 1` per slot; `0` marks a slot that was never written.
    epochs: Vec<u64>,
    slot_ns: u64,
}

impl Default for WindowedHistogram {
    fn default() -> Self {
        Self::with_slots(DEFAULT_SLOT_NS, DEFAULT_SLOTS)
    }
}

impl WindowedHistogram {
    /// Window of `DEFAULT_SLOTS` slots covering roughly 10 s.
    pub fn new() -> Self {
        Self::default()
    }

    /// Window with explicit slot width and count (both clamped to ≥ 1).
    pub fn with_slots(slot_ns: u64, n_slots: usize) -> Self {
        let n = n_slots.max(1);
        WindowedHistogram {
            slots: (0..n).map(|_| LatencyHistogram::new()).collect(),
            epochs: vec![0; n],
            slot_ns: slot_ns.max(1),
        }
    }

    /// Total span of the window in nanoseconds.
    pub fn window_ns(&self) -> u64 {
        self.slot_ns.saturating_mul(self.slots.len() as u64)
    }

    fn epoch_of(&self, now_ns: u64) -> u64 {
        now_ns / self.slot_ns
    }

    /// Record a sample with an explicit clock reading (deterministic).
    pub fn record_at(&mut self, ns: u64, now_ns: u64) {
        let epoch = self.epoch_of(now_ns);
        let idx = (epoch % self.slots.len() as u64) as usize;
        if self.epochs[idx] != epoch + 1 {
            self.slots[idx] = LatencyHistogram::new();
            self.epochs[idx] = epoch + 1;
        }
        self.slots[idx].record_ns(ns);
    }

    /// Record a sample at the current process clock.
    pub fn record_ns(&mut self, ns: u64) {
        self.record_at(ns, crate::util::clock::monotonic_ns());
    }

    /// Record a duration at the current process clock.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Oldest epoch (inclusive) still inside the window ending at
    /// `now_ns`'s epoch.
    fn live_floor(&self, now_ns: u64) -> u64 {
        self.epoch_of(now_ns)
            .saturating_sub(self.slots.len() as u64 - 1)
    }

    /// The merged histogram of all slots still inside the window at an
    /// explicit clock reading.
    pub fn windowed_at(&self, now_ns: u64) -> LatencyHistogram {
        let floor = self.live_floor(now_ns);
        let mut out = LatencyHistogram::new();
        for (slot, &e) in self.slots.iter().zip(&self.epochs) {
            if e > 0 && e - 1 >= floor {
                out.merge(slot);
            }
        }
        out
    }

    /// The merged histogram of the live window at the current process
    /// clock — feed the result's `to_json`/`summary`/quantiles.
    pub fn windowed(&self) -> LatencyHistogram {
        self.windowed_at(crate::util::clock::monotonic_ns())
    }

    /// Merge another window into this one at an explicit clock reading.
    /// Per slot index the newer epoch wins (equal epochs merge); slots
    /// already outside the window are skipped.  With a shared clock this
    /// makes merging associative and commutative: each index ends up
    /// holding the merge of every input slot carrying the maximum epoch
    /// for that index.  Mismatched shapes (different slot width or
    /// count) are skipped rather than merged wrongly.
    pub fn merge_at(&mut self, other: &WindowedHistogram, now_ns: u64) {
        if other.slot_ns != self.slot_ns || other.slots.len() != self.slots.len() {
            return; // refusing beats merging epochs that mean different times
        }
        let floor = self.live_floor(now_ns);
        for i in 0..self.slots.len() {
            let oe = other.epochs[i];
            if oe == 0 || oe - 1 < floor {
                continue;
            }
            let se = self.epochs[i];
            if oe > se {
                self.slots[i] = other.slots[i].clone();
                self.epochs[i] = oe;
            } else if oe == se {
                self.slots[i].merge(&other.slots[i]);
            }
        }
    }

    /// Merge another window at the current process clock.
    pub fn merge(&mut self, other: &WindowedHistogram) {
        self.merge_at(other, crate::util::clock::monotonic_ns());
    }

    /// JSON view: the live window's statistics plus the window span, an
    /// additive sibling of [`LatencyHistogram::to_json`].
    pub fn to_json(&self) -> crate::util::Json {
        self.to_json_at(crate::util::clock::monotonic_ns())
    }

    /// Deterministic variant of [`Self::to_json`].
    pub fn to_json_at(&self, now_ns: u64) -> crate::util::Json {
        use crate::util::Json;
        let mut j = self.windowed_at(now_ns).to_json();
        if let Json::Obj(o) = &mut j {
            o.insert(
                "window_s".to_string(),
                Json::Num(self.window_ns() as f64 / 1e9),
            );
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_ordered_and_close() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1us .. 1ms uniform
        }
        let p50 = h.quantile_ns(0.5);
        let p95 = h.quantile_ns(0.95);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // log-bucket resolution is ~4%; allow 10%
        assert!((p50 as f64 - 500_000.0).abs() / 500_000.0 < 0.1, "p50={p50}");
        assert!((p95 as f64 - 950_000.0).abs() / 950_000.0 < 0.1, "p95={p95}");
    }

    #[test]
    fn mean_and_max_exact() {
        let mut h = LatencyHistogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
        assert_eq!(h.max_ns(), 300);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record_ns(1_000);
            b.record_ns(1_000_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert!(a.quantile_ns(0.25) < 10_000);
        assert!(a.quantile_ns(0.75) > 100_000);
    }

    #[test]
    fn empty_is_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert!(!h.summary().is_empty());
    }

    #[test]
    fn to_json_carries_all_quantiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record_ns(i * 1_000);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(100));
        assert_eq!(j.get("max_ns").unwrap().as_u64(), Some(100_000));
        let p50 = j.get("p50_ns").unwrap().as_f64().unwrap();
        let p90 = j.get("p90_ns").unwrap().as_f64().unwrap();
        let p99 = j.get("p99_ns").unwrap().as_f64().unwrap();
        assert!(p50 <= p90 && p90 <= p99);
        assert!(j.get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        // serializes to a parseable document (bench artifact path)
        let text = j.to_string();
        assert_eq!(crate::util::Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn extreme_values_clamp() {
        let mut h = LatencyHistogram::new();
        h.record_ns(1); // below MIN
        h.record_ns(u64::MAX / 2); // above MAX
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ns(0.0) >= 100);
    }

    // --- WindowedHistogram ---

    /// Clock reading in the middle of epoch `e` for a given slot width.
    fn mid(slot_ns: u64, e: u64) -> u64 {
        e * slot_ns + slot_ns / 2
    }

    #[test]
    fn window_drops_old_epochs() {
        let slot = 1_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 4);
        w.record_at(10_000, mid(slot, 0));
        w.record_at(20_000, mid(slot, 1));
        assert_eq!(w.windowed_at(mid(slot, 1)).count(), 2);
        // epoch 4: window covers epochs 1..=4, epoch 0 falls out
        assert_eq!(w.windowed_at(mid(slot, 4)).count(), 1);
        // epoch 5: everything has aged out
        assert_eq!(w.windowed_at(mid(slot, 5)).count(), 0);
    }

    #[test]
    fn stale_slot_resets_on_reuse() {
        let slot = 1_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 2);
        w.record_at(10_000, mid(slot, 0));
        // epoch 2 reuses slot 0 and must not inherit epoch 0's sample
        w.record_at(30_000, mid(slot, 2));
        let h = w.windowed_at(mid(slot, 2));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_ns(), 30_000);
    }

    #[test]
    fn genuine_epoch_zero_is_live() {
        let slot = 1_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 4);
        w.record_at(5_000, 0); // now_ns = 0 → epoch 0
        assert_eq!(w.windowed_at(0).count(), 1);
    }

    #[test]
    fn window_agrees_with_cumulative_when_covered() {
        let slot = 1_000_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 8);
        let mut c = LatencyHistogram::new();
        for i in 1..=50u64 {
            let now = mid(slot, i % 8); // stays inside the window
            w.record_at(i * 777, now);
            c.record_ns(i * 777);
        }
        let h = w.windowed_at(mid(slot, 7));
        assert_eq!(h.count(), c.count());
        assert_eq!(h.max_ns(), c.max_ns());
        assert_eq!(h.quantile_ns(0.5), c.quantile_ns(0.5));
        assert_eq!(h.quantile_ns(0.99), c.quantile_ns(0.99));
        assert!((h.mean_ns() - c.mean_ns()).abs() < 1e-6);
    }

    #[test]
    fn merge_takes_newer_epoch_and_merges_equal() {
        let slot = 1_000u64;
        let now = mid(slot, 3);
        let mut a = WindowedHistogram::with_slots(slot, 4);
        let mut b = WindowedHistogram::with_slots(slot, 4);
        a.record_at(1_000, mid(slot, 3));
        b.record_at(2_000, mid(slot, 3)); // equal epoch → merge
        b.record_at(9_000, mid(slot, 2)); // only in b → adopt
        a.merge_at(&b, now);
        let h = a.windowed_at(now);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_ns(), 9_000);
    }

    #[test]
    fn merge_skips_mismatched_shapes_and_stale_slots() {
        let slot = 1_000u64;
        let mut a = WindowedHistogram::with_slots(slot, 4);
        let b = WindowedHistogram::with_slots(slot, 8);
        a.merge_at(&b, mid(slot, 0)); // shape mismatch: silent no-op
        let mut c = WindowedHistogram::with_slots(slot, 4);
        c.record_at(1_000, mid(slot, 0));
        a.merge_at(&c, mid(slot, 10)); // c's sample is outside the window
        assert_eq!(a.windowed_at(mid(slot, 10)).count(), 0);
    }

    #[test]
    fn epoch_wrap_at_ring_boundary_evicts_exactly_one_epoch() {
        let slot = 1_000u64;
        let n = 4usize;
        let mut w = WindowedHistogram::with_slots(slot, n);
        // fill every slot: epochs 0..=3
        for e in 0..n as u64 {
            w.record_at(10_000 * (e + 1), mid(slot, e));
        }
        assert_eq!(w.windowed_at(mid(slot, 3)).count(), 4);
        // epoch 4 wraps to slot 0: epoch 0's sample is overwritten, the
        // other three survive alongside the new one — the wrap evicts
        // exactly the epoch that aged out, nothing more
        w.record_at(90_000, mid(slot, 4));
        let h = w.windowed_at(mid(slot, 4));
        assert_eq!(h.count(), 4);
        assert_eq!(h.max_ns(), 90_000);
        // epoch 0's 10us sample is gone (log-bucket resolution ~4%)
        assert!(h.quantile_ns(0.0) >= 15_000);
    }

    #[test]
    fn clock_backwards_write_is_contained() {
        let slot = 1_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 4);
        w.record_at(10_000, mid(slot, 6)); // slot 2 holds epoch 6
        // a backwards clock reading lands in epoch 2 — the same ring
        // slot.  Last writer wins: the slot now holds epoch 2.  The
        // important invariants are no panic, no mixed-epoch slot, and
        // the stale write staying out of the live view.
        w.record_at(20_000, mid(slot, 2));
        assert_eq!(w.windowed_at(mid(slot, 6)).count(), 0);
        // a backwards *query* sees the epoch-2 write, coherently
        assert_eq!(w.windowed_at(mid(slot, 2)).count(), 1);
        // forward progress resumes cleanly after the glitch
        w.record_at(30_000, mid(slot, 6));
        let h = w.windowed_at(mid(slot, 6));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_ns(), 30_000);
    }

    #[test]
    fn merge_with_epochs_misaligned_beyond_window_length() {
        let slot = 1_000u64;
        let n = 4usize;
        let mut a = WindowedHistogram::with_slots(slot, n);
        let mut b = WindowedHistogram::with_slots(slot, n);
        // a's samples live in epochs 0..=3, b's a full window later
        // (8..=11): same ring indices, disjoint epochs
        for e in 0..n as u64 {
            a.record_at(1_000, mid(slot, e));
            b.record_at(2_000, mid(slot, e + 8));
        }
        // merging at b's clock: every a slot is below the floor and
        // every b slot is adopted — no cross-epoch mixing
        a.merge_at(&b, mid(slot, 11));
        let h = a.windowed_at(mid(slot, 11));
        assert_eq!(h.count(), 4);
        assert!(h.quantile_ns(0.0) >= 1_500);
        // the newer epoch wins even when the local clock lags a full
        // window behind the peer's: epochs, not `now`, decide adoption
        let mut c = WindowedHistogram::with_slots(slot, n);
        for e in 0..n as u64 {
            c.record_at(3_000, mid(slot, e));
        }
        c.merge_at(&a, mid(slot, 3));
        assert_eq!(c.windowed_at(mid(slot, 11)).count(), 4);
        assert!(c.windowed_at(mid(slot, 11)).max_ns() <= 2_500);
    }

    #[test]
    fn windowed_json_has_window_span() {
        let slot = 1_000_000_000u64;
        let mut w = WindowedHistogram::with_slots(slot, 10);
        w.record_at(5_000, mid(slot, 0));
        let j = w.to_json_at(mid(slot, 0));
        assert_eq!(j.get("count").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("window_s").unwrap().as_f64(), Some(10.0));
        assert!(j.get("p99_ns").is_some());
    }
}
