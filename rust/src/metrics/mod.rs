//! Measurement: the paper's complexity accounting, recall/error-rate
//! estimation, and serving latency histograms.

pub mod fanout;
pub mod latency;
pub mod ops;
pub mod recall;

pub use fanout::{FanoutStats, PruneRecall};
pub use latency::{LatencyHistogram, WindowedHistogram};
pub use ops::{BatchScanStats, CostModel, OpsCounter};
pub use recall::{Recall, RecallAtK};
