//! Recall and error-rate metrics.
//!
//! * Figures 1–8 plot the **error rate**: the probability that the class
//!   containing the query's true match does *not* achieve the highest
//!   score.
//! * Figures 9–12 plot **recall@1**: the rate at which the true nearest
//!   neighbor is found within the candidates of the first `p` classes.
//! * The k-NN eval reports **recall@k** ([`RecallAtK`]): the fraction of
//!   the true k nearest neighbors present in the returned k — the
//!   standard ANN reporting axis (Andoni–Indyk–Razenshteyn 2018).

/// Streaming recall@1 accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Recall {
    hits: u64,
    total: u64,
}

impl Recall {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one query outcome.
    pub fn record(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        }
        self.total += 1;
    }

    /// Number of recorded queries.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// recall@1 in [0, 1].
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.hits as f64 / self.total as f64
        }
    }

    /// Error rate = 1 − recall.
    pub fn error_rate(&self) -> f64 {
        1.0 - self.value()
    }

    /// Standard error of the estimate (binomial).
    pub fn std_error(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = self.value();
        (p * (1.0 - p) / self.total as f64).sqrt()
    }

    /// Merge another accumulator.
    pub fn merge(&mut self, other: &Recall) {
        self.hits += other.hits;
        self.total += other.total;
    }
}

/// Streaming recall@k accumulator: per query, the fraction of the exact
/// k nearest neighbors that appear among the returned k
/// (`|returned ∩ truth| / |truth|`, so a database smaller than k is not
/// penalized).  At k = 1 with one returned id this is exactly [`Recall`].
#[derive(Debug, Clone, Copy)]
pub struct RecallAtK {
    k: usize,
    sum: f64,
    total: u64,
}

impl RecallAtK {
    /// Fresh accumulator for a given `k` (> 0).
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        RecallAtK { k, sum: 0.0, total: 0 }
    }

    /// The `k` this accumulator measures.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Record one query: `returned` are the ids the system answered
    /// (nearest first), `truth` the exact nearest ids (nearest first).
    /// Both are truncated to `k` before intersecting.
    pub fn record(&mut self, returned: &[u32], truth: &[u32]) {
        let truth = &truth[..truth.len().min(self.k)];
        let returned = &returned[..returned.len().min(self.k)];
        let hits = returned.iter().filter(|id| truth.contains(*id)).count();
        if !truth.is_empty() {
            self.sum += hits as f64 / truth.len() as f64;
        }
        self.total += 1;
    }

    /// Number of recorded queries.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Mean recall@k in [0, 1].
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Merge another accumulator (same `k`).
    pub fn merge(&mut self, other: &RecallAtK) {
        assert_eq!(self.k, other.k, "cannot merge recall@k of different k");
        self.sum += other.sum;
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rates() {
        let mut r = Recall::new();
        for i in 0..10 {
            r.record(i < 7);
        }
        assert_eq!(r.value(), 0.7);
        assert!((r.error_rate() - 0.3).abs() < 1e-12);
        assert_eq!(r.total(), 10);
    }

    #[test]
    fn std_error_shrinks_with_n() {
        let mut small = Recall::new();
        let mut large = Recall::new();
        for i in 0..10 {
            small.record(i % 2 == 0);
        }
        for i in 0..1000 {
            large.record(i % 2 == 0);
        }
        assert!(large.std_error() < small.std_error());
    }

    #[test]
    fn merge_combines() {
        let mut a = Recall::new();
        a.record(true);
        let mut b = Recall::new();
        b.record(false);
        b.record(false);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert!((a.value() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let r = Recall::new();
        assert_eq!(r.value(), 0.0);
        assert_eq!(r.std_error(), 0.0);
    }

    #[test]
    fn recall_at_k_counts_intersection() {
        let mut r = RecallAtK::new(3);
        r.record(&[1, 2, 3], &[1, 2, 3]); // perfect -> 1.0
        r.record(&[1, 9, 8], &[1, 2, 3]); // one of three -> 1/3
        r.record(&[7, 8, 9], &[1, 2, 3]); // none -> 0
        assert_eq!(r.total(), 3);
        assert!((r.value() - (1.0 + 1.0 / 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn recall_at_k_order_independent_and_truncating() {
        let mut r = RecallAtK::new(2);
        // extra entries beyond k are ignored on both sides
        r.record(&[5, 4, 999], &[4, 5, 777]);
        assert_eq!(r.value(), 1.0);
        // truth shorter than k (n < k): not penalized
        let mut r = RecallAtK::new(10);
        r.record(&[3, 1, 2], &[1, 2, 3]);
        assert_eq!(r.value(), 1.0);
    }

    #[test]
    fn recall_at_1_matches_hit_based_recall() {
        let mut a = RecallAtK::new(1);
        let mut b = Recall::new();
        for (ret, truth) in [(4u32, 4u32), (5, 9), (1, 1), (0, 2)] {
            a.record(&[ret], &[truth]);
            b.record(ret == truth);
        }
        assert_eq!(a.value(), b.value());
    }

    #[test]
    fn recall_at_k_merge() {
        let mut a = RecallAtK::new(2);
        a.record(&[1, 2], &[1, 2]);
        let mut b = RecallAtK::new(2);
        b.record(&[8, 9], &[1, 2]);
        a.merge(&b);
        assert_eq!(a.total(), 2);
        assert_eq!(a.value(), 0.5);
    }

    #[test]
    #[should_panic]
    fn recall_at_k_zero_panics() {
        RecallAtK::new(0);
    }
}
