//! Recall and error-rate metrics.
//!
//! * Figures 1–8 plot the **error rate**: the probability that the class
//!   containing the query's true match does *not* achieve the highest
//!   score.
//! * Figures 9–12 plot **recall@1**: the rate at which the true nearest
//!   neighbor is found within the candidates of the first `p` classes.

/// Streaming recall@1 accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Recall {
    hits: u64,
    total: u64,
}

impl Recall {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one query outcome.
    pub fn record(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        }
        self.total += 1;
    }

    /// Number of recorded queries.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// recall@1 in [0, 1].
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.hits as f64 / self.total as f64
        }
    }

    /// Error rate = 1 − recall.
    pub fn error_rate(&self) -> f64 {
        1.0 - self.value()
    }

    /// Standard error of the estimate (binomial).
    pub fn std_error(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = self.value();
        (p * (1.0 - p) / self.total as f64).sqrt()
    }

    /// Merge another accumulator.
    pub fn merge(&mut self, other: &Recall) {
        self.hits += other.hits;
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rates() {
        let mut r = Recall::new();
        for i in 0..10 {
            r.record(i < 7);
        }
        assert_eq!(r.value(), 0.7);
        assert!((r.error_rate() - 0.3).abs() < 1e-12);
        assert_eq!(r.total(), 10);
    }

    #[test]
    fn std_error_shrinks_with_n() {
        let mut small = Recall::new();
        let mut large = Recall::new();
        for i in 0..10 {
            small.record(i % 2 == 0);
        }
        for i in 0..1000 {
            large.record(i % 2 == 0);
        }
        assert!(large.std_error() < small.std_error());
    }

    #[test]
    fn merge_combines() {
        let mut a = Recall::new();
        a.record(true);
        let mut b = Recall::new();
        b.record(false);
        b.record(false);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert!((a.value() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let r = Recall::new();
        assert_eq!(r.value(), 0.0);
        assert_eq!(r.std_error(), 0.0);
    }
}
