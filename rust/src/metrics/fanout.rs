//! Cluster-tier counters: per-shard fan-out accounting (how many and
//! which shards the router contacts per query) and shard-pruning recall
//! (how often a pruned fan-out reproduces the full fan-out answer).

use std::collections::BTreeMap;

use crate::util::Json;

/// Fan-out accounting for a scatter-gather router: total and per-shard
/// contact counts, and how many requests went to every shard.
#[derive(Debug, Clone, Default)]
pub struct FanoutStats {
    /// Routed requests.
    pub requests: u64,
    /// Shard contacts summed over requests.
    pub contacts: u64,
    /// Requests that contacted every shard (exact fan-out).
    pub full_fanouts: u64,
    /// Contacts per shard (`per_shard[s]` = requests sent to shard `s`).
    pub per_shard: Vec<u64>,
}

impl FanoutStats {
    /// Empty counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one routed request that contacted `contacted` (shard
    /// indices) out of `n_shards` shards.
    pub fn record(&mut self, contacted: &[u32], n_shards: usize) {
        if self.per_shard.len() < n_shards {
            self.per_shard.resize(n_shards, 0);
        }
        self.requests += 1;
        self.contacts += contacted.len() as u64;
        if contacted.len() >= n_shards {
            self.full_fanouts += 1;
        }
        for &s in contacted {
            if let Some(c) = self.per_shard.get_mut(s as usize) {
                *c += 1;
            }
        }
    }

    /// Mean shards contacted per request (the pruning win: `< N` means
    /// network fan-out was saved).
    pub fn mean_fanout(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.contacts as f64 / self.requests as f64
        }
    }

    /// Merge another counter set.
    pub fn merge(&mut self, other: &FanoutStats) {
        if self.per_shard.len() < other.per_shard.len() {
            self.per_shard.resize(other.per_shard.len(), 0);
        }
        for (a, b) in self.per_shard.iter_mut().zip(&other.per_shard) {
            *a += b;
        }
        self.requests += other.requests;
        self.contacts += other.contacts;
        self.full_fanouts += other.full_fanouts;
    }

    /// JSON image (the `fanout` object of the router's STATS reply).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("requests".to_string(), Json::Num(self.requests as f64));
        o.insert("mean_fanout".to_string(), Json::Num(self.mean_fanout()));
        o.insert(
            "full_fanouts".to_string(),
            Json::Num(self.full_fanouts as f64),
        );
        o.insert(
            "per_shard".to_string(),
            Json::Arr(self.per_shard.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(o)
    }
}

/// Shard-pruning recall: fraction of queries whose pruned-fan-out
/// answer (top-1 id) agrees with the full-fan-out reference.  Driven by
/// the cluster bench/tests, where both answers are available.
#[derive(Debug, Clone, Copy, Default)]
pub struct PruneRecall {
    /// Queries where pruned == reference.
    pub agree: u64,
    /// Queries recorded.
    pub total: u64,
}

impl PruneRecall {
    /// Empty counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one comparison of best-candidate ids (`None` = no
    /// candidates).
    pub fn record(&mut self, pruned: Option<u32>, reference: Option<u32>) {
        self.total += 1;
        if pruned == reference {
            self.agree += 1;
        }
    }

    /// Agreement fraction in [0, 1] (0 when nothing was recorded).
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.agree as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanout_accounting() {
        let mut f = FanoutStats::new();
        f.record(&[0, 2], 3);
        f.record(&[1], 3);
        f.record(&[0, 1, 2], 3);
        assert_eq!(f.requests, 3);
        assert_eq!(f.contacts, 6);
        assert_eq!(f.full_fanouts, 1);
        assert_eq!(f.per_shard, vec![2, 2, 2]);
        assert!((f.mean_fanout() - 2.0).abs() < 1e-12);
        let mut g = FanoutStats::new();
        g.record(&[3], 4);
        g.merge(&f);
        assert_eq!(g.requests, 4);
        assert_eq!(g.per_shard, vec![2, 2, 2, 1]);
        let j = f.to_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("full_fanouts").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn empty_fanout_is_safe() {
        let f = FanoutStats::new();
        assert_eq!(f.mean_fanout(), 0.0);
        assert!(f.to_json().get("per_shard").is_some());
    }

    #[test]
    fn prune_recall_counts_agreement() {
        let mut r = PruneRecall::new();
        r.record(Some(3), Some(3));
        r.record(Some(4), Some(7));
        r.record(None, None);
        r.record(None, Some(1));
        assert_eq!(r.total, 4);
        assert_eq!(r.agree, 2);
        assert!((r.value() - 0.5).abs() < 1e-12);
        assert_eq!(PruneRecall::new().value(), 0.0);
    }
}
