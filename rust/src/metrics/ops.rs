//! The paper's complexity accounting (§5.2).
//!
//! "the computational complexity of an exhaustive search is dn (or cn for
//! sparse vectors).  On the other hand, the proposed method has a twofold
//! computational cost: first the cost of computing each score, which is
//! d²q (or c²q for sparse vectors), then the cost of exhaustively looking
//! for the nearest neighbor in the selected p classes, which is pkd (or
//! pkc for sparse vectors)."
//!
//! Counters are incremented by the index/baselines with *actual* work
//! done (classes may have unequal sizes under greedy allocation, sparse
//! queries have varying support), and relative complexity is reported
//! against the exhaustive reference.

/// Elementary-operation counter for one or more searches.
///
/// A quantized scan ([`crate::quant`]) splits the candidate stage into
/// two separately counted terms: `compressed_ops` (approximate
/// distances over codes — `d` per candidate for SQ8, `m` table lookups
/// for PQ) and `rerank_ops` (exact f32 distances over the surviving
/// `rerank` candidates).  The exact scan keeps using `scan_ops`, so the
/// three never mix and the compression win is visible per stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpsCounter {
    /// Operations spent scoring class memories (d²q / c²q term).
    pub score_ops: u64,
    /// Operations spent scanning candidates at full precision
    /// (pkd / pkc term).
    pub scan_ops: u64,
    /// Operations spent scanning candidates over the compressed
    /// representation (quantized scans only).
    pub compressed_ops: u64,
    /// Operations spent exactly re-scoring compressed-scan survivors
    /// (quantized scans only).
    pub rerank_ops: u64,
    /// Operations spent on auxiliary structures (e.g. RS anchor search).
    pub aux_ops: u64,
    /// Number of searches accumulated.
    pub searches: u64,
}

impl OpsCounter {
    /// Fresh counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total elementary operations.
    pub fn total(&self) -> u64 {
        self.score_ops + self.scan_ops + self.compressed_ops + self.rerank_ops + self.aux_ops
    }

    /// Mean operations per search.
    pub fn per_search(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.total() as f64 / self.searches as f64
        }
    }

    /// Relative complexity versus exhaustive search costing
    /// `reference_ops` per search (dn dense / cn sparse).
    pub fn relative_to(&self, reference_ops: u64) -> f64 {
        if reference_ops == 0 || self.searches == 0 {
            return 0.0;
        }
        self.per_search() / reference_ops as f64
    }

    /// Merge another counter (e.g. from a worker thread).
    pub fn merge(&mut self, other: &OpsCounter) {
        self.score_ops += other.score_ops;
        self.scan_ops += other.scan_ops;
        self.compressed_ops += other.compressed_ops;
        self.rerank_ops += other.rerank_ops;
        self.aux_ops += other.aux_ops;
        self.searches += other.searches;
    }
}

/// Per-batch accounting of the class-grouped candidate scan.
///
/// A batch of `B` queries polls `Σ_b p_b` classes in total, but the
/// class-major scan brings each *distinct* polled class's member matrix
/// into cache exactly once per batch.  `polls / class_passes` is the
/// batching fusion factor: how many per-query slab reads each physical
/// pass replaced (1.0 = no overlap between queries, up to `B` when every
/// query polls the same classes).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchScanStats {
    /// Class polls requested across all queries (`Σ_b p_b`).
    pub polls: u64,
    /// Distinct class member-matrix passes actually executed.
    pub class_passes: u64,
    /// Batches accumulated.
    pub batches: u64,
}

impl BatchScanStats {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mean per-query class polls a single physical class pass served.
    pub fn fusion_factor(&self) -> f64 {
        if self.class_passes == 0 {
            0.0
        } else {
            self.polls as f64 / self.class_passes as f64
        }
    }

    /// Merge another accumulator (e.g. from a worker thread).
    pub fn merge(&mut self, other: &BatchScanStats) {
        self.polls += other.polls;
        self.class_passes += other.class_passes;
        self.batches += other.batches;
    }
}

/// Closed-form cost model of the paper, used to cross-check the counters
/// and to plot the analytic trade-off curves.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Vector dimension `d` (use `c` for sparse data).
    pub effective_dim: u64,
    /// Number of classes `q`.
    pub q: u64,
    /// Class size `k`.
    pub k: u64,
    /// Database size `n`.
    pub n: u64,
}

impl CostModel {
    /// Scoring cost: `d²·q` (or `c²·q` sparse).
    pub fn score_cost(&self) -> u64 {
        self.effective_dim * self.effective_dim * self.q
    }

    /// Candidate-scan cost with `p` polled classes: `p·k·d` (`p·k·c`).
    pub fn scan_cost(&self, p: u64) -> u64 {
        p * self.k * self.effective_dim
    }

    /// Exhaustive reference: `n·d` (`n·c`).
    pub fn exhaustive_cost(&self) -> u64 {
        self.n * self.effective_dim
    }

    /// Relative complexity of the method at poll depth `p`.
    pub fn relative(&self, p: u64) -> f64 {
        (self.score_cost() + self.scan_cost(p)) as f64 / self.exhaustive_cost() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_means() {
        let mut c = OpsCounter::new();
        c.score_ops = 100;
        c.scan_ops = 50;
        c.searches = 2;
        assert_eq!(c.total(), 150);
        assert_eq!(c.per_search(), 75.0);
        assert_eq!(c.relative_to(150), 0.5);
    }

    #[test]
    fn merge_adds() {
        let mut a = OpsCounter {
            score_ops: 1,
            scan_ops: 2,
            compressed_ops: 4,
            rerank_ops: 5,
            aux_ops: 3,
            searches: 1,
        };
        let b = OpsCounter {
            score_ops: 10,
            scan_ops: 20,
            compressed_ops: 40,
            rerank_ops: 50,
            aux_ops: 30,
            searches: 2,
        };
        a.merge(&b);
        assert_eq!(
            a,
            OpsCounter {
                score_ops: 11,
                scan_ops: 22,
                compressed_ops: 44,
                rerank_ops: 55,
                aux_ops: 33,
                searches: 3,
            }
        );
    }

    #[test]
    fn compressed_and_rerank_ops_count_toward_total() {
        let c = OpsCounter {
            score_ops: 100,
            compressed_ops: 30,
            rerank_ops: 20,
            searches: 1,
            ..Default::default()
        };
        assert_eq!(c.total(), 150);
        assert_eq!(c.per_search(), 150.0);
    }

    #[test]
    fn cost_model_matches_paper_formulas() {
        // d=128, q=64, k=256, n=16384: score = d² q, scan = p k d, ref = n d
        let m = CostModel { effective_dim: 128, q: 64, k: 256, n: 16384 };
        assert_eq!(m.score_cost(), 128 * 128 * 64);
        assert_eq!(m.scan_cost(2), 2 * 256 * 128);
        assert_eq!(m.exhaustive_cost(), 16384 * 128);
        let rel = m.relative(1);
        let want = (128.0 * 128.0 * 64.0 + 256.0 * 128.0) / (16384.0 * 128.0);
        assert!((rel - want).abs() < 1e-12);
    }

    #[test]
    fn sparse_model_uses_c() {
        // c=8, q=10, k=512, n=5120: the sparse costs from §5.2
        let m = CostModel { effective_dim: 8, q: 10, k: 512, n: 5120 };
        assert_eq!(m.score_cost(), 8 * 8 * 10);
        assert_eq!(m.scan_cost(3), 3 * 512 * 8);
        assert_eq!(m.exhaustive_cost(), 5120 * 8);
    }

    #[test]
    fn zero_searches_safe() {
        let c = OpsCounter::new();
        assert_eq!(c.per_search(), 0.0);
        assert_eq!(c.relative_to(100), 0.0);
    }

    #[test]
    fn batch_scan_stats_fusion() {
        let mut s = BatchScanStats::new();
        assert_eq!(s.fusion_factor(), 0.0); // empty is safe
        // 8 queries x 4 polls each served by 10 distinct class passes
        s.merge(&BatchScanStats { polls: 32, class_passes: 10, batches: 1 });
        assert!((s.fusion_factor() - 3.2).abs() < 1e-12);
        s.merge(&BatchScanStats { polls: 8, class_passes: 8, batches: 1 });
        assert_eq!(s.polls, 40);
        assert_eq!(s.class_passes, 18);
        assert_eq!(s.batches, 2);
    }
}
