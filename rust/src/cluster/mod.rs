//! The sharded cluster tier: shard planning, AM-based shard routing,
//! and a single-binary cluster harness.
//!
//! The paper's core move — poll small associative memories to decide
//! where to search exhaustively — is applied one level up: the router
//! holds one **summed super-memory per shard** (sum rule ⇒ exactly
//! `Σ_classes W_i`), scores them per query (`d²·N`), and contacts only
//! the top-`s` shards over the existing [`net`](crate::net) wire
//! protocol, merging shard top-k responses with the same
//! [`TopK`](crate::search::TopK) rule every scan path uses.  `s = N`
//! reproduces single-node results bitwise (with per-shard full poll);
//! `s < N` prunes network fan-out like `p < q` prunes scan work.
//!
//! * [`plan`] — shard planner (contiguous / round-robin /
//!   balanced-by-members), per-shard sub-index construction, routing
//!   table, and the v3 shard manifest (`cluster.amplan`)
//! * [`router`] — the scatter-gather [`Serveable`](crate::net::Serveable)
//!   backend with pooled, reconnect-with-backoff shard links
//! * [`harness`] — N in-process shard servers + router over loopback
//!   TCP (`serve-cluster`), so tests and CI drive the real wire path

pub mod harness;
pub mod plan;
pub mod router;

pub use harness::{ClusterConfig, ClusterHarness};
pub use plan::{
    build_shard_index, load_cluster, routing_table, write_cluster, LoadedCluster,
    RoutingTable, ShardPlan, ShardStrategy,
};
pub use router::{ClusterIndexInfo, ClusterRouter, RouterConfig, RouterMetrics};
