//! The scatter-gather cluster router: a [`Serveable`] backend that
//! answers each query by polling the **shard super-memories** in its
//! [`RoutingTable`], contacting only the top-`s` shards over pooled
//! pipelined [`NetClient`] links, and merging the shard top-k responses
//! with the same [`TopK`] selection rule every other search path uses.
//!
//! This is the paper's mechanism applied at the cluster tier: the
//! routing table is small and resident (`[N, d, d]`), shards hold the
//! bulk data, and the `s < N` knob trades recall for network fan-out
//! exactly like `p < q` trades recall for scan work inside one node.
//! At `s = N` with per-shard full poll, routed results are
//! bitwise-identical to single-node search (the shard-local id order is
//! ascending-global, so `(distance, id)` tie-breaks agree after
//! remapping; pinned by `prop_router_full_fanout_matches_single_node`).
//!
//! Concurrency model: a bounded request queue feeds `workers` router
//! threads; each worker owns one [`NetClient`] per shard (the
//! connection pool is `workers × N` links), scatters a request to its
//! selected shards pipelined (submit all, then collect), and merges.
//! Links reconnect with bounded jittered backoff
//! ([`NetClient::connect_backoff`]) so shard restarts and transient
//! `ERR_OVERLOADED` refusals do not kill the router.
//!
//! Latency accounting keeps two **separate** named histograms:
//! `latency` is the router-observed end-to-end time and
//! `shard_service` the shard-reported scan service time.  They are
//! never merged into one histogram — re-recording shard-reported
//! samples into the router's own would double-count every request in
//! any aggregate view.
//!
//! Quality observability mirrors the coordinator's: always-on
//! selectivity counters (which contacted-shard rank produced the
//! merged winner, candidate→k survival), and — when
//! `quality_sample > 0` — a shadow worker with its **own** shard links
//! that re-executes every sampled query at full fan-out `s = N`,
//! merging exactly like [`serve_one`]'s gather, and folds the
//! comparison into an online recall estimate.  The router-tier
//! estimate isolates the *fan-out* knob: shards are polled with the
//! same per-request `top_p`, so per-shard poll loss is measured by
//! each shard's own estimator, not double-counted here.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::SearchResponse;
use crate::error::{Error, Result};
use crate::metrics::{FanoutStats, LatencyHistogram, WindowedHistogram};
use crate::net::wire::{self, WireResponse};
use crate::net::{NetClient, RetryPolicy, Serveable};
use crate::obs::{
    prom, sample_hit, QualityStats, RankHistogram, Registry, ShadowQueue,
    SurvivalStats, Trace, TraceSink,
};
use crate::search::{top_p_largest, Neighbor, TopK};
use crate::util::sync::lock_unpoisoned;
use crate::util::Json;

use super::plan::RoutingTable;

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Shards contacted per query (`0` = every shard, exact fan-out).
    pub fan_out: usize,
    /// Router worker threads (each owns one connection per shard).
    pub workers: usize,
    /// Bound of the request queue (backpressure, like the coordinator).
    pub queue_depth: usize,
    /// Reconnect/backoff policy for router→shard links.
    pub retry: RetryPolicy,
    /// Shadow-re-execute every `quality_sample`-th routed request at
    /// full fan-out on a dedicated worker and fold the comparison into
    /// the online recall estimate (`0` = quality sampling off).
    pub quality_sample: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            fan_out: 0,
            workers: 4,
            queue_depth: 1024,
            retry: RetryPolicy::default(),
            quality_sample: 0,
        }
    }
}

impl RouterConfig {
    /// Validate the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("router.workers must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("router.queue_depth must be > 0".into()));
        }
        Ok(())
    }
}

/// Router serving metrics.  `latency` (router end-to-end) and
/// `shard_service` (shard-reported) are deliberately separate named
/// histograms — see the module docs.
#[derive(Debug, Clone, Default)]
pub struct RouterMetrics {
    /// Requests routed (success or error response).
    pub requests: u64,
    /// Requests answered with an error response.
    pub errors: u64,
    /// Router-observed end-to-end latency (enqueue → response ready).
    pub latency: LatencyHistogram,
    /// Shard-reported per-request service time (one sample per shard
    /// contact, as carried in the shard's RESULT frame).
    pub shard_service: LatencyHistogram,
    /// Rolling-window view of `latency` (router end-to-end tail over
    /// the last ~10 s).
    pub window: WindowedHistogram,
    /// Rolling-window shard service time **per shard link** (indexed by
    /// shard), so one slow shard is visible instead of averaged away.
    /// Sized to the shard count at router start.
    pub shard_windows: Vec<WindowedHistogram>,
    /// Per-shard fan-out accounting.
    pub fanout: FanoutStats,
    /// Online recall estimate vs the full-fanout shadow re-execution
    /// (all-zero when `quality_sample` is 0).
    pub quality: QualityStats,
    /// Always-on: which contacted-shard rank (scored order) produced
    /// the merged winner.
    pub served_from: RankHistogram,
    /// Sampled: rank, in the router's *full* scored order, of the shard
    /// holding the true (full-fanout) winner — the fan-out
    /// effectiveness view.  A mass at ranks `>= s` means raising the
    /// fan-out would recover real winners.
    pub truth_from: RankHistogram,
    /// Always-on: shard candidates scanned → merged `k` survival.
    pub survival: SurvivalStats,
    /// Sampled, indexed by shard: how much of the full-fanout truth set
    /// lives on each shard and how much of it serving captured.  Sized
    /// to the shard count at router start.
    pub shard_quality: Vec<ShardQuality>,
}

/// One shard's share of the shadow (full-fanout) truth set and how much
/// of it the serving answer captured — "which shard's data are we
/// missing?" in one pair of counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardQuality {
    /// Exact top-k neighbors that live on this shard (over all shadow
    /// comparisons).
    pub truth: u64,
    /// Of those, how many the served answer actually returned.
    pub captured: u64,
}

impl ShardQuality {
    /// Fraction of this shard's truth neighbors that serving captured
    /// (`1.0` when the shard held none — no evidence of loss).
    pub fn capture_rate(&self) -> f64 {
        if self.truth == 0 {
            1.0
        } else {
            self.captured as f64 / self.truth as f64
        }
    }

    /// `{truth, captured, capture_rate}` for the STATS report.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("truth".to_string(), Json::Num(self.truth as f64));
        o.insert("captured".to_string(), Json::Num(self.captured as f64));
        o.insert(
            "capture_rate".to_string(),
            Json::Num(self.capture_rate()),
        );
        Json::Obj(o)
    }
}

/// Bound of the shadow hand-off queue: deep enough to ride out shard
/// latency spikes, small enough that a stalled shadow worker sheds
/// (oldest-first) instead of accumulating.
const SHADOW_QUEUE_DEPTH: usize = 256;

/// One sampled request handed to the shadow worker: the query, what
/// serving answered, and the knobs needed to re-execute it faithfully.
struct RouterShadowSample {
    vector: Vec<f32>,
    served: Vec<Neighbor>,
    top_p: usize,
    top_k: usize,
}

/// Shadow-sampling state: the admission counter deciding which requests
/// are sampled and the bounded drop-oldest queue feeding the
/// full-fanout shadow worker.
struct RouterShadow {
    every: u64,
    served: AtomicU64,
    queue: Arc<ShadowQueue<RouterShadowSample>>,
}

/// One queued router request.
struct RouterRequest {
    id: u64,
    vector: Vec<f32>,
    top_p: usize,
    top_k: usize,
    /// `0` = untraced; non-zero ids propagate to every contacted shard
    /// so shard spans stitch under the router's trace id.
    trace_id: u64,
    enqueued: Instant,
    resp: SyncSender<SearchResponse>,
}

/// What the router knows about the indices behind its shards: summed
/// scan-representation footprints and the (shared) quantization mode —
/// the cluster-level `index.*` / `quant.*` STATS fields.  Set by the
/// harness at launch, when the shard indices are in hand.
#[derive(Debug, Clone)]
pub struct ClusterIndexInfo {
    /// Footprints summed over every shard.
    pub footprint: crate::quant::IndexFootprint,
    /// Scan mode ("exact" | "sq8" | "pq", or "mixed" if shards differ).
    pub quant_mode: String,
    /// Rerank budget of the shard indices (0 = all).
    pub rerank: usize,
    /// Distance-kernel backend of the shard indices ("scalar" | "sse2"
    /// | "avx2" | "neon", or "mixed" if shards differ).
    pub kernel_backend: String,
}

impl ClusterIndexInfo {
    /// Aggregate over the shard indices of a cluster.
    pub fn from_indices<'a>(
        indices: impl IntoIterator<Item = &'a crate::index::AmIndex>,
    ) -> ClusterIndexInfo {
        let mut footprint = crate::quant::IndexFootprint::default();
        let mut mode: Option<&'static str> = None;
        let mut mixed = false;
        let mut rerank = 0usize;
        let mut kernel: Option<&'static str> = None;
        let mut kernel_mixed = false;
        for idx in indices {
            footprint.add(idx.footprint());
            match mode {
                None => mode = Some(idx.quant_mode()),
                Some(m) if m != idx.quant_mode() => mixed = true,
                Some(_) => {}
            }
            match kernel {
                None => kernel = Some(idx.kernel_backend()),
                Some(k) if k != idx.kernel_backend() => kernel_mixed = true,
                Some(_) => {}
            }
            rerank = rerank.max(idx.params().precision.rerank());
        }
        ClusterIndexInfo {
            footprint,
            quant_mode: if mixed {
                "mixed".to_string()
            } else {
                mode.unwrap_or("exact").to_string()
            },
            rerank,
            kernel_backend: if kernel_mixed {
                "mixed".to_string()
            } else {
                kernel.unwrap_or("scalar").to_string()
            },
        }
    }
}

/// State shared by the router handle and its workers.
struct RouterShared {
    table: RoutingTable,
    addrs: Vec<String>,
    fan_out: AtomicUsize,
    retry: RetryPolicy,
    metrics: Mutex<RouterMetrics>,
    index_info: Mutex<Option<ClusterIndexInfo>>,
    /// Trace sink; consulted at admission for sampling.  `None` =
    /// tracing disabled.
    trace: Option<Arc<TraceSink>>,
    /// Shadow quality sampling; `None` = quality sampling disabled.
    shadow: Option<RouterShadow>,
}

impl RouterShared {
    /// The single home of the fan-out rule: `0` = every shard,
    /// otherwise clamped to `N` (STATS and routing must never diverge).
    fn effective_fan_out(&self) -> usize {
        let raw = self.fan_out.load(Ordering::Relaxed);
        let n = self.table.n_shards();
        if raw == 0 {
            n
        } else {
            raw.min(n)
        }
    }
}

/// Handle to a running scatter-gather router.  Sits behind a
/// [`NetServer`](crate::net::NetServer) front door via [`Serveable`],
/// exactly like a single-node [`SearchServer`](crate::coordinator::SearchServer).
pub struct ClusterRouter {
    shared: Arc<RouterShared>,
    tx: Mutex<Option<SyncSender<RouterRequest>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// The full-fanout shadow worker (present iff `quality_sample > 0`).
    shadow_worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl ClusterRouter {
    /// Start the router: `cfg.workers` threads, each owning one lazily
    /// connected link per shard in `addrs` (shard order must match the
    /// routing table's).
    pub fn start(
        table: RoutingTable,
        addrs: Vec<String>,
        cfg: RouterConfig,
    ) -> Result<ClusterRouter> {
        Self::start_traced(table, addrs, cfg, None)
    }

    /// [`Self::start`] with an optional trace sink: sampled requests
    /// emit router-tier span records, and their trace ids propagate to
    /// every contacted shard so shard spans stitch under the same id.
    pub fn start_traced(
        table: RoutingTable,
        addrs: Vec<String>,
        cfg: RouterConfig,
        trace: Option<Arc<TraceSink>>,
    ) -> Result<ClusterRouter> {
        cfg.validate()?;
        if addrs.len() != table.n_shards() {
            return Err(Error::Config(format!(
                "{} shard addresses for a {}-shard routing table",
                addrs.len(),
                table.n_shards()
            )));
        }
        let metrics = RouterMetrics {
            shard_windows: vec![WindowedHistogram::new(); addrs.len()],
            shard_quality: vec![ShardQuality::default(); addrs.len()],
            ..RouterMetrics::default()
        };
        let shadow = (cfg.quality_sample > 0).then(|| RouterShadow {
            every: cfg.quality_sample,
            served: AtomicU64::new(0),
            queue: Arc::new(ShadowQueue::new(SHADOW_QUEUE_DEPTH)),
        });
        let shared = Arc::new(RouterShared {
            table,
            addrs,
            fan_out: AtomicUsize::new(cfg.fan_out),
            retry: cfg.retry,
            metrics: Mutex::new(metrics),
            index_info: Mutex::new(None),
            trace,
            shadow,
        });
        let (req_tx, req_rx) = mpsc::sync_channel::<RouterRequest>(cfg.queue_depth);
        let req_rx: Arc<Mutex<Receiver<RouterRequest>>> = Arc::new(Mutex::new(req_rx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for wi in 0..cfg.workers {
            let shared = shared.clone();
            let req_rx = req_rx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("amsearch-router-{wi}"))
                .spawn(move || {
                    let mut links: Vec<ShardLink> = shared
                        .addrs
                        .iter()
                        .map(|a| ShardLink::new(a.clone()))
                        .collect();
                    loop {
                        // take one request under the lock, release
                        // before the network round-trips
                        let req = {
                            let rx = lock_unpoisoned(&req_rx);
                            // amlint: allow(lock_blocking, reason = "the guard IS the hand-off: idle workers queue on this lock until a request arrives")
                            match rx.recv() {
                                Ok(r) => r,
                                Err(_) => return,
                            }
                        };
                        serve_one(&shared, &mut links, req);
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn router worker: {e}")))?;
            workers.push(handle);
        }
        // the shadow worker owns its own links so quality re-execution
        // never competes with serving for a pooled connection
        let shadow_worker = if shared.shadow.is_some() {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name("amsearch-router-shadow".to_string())
                .spawn(move || {
                    let mut links: Vec<ShardLink> = shared
                        .addrs
                        .iter()
                        .map(|a| ShardLink::new(a.clone()))
                        .collect();
                    let Some(shadow) = shared.shadow.as_ref() else { return };
                    while let Some(sample) = shadow.queue.pop() {
                        shadow_compare(&shared, &mut links, &sample);
                    }
                })
                .map_err(|e| {
                    Error::Coordinator(format!("spawn router shadow worker: {e}"))
                })?;
            Some(handle)
        } else {
            None
        };
        Ok(ClusterRouter {
            shared,
            tx: Mutex::new(Some(req_tx)),
            workers: Mutex::new(workers),
            shadow_worker: Mutex::new(shadow_worker),
            next_id: AtomicU64::new(0),
        })
    }

    /// Number of shards routed over.
    pub fn n_shards(&self) -> usize {
        self.shared.table.n_shards()
    }

    /// Dimension of the routed index.
    pub fn dim(&self) -> usize {
        self.shared.table.dim()
    }

    /// Total vectors across all shards.
    pub fn n_vectors(&self) -> usize {
        self.shared.table.n_vectors()
    }

    /// Effective fan-out `s`: shards contacted per query.
    pub fn fan_out(&self) -> usize {
        self.shared.effective_fan_out()
    }

    /// Change the fan-out at runtime (`0` = every shard).  Takes effect
    /// for subsequently routed requests — the bench sweeps this knob.
    pub fn set_fan_out(&self, s: usize) {
        self.shared.fan_out.store(s, Ordering::Relaxed);
    }

    /// Attach the shard-index summary (footprints + quant mode) so the
    /// router's STATS report the cluster's compression the same way a
    /// single node reports its own.
    pub fn set_index_info(&self, info: ClusterIndexInfo) {
        *lock_unpoisoned(&self.shared.index_info) = Some(info);
    }

    /// Submit a query and block until its merged response arrives (the
    /// in-process convenience mirror of `SearchServer::search`).
    pub fn search(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
    ) -> Result<SearchResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        Serveable::submit(self, vector, top_p, top_k, id, 0, resp_tx)?;
        let resp = resp_rx
            .recv()
            .map_err(|_| Error::Coordinator("router dropped request".into()))?;
        match resp.error {
            Some(msg) => Err(Error::Coordinator(msg)),
            None => Ok(resp),
        }
    }

    /// Replay one query through the routing tier with full
    /// introspection: shard scores, the fan-out decision and its
    /// margin, per-shard results, merged neighbors with shard
    /// attribution, and (with `exact`) the full-fanout ground-truth
    /// diff.  Runs synchronously on fresh shard links so the serving
    /// pool is never perturbed.
    pub fn explain(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        exact: bool,
    ) -> Result<Json> {
        let shared = &self.shared;
        if vector.len() != shared.table.dim() {
            return Err(Error::Shape(format!(
                "query dim {} != index dim {}",
                vector.len(),
                shared.table.dim()
            )));
        }
        let mut links: Vec<ShardLink> = shared
            .addrs
            .iter()
            .map(|a| ShardLink::new(a.clone()))
            .collect();
        let n = shared.table.n_shards();
        let s = shared.effective_fan_out();
        let scores = shared.table.score(&vector);
        let order = top_p_largest(&scores, n);
        // contact every shard once when the exact diff is requested:
        // the first `s` answers are the serving-fanout view, the rest
        // complete the ground-truth merge (at s = N the two coincide)
        let contact: &[u32] = if exact { &order } else { &order[..s] };
        let mut pending: Vec<(usize, u64)> = Vec::with_capacity(contact.len());
        for &si in contact {
            let id = links[si as usize]
                .submit(&vector, top_p, top_k, 0, &shared.retry)?;
            pending.push((si as usize, id));
        }
        let mut results: Vec<Option<WireResponse>> = vec![None; n];
        for (si, id) in pending {
            let r = links[si].wait(id, &vector, top_p, top_k, 0, &shared.retry)?;
            results[si] = Some(r);
        }
        let k_req = if top_k == 0 { shared.table.default_top_k() } else { top_k };
        let k = k_req.min(shared.table.n_vectors()).max(1);
        // same merge rule as serve_one's gather (TopK over remapped ids)
        let merge = |take: &[u32]| -> Vec<Neighbor> {
            let mut acc = TopK::new(k);
            for &si in take {
                if let Some(r) = &results[si as usize] {
                    for nb in &r.neighbors {
                        acc.push(
                            nb.distance,
                            shared.table.global_id(si as usize, nb.id),
                        );
                    }
                }
            }
            acc.into_neighbors()
        };
        let served = merge(&order[..s]);
        let shard_of = |gid: u32| -> Option<usize> {
            results.iter().enumerate().find_map(|(si, r)| {
                r.as_ref().and_then(|r| {
                    r.neighbors
                        .iter()
                        .any(|nb| shared.table.global_id(si, nb.id) == gid)
                        .then_some(si)
                })
            })
        };
        let mut o = BTreeMap::new();
        o.insert("backend".to_string(), Json::Str("router".to_string()));
        o.insert("shards".to_string(), Json::Num(n as f64));
        // the fan-out decision: every shard's score and rank, the
        // contacted cut, and the margin at the cut
        let mut fan = BTreeMap::new();
        fan.insert("s".to_string(), Json::Num(s as f64));
        if s > 0 && s < n {
            let margin = scores[order[s - 1] as usize] - scores[order[s] as usize];
            fan.insert("margin".to_string(), Json::Num(margin as f64));
        }
        fan.insert(
            "ranked".to_string(),
            Json::Arr(
                order
                    .iter()
                    .enumerate()
                    .map(|(rank, &si)| {
                        let mut e = BTreeMap::new();
                        e.insert("shard".to_string(), Json::Num(si as f64));
                        e.insert("rank".to_string(), Json::Num(rank as f64));
                        e.insert(
                            "score".to_string(),
                            Json::Num(scores[si as usize] as f64),
                        );
                        e.insert("contacted".to_string(), Json::Bool(rank < s));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        o.insert("fan_out".to_string(), Json::Obj(fan));
        // per-shard results for the serving fan-out
        let mut candidates: u64 = 0;
        let mut shard_results = Vec::new();
        for (rank, &si) in order[..s].iter().enumerate() {
            let Some(r) = &results[si as usize] else { continue };
            candidates += r.candidates;
            let mut e = BTreeMap::new();
            e.insert("shard".to_string(), Json::Num(si as f64));
            e.insert("rank".to_string(), Json::Num(rank as f64));
            e.insert(
                "returned".to_string(),
                Json::Num(r.neighbors.len() as f64),
            );
            e.insert("candidates".to_string(), Json::Num(r.candidates as f64));
            e.insert("ops".to_string(), Json::Num(r.ops as f64));
            e.insert("service_ns".to_string(), Json::Num(r.service_ns as f64));
            shard_results.push(Json::Obj(e));
        }
        o.insert("shard_results".to_string(), Json::Arr(shard_results));
        o.insert(
            "neighbors".to_string(),
            Json::Arr(
                served
                    .iter()
                    .map(|nb| {
                        let mut e = BTreeMap::new();
                        e.insert("id".to_string(), Json::Num(nb.id as f64));
                        e.insert(
                            "distance".to_string(),
                            Json::Num(nb.distance as f64),
                        );
                        match shard_of(nb.id) {
                            Some(si) => {
                                e.insert(
                                    "shard".to_string(),
                                    Json::Num(si as f64),
                                );
                                let rank = order
                                    .iter()
                                    .position(|&c| c as usize == si);
                                e.insert(
                                    "shard_rank".to_string(),
                                    rank.map_or(Json::Null, |r| {
                                        Json::Num(r as f64)
                                    }),
                                );
                            }
                            None => {
                                e.insert("shard".to_string(), Json::Null);
                                e.insert("shard_rank".to_string(), Json::Null);
                            }
                        }
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        let mut funnel = BTreeMap::new();
        funnel.insert("candidates".to_string(), Json::Num(candidates as f64));
        funnel.insert("survivors".to_string(), Json::Num(served.len() as f64));
        o.insert("funnel".to_string(), Json::Obj(funnel));
        if exact {
            let truth = merge(&order);
            let mut q = QualityStats::default();
            q.record_comparison(&served, &truth);
            let mut e = BTreeMap::new();
            e.insert(
                "neighbors".to_string(),
                Json::Arr(
                    truth
                        .iter()
                        .map(|nb| {
                            let mut t = BTreeMap::new();
                            t.insert("id".to_string(), Json::Num(nb.id as f64));
                            t.insert(
                                "distance".to_string(),
                                Json::Num(nb.distance as f64),
                            );
                            Json::Obj(t)
                        })
                        .collect(),
                ),
            );
            e.insert("recall".to_string(), Json::Num(q.recall()));
            e.insert(
                "matches_exactly".to_string(),
                Json::Bool(q.exact_matches == 1),
            );
            e.insert(
                "mean_rank_displacement".to_string(),
                Json::Num(q.mean_displacement()),
            );
            e.insert(
                "mean_distance_error".to_string(),
                Json::Num(q.mean_distance_error()),
            );
            o.insert("exact".to_string(), Json::Obj(e));
        }
        Ok(Json::Obj(o))
    }

    /// Snapshot the router metrics.  The shadow queue's drop counter is
    /// folded in here so the snapshot reflects sheds that happened
    /// since the last comparison was recorded.
    pub fn metrics(&self) -> RouterMetrics {
        let mut m = lock_unpoisoned(&self.shared.metrics).clone();
        if let Some(shadow) = &self.shared.shadow {
            m.quality.dropped = shadow.queue.dropped();
        }
        m
    }

    /// The routing table served by this router.
    pub fn table(&self) -> &RoutingTable {
        &self.shared.table
    }

    /// Graceful shutdown: stop accepting, drain queued requests (every
    /// accepted request still gets its response), join the workers,
    /// drain the shadow queue, and flush buffered trace records.
    pub fn shutdown(&self) {
        *lock_unpoisoned(&self.tx) = None;
        let mut workers = lock_unpoisoned(&self.workers);
        for w in workers.drain(..) {
            let _ = w.join();
        }
        drop(workers);
        // close after the serving workers stopped pushing: the shadow
        // worker drains what is queued, then exits
        if let Some(shadow) = &self.shared.shadow {
            shadow.queue.close();
        }
        if let Some(h) = lock_unpoisoned(&self.shadow_worker).take() {
            let _ = h.join();
        }
        // push the tail of buffered trace records to disk before the
        // process (or a test) inspects the trace file
        if let Some(trace) = &self.shared.trace {
            trace.flush();
        }
    }
}

impl Drop for ClusterRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Serveable for ClusterRouter {
    fn submit(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        id: u64,
        trace_id: u64,
        resp: SyncSender<SearchResponse>,
    ) -> Result<()> {
        if vector.len() != self.shared.table.dim() {
            return Err(Error::Shape(format!(
                "query dim {} != index dim {}",
                vector.len(),
                self.shared.table.dim()
            )));
        }
        let trace_id = match &self.shared.trace {
            Some(sink) if trace_id == 0 => sink.sample_id(),
            _ => trace_id,
        };
        let req = RouterRequest {
            id,
            vector,
            top_p,
            top_k,
            trace_id,
            enqueued: Instant::now(),
            resp,
        };
        let guard = lock_unpoisoned(&self.tx);
        let tx = guard
            .as_ref()
            .ok_or_else(|| Error::Coordinator("router shutting down".into()))?;
        // amlint: allow(lock_blocking, reason = "bounded-queue backpressure by design; holding the guard keeps shutdown from closing the channel mid-send")
        tx.send(req)
            .map_err(|_| Error::Coordinator("router shutting down".into()))
    }

    fn explain(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        exact: bool,
    ) -> Result<Json> {
        ClusterRouter::explain(self, vector, top_p, top_k, exact)
    }

    fn stats_json(&self) -> Json {
        let m = self.metrics();
        let mut o = BTreeMap::new();
        o.insert("role".to_string(), Json::Str("router".to_string()));
        o.insert("dim".to_string(), Json::Num(self.dim() as f64));
        o.insert("n_vectors".to_string(), Json::Num(self.n_vectors() as f64));
        o.insert("shards".to_string(), Json::Num(self.n_shards() as f64));
        o.insert("fan_out".to_string(), Json::Num(self.fan_out() as f64));
        o.insert("requests".to_string(), Json::Num(m.requests as f64));
        o.insert("errors".to_string(), Json::Num(m.errors as f64));
        // cluster-wide scan footprint + quant mode, same shape as the
        // single-node server's STATS (summed over shard indices)
        if let Some(info) = lock_unpoisoned(&self.shared.index_info).as_ref() {
            o.insert(
                "index".to_string(),
                crate::coordinator::footprint_json(&info.footprint),
            );
            o.insert(
                "quant".to_string(),
                crate::coordinator::quant_json(&info.quant_mode, info.rerank),
            );
            o.insert(
                "kernel".to_string(),
                crate::coordinator::kernel_json(&info.kernel_backend),
            );
        }
        // two *separate* named histograms — never merged (merging would
        // double-count each request: once as observed by the router,
        // once per shard-reported sample)
        o.insert("latency".to_string(), m.latency.to_json());
        o.insert("shard_service".to_string(), m.shard_service.to_json());
        o.insert("window".to_string(), m.window.to_json());
        o.insert(
            "shard_windows".to_string(),
            Json::Arr(m.shard_windows.iter().map(|w| w.to_json()).collect()),
        );
        o.insert("fanout".to_string(), m.fanout.to_json());
        // always-on selectivity: shard-rank of the merged winner +
        // candidate→k survival, same shape as the coordinator's
        o.insert(
            "selectivity".to_string(),
            crate::coordinator::selectivity_json(&m.served_from, &m.survival),
        );
        // present iff quality sampling is on, so scrapers can key off
        // the field deterministically
        if self.shared.shadow.is_some() {
            o.insert("quality".to_string(), m.quality.to_json());
            o.insert(
                "fanout_effectiveness".to_string(),
                m.truth_from.to_json(),
            );
            o.insert(
                "shard_quality".to_string(),
                Json::Arr(m.shard_quality.iter().map(|q| q.to_json()).collect()),
            );
        }
        Json::Obj(o)
    }

    /// Prometheus-style registry derived from the same single-lock
    /// [`Self::metrics`] snapshot as [`Serveable::stats_json`], so the
    /// two export surfaces can never disagree.
    fn metrics_registry(&self) -> Registry {
        let m = self.metrics();
        let mut reg = Registry::default();
        let role = [("role", "router")];
        reg.counter(prom::M_REQUESTS, &role, m.requests);
        reg.counter(prom::M_ERRORS, &role, m.errors);
        reg.histogram(prom::M_LATENCY, &role, &m.latency);
        reg.histogram(prom::M_SHARD_SERVICE, &role, &m.shard_service);
        reg.histogram(prom::M_WINDOW_LATENCY, &role, &m.window.windowed());
        for (si, w) in m.shard_windows.iter().enumerate() {
            let shard = si.to_string();
            reg.histogram(
                prom::M_SHARD_WINDOW,
                &[("role", "router"), ("shard", shard.as_str())],
                &w.windowed(),
            );
        }
        // selectivity gauges are always exported; the sampled quality
        // families appear iff quality sampling is on (same presence
        // rule as the STATS `quality` field)
        reg.gauge(
            prom::M_QUALITY_TOP1_FRACTION,
            &role,
            m.served_from.top1_fraction(),
        );
        reg.gauge(prom::M_QUALITY_SURVIVAL, &role, m.survival.ratio());
        if self.shared.shadow.is_some() {
            reg.counter(prom::M_QUALITY_SAMPLES, &role, m.quality.samples);
            reg.counter(prom::M_QUALITY_DROPPED, &role, m.quality.dropped);
            reg.gauge(prom::M_QUALITY_RECALL, &role, m.quality.recall());
            reg.gauge(
                prom::M_QUALITY_RANK_DISPLACEMENT,
                &role,
                m.quality.mean_displacement(),
            );
            reg.gauge(
                prom::M_QUALITY_DISTANCE_ERROR,
                &role,
                m.quality.mean_distance_error(),
            );
            for (si, q) in m.shard_quality.iter().enumerate() {
                let shard = si.to_string();
                reg.gauge(
                    prom::M_QUALITY_SHARD_CAPTURE,
                    &[("role", "router"), ("shard", shard.as_str())],
                    q.capture_rate(),
                );
            }
        }
        reg
    }
}

/// Route one request: score shards, scatter to the top-`s`, gather and
/// merge.  Exactly one response is delivered, success or error.
///
/// A traced request (non-zero `trace_id`, or a slow outlier crossing
/// the sink's threshold) emits one router-tier span record — `queue`,
/// `score`, `scatter`, `gather`, `respond` — and its id travels to
/// every contacted shard inside the SEARCH frame so the shard-tier
/// records stitch under the same trace.
fn serve_one(shared: &RouterShared, links: &mut [ShardLink], req: RouterRequest) {
    let started = Instant::now();
    let n_shards = links.len();
    let scores = shared.table.score(&req.vector);
    let contacted = top_p_largest(&scores, shared.effective_fan_out());
    let score_ns = started.elapsed().as_nanos() as u64;

    // scatter: submit to every selected shard before collecting any
    // response (the links pipeline, so shard scans overlap)
    let scatter_started = Instant::now();
    let mut pending: Vec<(usize, u64)> = Vec::with_capacity(contacted.len());
    let mut failure: Option<Error> = None;
    for &si in &contacted {
        match links[si as usize].submit(
            &req.vector,
            req.top_p,
            req.top_k,
            req.trace_id,
            &shared.retry,
        ) {
            Ok(id) => pending.push((si as usize, id)),
            Err(e) => {
                failure = Some(e);
                break;
            }
        }
    }
    let scatter_ns = scatter_started.elapsed().as_nanos() as u64;

    // the shards actually reached (scatter may have aborted early):
    // what the fan-out counters must reflect
    let submitted: Vec<u32> = pending.iter().map(|&(si, _)| si as u32).collect();

    // gather: collect every submitted response even after a failure so
    // the links stay in sync for the next request
    let k_req = if req.top_k == 0 {
        shared.table.default_top_k()
    } else {
        req.top_k
    };
    let k = k_req.min(shared.table.n_vectors()).max(1);
    let d = shared.table.dim();
    let mut acc = TopK::new(k);
    let mut polled: Vec<u32> = Vec::new();
    let mut candidates: u64 = 0;
    // routing cost: one bilinear poll per shard super-memory
    let mut ops: u64 = (d * d * n_shards) as u64;
    let gather_started = Instant::now();
    let mut shard_ns: Vec<(usize, u64)> = Vec::with_capacity(pending.len());
    // each reached shard's own best neighbor (shards return ascending
    // `(distance, id)`, so their first is their best), in contacted
    // order — resolves which fan-out rank produced the merged winner
    let mut shard_best: Vec<Option<Neighbor>> = Vec::with_capacity(pending.len());
    for (si, id) in pending {
        match links[si].wait(
            id,
            &req.vector,
            req.top_p,
            req.top_k,
            req.trace_id,
            &shared.retry,
        ) {
            Ok(r) => {
                shard_best.push(r.neighbors.first().map(|n| Neighbor {
                    id: shared.table.global_id(si, n.id),
                    distance: n.distance,
                }));
                for n in &r.neighbors {
                    acc.push(n.distance, shared.table.global_id(si, n.id));
                }
                for &c in &r.polled {
                    polled.push(shared.table.global_class(si, c));
                }
                candidates += r.candidates;
                ops += r.ops;
                shard_ns.push((si, r.service_ns));
            }
            Err(e) => {
                shard_best.push(None);
                if failure.is_none() {
                    failure = Some(e);
                }
            }
        }
    }
    let gather_ns = gather_started.elapsed().as_nanos() as u64;

    let resp = match failure {
        Some(e) => {
            SearchResponse::failed(req.id, format!("router: shard search failed: {e}"))
        }
        None => SearchResponse {
            id: req.id,
            neighbors: acc.into_neighbors(),
            polled,
            candidates: candidates as usize,
            ops,
            service_ns: started.elapsed().as_nanos() as u64,
            error: None,
        },
    };
    // shadow sampling: clone-only — the served response itself is
    // untouched, so sampled and unsampled serving stay bitwise-identical
    if resp.error.is_none() {
        if let Some(shadow) = &shared.shadow {
            let n = 1 + shadow.served.fetch_add(1, Ordering::Relaxed);
            if sample_hit(n, shadow.every) {
                shadow.queue.push(RouterShadowSample {
                    vector: req.vector.clone(),
                    served: resp.neighbors.clone(),
                    top_p: req.top_p,
                    top_k: req.top_k,
                });
            }
        }
    }
    // which contacted-shard rank produced the merged winner (None ⇒
    // unresolved: empty merge)
    let served_rank = resp.neighbors.first().and_then(|w| {
        shard_best
            .iter()
            .position(|b| matches!(b, Some(n) if n.id == w.id))
    });
    // metrics BEFORE completing the request, same discipline as the
    // coordinator: a client must never observe its response while its
    // own request is uncounted
    {
        let mut m = lock_unpoisoned(&shared.metrics);
        m.requests += 1;
        if resp.error.is_some() {
            m.errors += 1;
        }
        let lat_ns = req.enqueued.elapsed().as_nanos() as u64;
        m.latency.record_ns(lat_ns);
        m.window.record_ns(lat_ns);
        for &(si, ns) in &shard_ns {
            m.shard_service.record_ns(ns);
            if let Some(w) = m.shard_windows.get_mut(si) {
                w.record_ns(ns);
            }
        }
        m.fanout.record(&submitted, n_shards);
        if resp.error.is_none() {
            m.served_from.record(served_rank);
            m.survival.record(resp.candidates, resp.neighbors.len());
        }
    }
    let Some(sink) = shared.trace.as_deref() else {
        let _ = req.resp.send(resp); // receiver may have timed out
        return;
    };
    // slow outliers are force-sampled even when the sampler skipped
    // them at admission (router-tier record only: the shards were
    // contacted with trace id 0 and emitted nothing)
    let tid = if req.trace_id != 0 {
        req.trace_id
    } else if sink.slow_ns() > 0
        && req.enqueued.elapsed().as_nanos() as u64 >= sink.slow_ns()
    {
        sink.force_id()
    } else {
        0
    };
    if tid == 0 {
        let _ = req.resp.send(resp);
        return;
    }
    let mut t = Trace::start(tid, "router", req.id);
    t.span_ns("queue", started.duration_since(req.enqueued).as_nanos() as u64);
    t.span_ns("score", score_ns);
    t.span_ns("scatter", scatter_ns);
    t.span_ns("gather", gather_ns);
    let send_started = Instant::now();
    let _ = req.resp.send(resp);
    t.span_ns("respond", send_started.elapsed().as_nanos() as u64);
    let rec = t.finish_with_total(req.enqueued.elapsed().as_nanos() as u64);
    sink.emit(&rec);
}

/// Shadow-compare one sampled request: re-execute at full fan-out over
/// the shadow worker's own links, then fold the served-vs-exact
/// comparison, the fan-out-effectiveness rank, and the per-shard truth
/// attribution into the metrics under the usual single lock.  A failed
/// re-execution (unreachable shard) is skipped, never charged to the
/// estimate.
fn shadow_compare(
    shared: &RouterShared,
    links: &mut [ShardLink],
    sample: &RouterShadowSample,
) {
    let Some((exact, returned)) = shadow_full_fanout(shared, links, sample) else {
        return;
    };
    // the shard each exact neighbor lives on (global ids are unique, so
    // membership in one shard's returned list resolves it)
    let shard_of = |id: u32| returned.iter().position(|ids| ids.contains(&id));
    // rank, in the router's full scored order, of the shard holding the
    // true winner — fan-out effectiveness ("would a bigger s help?")
    let truth_rank = exact.first().and_then(|w| shard_of(w.id)).map(|si| {
        let scores = shared.table.score(&sample.vector);
        let order = top_p_largest(&scores, shared.table.n_shards());
        order
            .iter()
            .position(|&c| c as usize == si)
            .unwrap_or(order.len())
    });
    let mut per_shard: Vec<ShardQuality> =
        vec![ShardQuality::default(); returned.len()];
    for n in &exact {
        let Some(si) = shard_of(n.id) else { continue };
        per_shard[si].truth += 1;
        if sample.served.iter().any(|s| s.id == n.id) {
            per_shard[si].captured += 1;
        }
    }
    let mut m = lock_unpoisoned(&shared.metrics);
    m.quality.record_comparison(&sample.served, &exact);
    m.truth_from.record(truth_rank);
    for (si, q) in per_shard.iter().enumerate() {
        if let Some(slot) = m.shard_quality.get_mut(si) {
            slot.truth += q.truth;
            slot.captured += q.captured;
        }
    }
}

/// Re-execute one sampled query at full fan-out (`s = N`) and merge
/// exactly like [`serve_one`]'s gather — same per-shard `top_p`, same
/// `k` clamp, same `TopK` tie-break — so at serving fan-out `s = N`
/// the shadow answer is identical to the served one by construction.
/// Returns the merged exact top-k plus each shard's returned global
/// ids, or `None` when any shard contact failed.
fn shadow_full_fanout(
    shared: &RouterShared,
    links: &mut [ShardLink],
    sample: &RouterShadowSample,
) -> Option<(Vec<Neighbor>, Vec<Vec<u32>>)> {
    let mut pending: Vec<(usize, u64)> = Vec::with_capacity(links.len());
    let mut failed = false;
    for si in 0..links.len() {
        match links[si].submit(
            &sample.vector,
            sample.top_p,
            sample.top_k,
            0,
            &shared.retry,
        ) {
            Ok(id) => pending.push((si, id)),
            Err(_) => {
                failed = true;
                break;
            }
        }
    }
    let k_req = if sample.top_k == 0 {
        shared.table.default_top_k()
    } else {
        sample.top_k
    };
    let k = k_req.min(shared.table.n_vectors()).max(1);
    let mut acc = TopK::new(k);
    let mut returned: Vec<Vec<u32>> = vec![Vec::new(); links.len()];
    // collect every submitted response even after a failure so the
    // links stay in sync for the next sample
    for (si, id) in pending {
        match links[si].wait(
            id,
            &sample.vector,
            sample.top_p,
            sample.top_k,
            0,
            &shared.retry,
        ) {
            Ok(r) => {
                for n in &r.neighbors {
                    let gid = shared.table.global_id(si, n.id);
                    acc.push(n.distance, gid);
                    returned[si].push(gid);
                }
            }
            Err(_) => failed = true,
        }
    }
    if failed {
        return None;
    }
    Some((acc.into_neighbors(), returned))
}

/// One router→shard connection with reconnect-on-failure semantics.
struct ShardLink {
    addr: String,
    client: Option<NetClient>,
}

impl ShardLink {
    fn new(addr: String) -> Self {
        ShardLink { addr, client: None }
    }

    /// The live client, (re)connecting with backoff when absent.
    fn ensure(&mut self, retry: &RetryPolicy) -> Result<&mut NetClient> {
        if self.client.is_none() {
            let c = NetClient::connect_backoff(&self.addr, retry)?;
            c.set_timeout(Some(Duration::from_secs(60)))?;
            self.client = Some(c);
        }
        self.client
            .as_mut()
            .ok_or_else(|| Error::Coordinator("shard link: connect failed".into()))
    }

    /// Submit a search, reconnecting once if the link died since the
    /// last request (a restarted shard surfaces as a send failure).
    /// `trace_id` rides the SEARCH frame (0 = untraced, wire v1).
    fn submit(
        &mut self,
        vector: &[f32],
        top_p: usize,
        top_k: usize,
        trace_id: u64,
        retry: &RetryPolicy,
    ) -> Result<u64> {
        let first = self
            .ensure(retry)?
            .submit_traced(vector, top_p, top_k, trace_id);
        match first {
            Ok(id) => Ok(id),
            Err(_) => {
                self.client = None;
                self.ensure(retry)?.submit_traced(vector, top_p, top_k, trace_id)
            }
        }
    }

    /// Wait for `id`.  A dead connection or a typed refusal
    /// (`ERR_OVERLOADED` / `ERR_SHUTTING_DOWN`) tears the link down,
    /// reconnects with backoff, and resubmits the query once; any other
    /// shard error is returned as-is.
    fn wait(
        &mut self,
        id: u64,
        vector: &[f32],
        top_p: usize,
        top_k: usize,
        trace_id: u64,
        retry: &RetryPolicy,
    ) -> Result<WireResponse> {
        let client = self
            .client
            .as_mut()
            .ok_or_else(|| Error::Coordinator("router: link lost before response".into()))?;
        match client.wait_detailed(id) {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(we))
                if we.code == wire::ERR_OVERLOADED
                    || we.code == wire::ERR_SHUTTING_DOWN =>
            {
                self.resubmit(vector, top_p, top_k, trace_id, retry)
            }
            Ok(Err(we)) => Err(Error::Coordinator(format!(
                "shard error (code {}): {}",
                we.code, we.message
            ))),
            Err(_) => self.resubmit(vector, top_p, top_k, trace_id, retry),
        }
    }

    fn resubmit(
        &mut self,
        vector: &[f32],
        top_p: usize,
        top_k: usize,
        trace_id: u64,
        retry: &RetryPolicy,
    ) -> Result<WireResponse> {
        self.client = None;
        let client = self.ensure(retry)?;
        let id = client.submit_traced(vector, top_p, top_k, trace_id)?;
        client.wait(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::plan::{routing_table, ShardPlan, ShardStrategy};
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::index::{AmIndex, IndexParams};

    fn small_table() -> RoutingTable {
        let mut rng = Rng::new(11);
        let wl = synthetic::dense_workload(16, 64, 4, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 4, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let plan =
            ShardPlan::for_index(&index, 2, ShardStrategy::Contiguous).unwrap();
        routing_table(&index, &plan).unwrap()
    }

    #[test]
    fn config_validation() {
        RouterConfig::default().validate().unwrap();
        assert!(RouterConfig { workers: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(RouterConfig { queue_depth: 0, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn addr_count_must_match_table() {
        let table = small_table();
        let err = ClusterRouter::start(
            table,
            vec!["127.0.0.1:1".into()], // 1 addr for 2 shards
            RouterConfig::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn unreachable_shards_yield_error_responses_not_hangs() {
        // port 1 on loopback: connection refused — the request must
        // resolve with an explicit error after bounded backoff
        let table = small_table();
        let retry = RetryPolicy {
            max_attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            ..Default::default()
        };
        let cfg = RouterConfig { workers: 1, retry, ..Default::default() };
        let router = ClusterRouter::start(
            table,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            cfg,
        )
        .unwrap();
        let err = router.search(vec![0.0; 16], 1, 1).unwrap_err();
        assert!(err.to_string().contains("shard"), "{err}");
        let m = router.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.errors, 1);
        assert_eq!(m.latency.count(), 1);
        assert_eq!(m.shard_service.count(), 0, "no shard ever answered");
        // dim validation happens at submit time
        let err = router.search(vec![0.0; 5], 1, 1).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        router.shutdown();
    }

    #[test]
    fn fan_out_knob_resolves_and_clamps() {
        let table = small_table();
        let router = ClusterRouter::start(
            table,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            RouterConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(router.fan_out(), 2, "0 = every shard");
        router.set_fan_out(1);
        assert_eq!(router.fan_out(), 1);
        router.set_fan_out(99);
        assert_eq!(router.fan_out(), 2, "clamped to N");
        let stats = Serveable::stats_json(&router);
        assert_eq!(stats.get("role").unwrap().as_str(), Some("router"));
        assert_eq!(stats.get("shards").unwrap().as_usize(), Some(2));
        assert!(stats.get("latency").is_some());
        assert!(stats.get("shard_service").is_some());
        assert!(stats.get("window").is_some());
        let windows = stats.get("shard_windows").unwrap();
        assert!(
            matches!(windows, Json::Arr(a) if a.len() == 2),
            "one rolling window per shard link"
        );
        // always-on selectivity; sampled quality absent while the knob
        // is off
        let sel = stats.get("selectivity").unwrap();
        assert!(sel.get("served_from").is_some());
        assert!(sel.get("survival").is_some());
        assert!(stats.get("quality").is_none(), "sampling off ⇒ no estimate");
        assert!(stats.get("shard_quality").is_none());
        // the exposition surface derives from the same snapshot and
        // must always validate with every required family present
        let text = Serveable::metrics_registry(&router).render();
        crate::obs::prom::validate(&text, &crate::obs::REQUIRED_FAMILIES).unwrap();
        assert!(text.contains("amsearch_requests_total{role=\"router\"}"));
        assert!(text.contains("shard=\"1\""), "per-shard windowed family");
        assert!(text.contains("amsearch_quality_top1_fraction{role=\"router\"}"));
        assert!(text.contains("amsearch_quality_survival_ratio{role=\"router\"}"));
        assert!(
            !text.contains("amsearch_quality_recall"),
            "sampled families gated on the quality knob"
        );
        router.shutdown();
    }

    #[test]
    fn quality_knob_exposes_estimate_surfaces() {
        let table = small_table();
        let router = ClusterRouter::start(
            table,
            vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            RouterConfig { workers: 1, quality_sample: 2, ..Default::default() },
        )
        .unwrap();
        let stats = Serveable::stats_json(&router);
        let q = stats.get("quality").unwrap();
        assert_eq!(q.get("samples").unwrap().as_u64(), Some(0));
        assert_eq!(q.get("recall").unwrap().as_f64(), Some(1.0));
        let sq = stats.get("shard_quality").unwrap();
        assert!(
            matches!(sq, Json::Arr(a) if a.len() == 2),
            "one capture entry per shard"
        );
        assert!(stats.get("fanout_effectiveness").is_some());
        let text = Serveable::metrics_registry(&router).render();
        crate::obs::prom::validate(&text, &crate::obs::REQUIRED_FAMILIES).unwrap();
        assert!(text.contains("amsearch_quality_samples_total{role=\"router\"}"));
        assert!(text.contains("amsearch_quality_recall{role=\"router\"}"));
        assert!(text.contains(
            "amsearch_quality_shard_capture_rate{role=\"router\",shard=\"0\"}"
        ));
        // shutdown with an idle shadow worker must not hang
        router.shutdown();
    }

    #[test]
    fn shard_quality_capture_rate() {
        let mut q = ShardQuality::default();
        assert_eq!(q.capture_rate(), 1.0, "no truth ⇒ no evidence of loss");
        q.truth = 4;
        q.captured = 3;
        assert_eq!(q.capture_rate(), 0.75);
        let j = q.to_json();
        assert_eq!(j.get("truth").unwrap().as_u64(), Some(4));
        assert_eq!(j.get("captured").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("capture_rate").unwrap().as_f64(), Some(0.75));
    }
}
