//! The shard planner: partition a trained [`AmIndex`]'s classes across
//! N shards and derive everything the cluster tier needs to run them —
//! per-shard sub-indices (ordinary index files written via
//! [`crate::index::persist::save`]), and a [`RoutingTable`] holding each
//! shard's **summed super-memory**.
//!
//! The routing table is the paper's trick applied one level up: the sum
//! rule is additive, so a shard's super-memory is exactly
//! `Σ_{classes in shard} W_i`, and the bilinear score
//! `x⁰ᵀ W_shard x⁰ = Σ_classes s(X^i, x⁰)` ranks shards by how much
//! stored signal they hold for a query — the same way
//! [`HierarchicalIndex`](crate::index::HierarchicalIndex) ranks
//! super-classes, but across the network boundary.  The router keeps
//! only this small `[N, d, d]` structure resident; shards hold the bulk
//! data.
//!
//! Shard manifest format v3 (`cluster.amplan`, all integers
//! little-endian, FNV-1a checksummed like the index format):
//!
//! ```text
//! magic    8B   "AMSHPLAN"
//! version  u32  (3)
//! dim      u32
//! metric   u8   0 = sq_l2, 1 = neg_dot, 2 = hamming
//! strategy u8   0 = contiguous, 1 = round_robin, 2 = balanced
//! top_k    u32  default neighbors per query
//! n_total  u64  vectors across all shards
//! n_shards u32
//! per shard:
//!   file       u32 len + utf-8 bytes (shard index artifact)
//!   n_classes  u32, then that many u32 global class ids (ascending)
//!   n_vectors  u64, then that many u32 global vector ids (ascending)
//!   count      u64  patterns summed into the shard super-memory
//! routing  n_shards * dim * dim * f32 (summed super-memories)
//! checksum u64  FNV-1a of everything before it
//! ```

use std::io::{BufReader, BufWriter};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::index::persist::{CountingReader, CountingWriter, SHARD_MANIFEST_VERSION};
use crate::index::{AmIndex, IndexParams};
use crate::memory::{MemoryBank, StorageRule};
use crate::search::Metric;

/// File name of the shard manifest inside a plan directory.
pub const MANIFEST_FILE: &str = "cluster.amplan";

const MANIFEST_MAGIC: &[u8; 8] = b"AMSHPLAN";

/// How classes are distributed across shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Contiguous runs of classes (near-equal class counts per shard).
    Contiguous,
    /// Class `c` goes to shard `c % N`.
    RoundRobin,
    /// Longest-processing-time greedy on class member counts: classes
    /// sorted by size descending, each assigned to the currently
    /// smallest shard — near-equal *vector* counts even when class
    /// sizes are skewed (greedy allocation, online inserts).
    BalancedMembers,
}

impl std::str::FromStr for ShardStrategy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "contiguous" => Ok(ShardStrategy::Contiguous),
            "round_robin" => Ok(ShardStrategy::RoundRobin),
            "balanced" => Ok(ShardStrategy::BalancedMembers),
            other => Err(Error::Config(format!(
                "unknown shard strategy '{other}' \
                 (expected contiguous | round_robin | balanced)"
            ))),
        }
    }
}

impl std::fmt::Display for ShardStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShardStrategy::Contiguous => "contiguous",
            ShardStrategy::RoundRobin => "round_robin",
            ShardStrategy::BalancedMembers => "balanced",
        })
    }
}

impl ShardStrategy {
    fn to_byte(self) -> u8 {
        match self {
            ShardStrategy::Contiguous => 0,
            ShardStrategy::RoundRobin => 1,
            ShardStrategy::BalancedMembers => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Self> {
        match b {
            0 => Ok(ShardStrategy::Contiguous),
            1 => Ok(ShardStrategy::RoundRobin),
            2 => Ok(ShardStrategy::BalancedMembers),
            x => Err(Error::Data(format!("bad shard strategy byte {x}"))),
        }
    }
}

/// An assignment of `q` classes to `n_shards` shards.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of shards `N`.
    pub n_shards: usize,
    /// Strategy that produced the plan.
    pub strategy: ShardStrategy,
    /// `shard_of[class] = shard index`.
    pub shard_of: Vec<u32>,
    /// Global class ids per shard, ascending.
    pub classes_of: Vec<Vec<u32>>,
}

impl ShardPlan {
    /// Plan a partition of `class_sizes.len()` classes (with the given
    /// member counts) across `n_shards` shards.  Every shard receives at
    /// least one class (requires `1 <= n_shards <= q`).
    pub fn new(
        class_sizes: &[usize],
        n_shards: usize,
        strategy: ShardStrategy,
    ) -> Result<ShardPlan> {
        let q = class_sizes.len();
        if n_shards == 0 || n_shards > q {
            return Err(Error::Config(format!(
                "need 1 <= n_shards={n_shards} <= q={q}"
            )));
        }
        let mut shard_of = vec![0u32; q];
        match strategy {
            ShardStrategy::Contiguous => {
                // N contiguous chunks of size floor(q/N), the first
                // q % N chunks one larger — never an empty shard
                let base = q / n_shards;
                let extra = q % n_shards;
                let mut c = 0usize;
                for s in 0..n_shards {
                    let len = base + usize::from(s < extra);
                    for _ in 0..len {
                        shard_of[c] = s as u32;
                        c += 1;
                    }
                }
            }
            ShardStrategy::RoundRobin => {
                for (c, slot) in shard_of.iter_mut().enumerate() {
                    *slot = (c % n_shards) as u32;
                }
            }
            ShardStrategy::BalancedMembers => {
                let mut order: Vec<usize> = (0..q).collect();
                // largest classes first; ties by smaller class id
                order.sort_by_key(|&c| (std::cmp::Reverse(class_sizes[c]), c));
                let mut load = vec![0usize; n_shards];
                for (i, &c) in order.iter().enumerate() {
                    // the first N classes seed one per shard so no shard
                    // is left empty even with zero-sized classes
                    let s = if i < n_shards {
                        i
                    } else {
                        (0..n_shards)
                            .min_by_key(|&s| (load[s], s))
                            .unwrap_or(0)
                    };
                    shard_of[c] = s as u32;
                    load[s] += class_sizes[c];
                }
            }
        }
        let mut classes_of = vec![Vec::new(); n_shards];
        for (c, &s) in shard_of.iter().enumerate() {
            classes_of[s as usize].push(c as u32);
        }
        // class ids were visited ascending, so each list is ascending —
        // the invariant the id-remap monotonicity proof rests on
        Ok(ShardPlan { n_shards, strategy, shard_of, classes_of })
    }

    /// Convenience: plan over a built index's class sizes.
    pub fn for_index(
        index: &AmIndex,
        n_shards: usize,
        strategy: ShardStrategy,
    ) -> Result<ShardPlan> {
        ShardPlan::new(&index.partition().sizes(), n_shards, strategy)
    }

    /// Global vector ids belonging to shard `si`, ascending.  Ascending
    /// order is load-bearing: shard-local ids are assigned in this
    /// order, so the local `(distance, id)` tie-break of a shard's
    /// top-k agrees with the global one after remapping — the property
    /// that makes full fan-out bitwise-identical to single-node search
    /// even through distance ties.
    pub fn shard_vector_ids(&self, index: &AmIndex, si: usize) -> Vec<u32> {
        let mut ids: Vec<u32> = self.classes_of[si]
            .iter()
            .flat_map(|&c| index.partition().members(c as usize).iter().copied())
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Vector counts per shard (balance diagnostic).
    pub fn shard_sizes(&self, class_sizes: &[usize]) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_shards];
        for (c, &s) in self.shard_of.iter().enumerate() {
            sizes[s as usize] += class_sizes[c];
        }
        sizes
    }
}

/// Build shard `si`'s standalone sub-index: the shard's classes (with
/// their original memories, weights bit-identical) over the shard's
/// vectors, with local ids assigned in ascending-global-id order.
/// Returns the sub-index plus the local→global id map.
pub fn build_shard_index(
    index: &AmIndex,
    plan: &ShardPlan,
    si: usize,
) -> Result<(AmIndex, Vec<u32>)> {
    let classes = &plan.classes_of[si];
    if classes.is_empty() {
        return Err(Error::Config(format!("shard {si} has no classes")));
    }
    let shard_ids = plan.shard_vector_ids(index, si);
    if shard_ids.len() < classes.len() {
        return Err(Error::Config(format!(
            "shard {si}: {} vectors cannot cover {} classes \
             (lower --shards or rebalance)",
            shard_ids.len(),
            classes.len()
        )));
    }
    let mut assignments: Vec<u32> = Vec::with_capacity(shard_ids.len());
    for &gid in &shard_ids {
        let gc = index.partition().class_of(gid as usize);
        let local = classes.binary_search(&gc).map_err(|_| {
            Error::Data(format!(
                "shard {si}: vector {gid} belongs to class {gc}, which is not                  assigned to this shard (corrupt plan?)"
            ))
        })?;
        assignments.push(local as u32);
    }
    let d = index.dim();
    let mut stacked = Vec::with_capacity(classes.len() * d * d);
    let mut counts = Vec::with_capacity(classes.len());
    for &c in classes {
        stacked.extend_from_slice(index.bank().class_weights(c as usize));
        counts.push(index.bank().count(c as usize));
    }
    let data = index.data().gather(&shard_ids);
    let p = index.params();
    let params = IndexParams {
        n_classes: classes.len(),
        top_p: p.top_p.min(classes.len()).max(1),
        top_k: p.top_k,
        rule: p.rule,
        allocation: p.allocation,
        metric: p.metric,
        greedy_cap_factor: p.greedy_cap_factor,
        // the quantization config travels into every shard artifact:
        // each shard trains its own codebooks over its own vectors
        // (deterministically), so routed serving scans compressed shards
        precision: p.precision,
    };
    let shard = AmIndex::from_parts(params, assignments, stacked, counts, data)?;
    Ok((shard, shard_ids))
}

/// The router's resident structure: one summed super-memory per shard
/// plus the id/class maps needed to translate shard-local responses
/// back into the global namespace.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    /// `[N, d, d]` stacked shard super-memories (sum rule).
    bank: MemoryBank,
    metric: Metric,
    default_top_k: usize,
    n_vectors: usize,
    /// `id_maps[s][local] = global` vector id (ascending per shard).
    id_maps: Vec<Vec<u32>>,
    /// `class_maps[s][local] = global` class id (ascending per shard).
    class_maps: Vec<Vec<u32>>,
}

impl RoutingTable {
    /// Number of shards `N`.
    pub fn n_shards(&self) -> usize {
        self.bank.n_classes()
    }

    /// Vector dimensionality `d`.
    pub fn dim(&self) -> usize {
        self.bank.dim()
    }

    /// Total vectors across all shards.
    pub fn n_vectors(&self) -> usize {
        self.n_vectors
    }

    /// The index's default `k` (used to size the router's merge
    /// accumulator when a request passes `top_k = 0`).
    pub fn default_top_k(&self) -> usize {
        self.default_top_k
    }

    /// Distance metric of the sharded index.
    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// The stacked super-memory bank (for inspection/tests).
    pub fn bank(&self) -> &MemoryBank {
        &self.bank
    }

    /// Score every shard's super-memory against `x` — the shard-tier
    /// analog of polling the class memories (`d²·N` operations).
    pub fn score(&self, x: &[f32]) -> Vec<f32> {
        self.bank.score_query(x)
    }

    /// Translate a shard-local vector id to its global id.
    pub fn global_id(&self, shard: usize, local: u32) -> u32 {
        self.id_maps[shard][local as usize]
    }

    /// Translate a shard-local class id to its global id.
    pub fn global_class(&self, shard: usize, local: u32) -> u32 {
        self.class_maps[shard][local as usize]
    }

    /// Vectors held by shard `si`.
    pub fn shard_len(&self, si: usize) -> usize {
        self.id_maps[si].len()
    }
}

/// Derive the routing table for a plan over a built index.  Requires
/// the sum rule: the shard super-memory is `Σ_classes W_i`, which is
/// only a faithful super-memory when storage is additive (same
/// restriction as [`HierarchicalIndex`](crate::index::HierarchicalIndex)).
pub fn routing_table(index: &AmIndex, plan: &ShardPlan) -> Result<RoutingTable> {
    if index.params().rule != StorageRule::Sum {
        return Err(Error::Config(
            "shard routing requires the sum rule (super-memories must be additive)"
                .into(),
        ));
    }
    let d = index.dim();
    let sz = d * d;
    let mut weights = vec![0f32; plan.n_shards * sz];
    let mut counts = vec![0usize; plan.n_shards];
    for (c, &s) in plan.shard_of.iter().enumerate() {
        let dst = &mut weights[s as usize * sz..(s as usize + 1) * sz];
        for (a, b) in dst.iter_mut().zip(index.bank().class_weights(c)) {
            *a += b;
        }
        counts[s as usize] += index.bank().count(c);
    }
    let bank = MemoryBank::from_parts(d, weights, counts, StorageRule::Sum)?;
    let id_maps: Vec<Vec<u32>> = (0..plan.n_shards)
        .map(|si| plan.shard_vector_ids(index, si))
        .collect();
    Ok(RoutingTable {
        bank,
        metric: index.params().metric,
        default_top_k: index.params().top_k,
        n_vectors: index.len(),
        id_maps,
        class_maps: plan.classes_of.clone(),
    })
}

/// A cluster plan loaded back from disk.
#[derive(Debug)]
pub struct LoadedCluster {
    /// The router's routing table.
    pub table: RoutingTable,
    /// Strategy recorded in the manifest.
    pub strategy: ShardStrategy,
    /// Shard index artifact paths, shard order.
    pub shard_files: Vec<PathBuf>,
}

/// Materialize a full cluster plan under `dir`: one index artifact per
/// shard (`shard-<i>.amidx`, written via [`crate::index::persist::save`])
/// plus the v3 shard manifest (`cluster.amplan`) carrying the routing
/// table.  Returns the written shard artifact paths.
pub fn write_cluster(index: &AmIndex, plan: &ShardPlan, dir: &Path) -> Result<Vec<PathBuf>> {
    let table = routing_table(index, plan)?;
    std::fs::create_dir_all(dir)?;
    let mut files = Vec::with_capacity(plan.n_shards);
    let mut names = Vec::with_capacity(plan.n_shards);
    for si in 0..plan.n_shards {
        let (shard, _ids) = build_shard_index(index, plan, si)?;
        let name = format!("shard-{si}.amidx");
        let path = dir.join(&name);
        crate::index::persist::save(&shard, &path)?;
        files.push(path);
        names.push(name);
    }
    save_manifest(&table, plan.strategy, &names, &dir.join(MANIFEST_FILE))?;
    Ok(files)
}

fn metric_byte(m: Metric) -> u8 {
    match m {
        Metric::SqL2 => 0,
        Metric::NegDot => 1,
        Metric::Hamming => 2,
    }
}

fn metric_from_byte(b: u8) -> Result<Metric> {
    match b {
        0 => Ok(Metric::SqL2),
        1 => Ok(Metric::NegDot),
        2 => Ok(Metric::Hamming),
        x => Err(Error::Data(format!("bad metric byte {x}"))),
    }
}

/// Write the shard manifest (format v3).
pub fn save_manifest(
    table: &RoutingTable,
    strategy: ShardStrategy,
    shard_files: &[String],
    path: &Path,
) -> Result<()> {
    let n_shards = table.n_shards();
    if shard_files.len() != n_shards {
        return Err(Error::Config(format!(
            "{} shard files for {n_shards} shards",
            shard_files.len()
        )));
    }
    let d = table.dim();
    let file = std::fs::File::create(path)?;
    let mut w = CountingWriter::new(BufWriter::new(file));
    w.put(MANIFEST_MAGIC)?;
    w.put(&SHARD_MANIFEST_VERSION.to_le_bytes())?;
    w.put(&(d as u32).to_le_bytes())?;
    w.put(&[metric_byte(table.metric)])?;
    w.put(&[strategy.to_byte()])?;
    w.put(&(table.default_top_k as u32).to_le_bytes())?;
    w.put(&(table.n_vectors as u64).to_le_bytes())?;
    w.put(&(n_shards as u32).to_le_bytes())?;
    for si in 0..n_shards {
        let name = shard_files[si].as_bytes();
        w.put(&(name.len() as u32).to_le_bytes())?;
        w.put(name)?;
        let classes = &table.class_maps[si];
        w.put(&(classes.len() as u32).to_le_bytes())?;
        for &c in classes {
            w.put(&c.to_le_bytes())?;
        }
        let ids = &table.id_maps[si];
        w.put(&(ids.len() as u64).to_le_bytes())?;
        for &v in ids {
            w.put(&v.to_le_bytes())?;
        }
        w.put(&(table.bank.count(si) as u64).to_le_bytes())?;
    }
    for &x in table.bank.stacked() {
        w.put(&x.to_le_bytes())?;
    }
    w.finish()
}

/// Load a cluster plan directory written by [`write_cluster`].
pub fn load_cluster(dir: &Path) -> Result<LoadedCluster> {
    let path = dir.join(MANIFEST_FILE);
    let file = std::fs::File::open(&path)
        .map_err(|e| Error::Data(format!("cannot open {}: {e}", path.display())))?;
    let mut r = CountingReader::new(BufReader::new(file));
    let mut magic = [0u8; 8];
    r.take(&mut magic)?;
    if &magic != MANIFEST_MAGIC {
        return Err(Error::Data("not an amsearch shard manifest".into()));
    }
    let version = r.u32()?;
    if version != SHARD_MANIFEST_VERSION {
        return Err(Error::Data(format!(
            "unsupported shard manifest version {version}"
        )));
    }
    // every length-bearing header field is bounded BEFORE it sizes an
    // allocation or arithmetic (same discipline as the wire decoder): a
    // corrupt count must surface as a typed error at the element reads
    // or the checksum, never as a multi-GB allocation abort
    let d = r.u32()? as usize;
    if d == 0 || d > (1 << 16) {
        return Err(Error::Data(format!("shard manifest: implausible dim {d}")));
    }
    let metric = metric_from_byte(r.u8()?)?;
    let strategy = ShardStrategy::from_byte(r.u8()?)?;
    let default_top_k = r.u32()? as usize;
    let n_total = r.u64()? as usize;
    let n_shards = r.u32()? as usize;
    if n_shards == 0 || n_shards > (1 << 12) {
        return Err(Error::Data(format!(
            "shard manifest: implausible shard count {n_shards}"
        )));
    }
    let mut shard_files = Vec::with_capacity(n_shards);
    let mut class_maps = Vec::with_capacity(n_shards);
    let mut id_maps = Vec::with_capacity(n_shards);
    let mut counts = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let name_len = r.u32()? as usize;
        if name_len > 4096 {
            return Err(Error::Data("shard file name too long".into()));
        }
        let mut name = vec![0u8; name_len];
        r.take(&mut name)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Data("shard file name is not utf-8".into()))?;
        shard_files.push(dir.join(name));
        // element-wise reads: a corrupt count runs into EOF (typed io
        // error), so capacity is only a bounded hint, never trusted
        let n_classes = r.u32()? as usize;
        let mut classes = Vec::with_capacity(n_classes.min(1 << 16));
        for _ in 0..n_classes {
            classes.push(r.u32()?);
        }
        class_maps.push(classes);
        let n_vectors = r.u64()? as usize;
        let mut ids = Vec::with_capacity(n_vectors.min(1 << 20));
        for _ in 0..n_vectors {
            ids.push(r.u32()?);
        }
        id_maps.push(ids);
        counts.push(r.u64()? as usize);
    }
    // bounded d and n_shards keep this product far from overflow, and
    // the chunked reads grow the buffer only as real bytes arrive
    let weights_len = n_shards * d * d;
    let mut weights = Vec::new();
    let mut remaining = weights_len;
    while remaining > 0 {
        let chunk = remaining.min(1 << 20);
        weights.extend(r.f32_vec(chunk)?);
        remaining -= chunk;
    }
    r.verify_checksum()?;
    let total_ids: usize = id_maps.iter().map(|m| m.len()).sum();
    if total_ids != n_total {
        return Err(Error::Data(format!(
            "shard manifest corrupt: id maps cover {total_ids} vectors, \
             header says {n_total}"
        )));
    }
    let bank = MemoryBank::from_parts(d, weights, counts, StorageRule::Sum)?;
    Ok(LoadedCluster {
        table: RoutingTable {
            bank,
            metric,
            default_top_k,
            n_vectors: n_total,
            id_maps,
            class_maps,
        },
        strategy,
        shard_files,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::metrics::OpsCounter;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "amsearch_cluster_{}_{}",
            std::process::id(),
            name
        ))
    }

    fn build(seed: u64, n: usize, q: usize) -> (AmIndex, crate::data::Workload) {
        let mut rng = Rng::new(seed);
        let wl = synthetic::dense_workload(32, n, 20, QueryModel::Exact, &mut rng);
        let params =
            IndexParams { n_classes: q, top_p: 2, top_k: 3, ..Default::default() };
        (AmIndex::build(wl.base.clone(), params, &mut rng).unwrap(), wl)
    }

    #[test]
    fn strategies_produce_exact_covers_with_no_empty_shard() {
        let sizes = vec![7usize, 1, 9, 3, 3, 0, 12, 5, 2];
        for strategy in [
            ShardStrategy::Contiguous,
            ShardStrategy::RoundRobin,
            ShardStrategy::BalancedMembers,
        ] {
            for n_shards in 1..=sizes.len() {
                let plan = ShardPlan::new(&sizes, n_shards, strategy).unwrap();
                assert_eq!(plan.shard_of.len(), sizes.len());
                let covered: usize =
                    plan.classes_of.iter().map(|c| c.len()).sum();
                assert_eq!(covered, sizes.len(), "{strategy} N={n_shards}");
                for (si, classes) in plan.classes_of.iter().enumerate() {
                    assert!(
                        !classes.is_empty(),
                        "{strategy} N={n_shards}: shard {si} empty"
                    );
                    assert!(
                        classes.windows(2).all(|w| w[0] < w[1]),
                        "classes not ascending"
                    );
                    for &c in classes {
                        assert_eq!(plan.shard_of[c as usize] as usize, si);
                    }
                }
            }
        }
    }

    #[test]
    fn balanced_members_evens_out_skewed_classes() {
        // one huge class + many small ones: LPT must not stack the big
        // one with others while another shard starves
        let sizes = vec![100usize, 10, 10, 10, 10, 10, 10, 10];
        let plan =
            ShardPlan::new(&sizes, 4, ShardStrategy::BalancedMembers).unwrap();
        let shard_sizes = plan.shard_sizes(&sizes);
        assert_eq!(shard_sizes.iter().sum::<usize>(), 170);
        // the big class sits alone; the 7 small ones split across the
        // other three shards
        assert_eq!(*shard_sizes.iter().max().unwrap(), 100);
        assert!(*shard_sizes.iter().min().unwrap() >= 20, "{shard_sizes:?}");
    }

    #[test]
    fn bad_shard_counts_rejected() {
        let sizes = vec![4usize; 6];
        assert!(ShardPlan::new(&sizes, 0, ShardStrategy::Contiguous).is_err());
        assert!(ShardPlan::new(&sizes, 7, ShardStrategy::Contiguous).is_err());
    }

    #[test]
    fn shard_indices_partition_the_database() {
        let (index, _) = build(1, 240, 12);
        for strategy in [
            ShardStrategy::Contiguous,
            ShardStrategy::RoundRobin,
            ShardStrategy::BalancedMembers,
        ] {
            let plan = ShardPlan::for_index(&index, 4, strategy).unwrap();
            let mut seen = vec![false; index.len()];
            for si in 0..4 {
                let (shard, id_map) = build_shard_index(&index, &plan, si).unwrap();
                assert_eq!(shard.len(), id_map.len());
                assert_eq!(shard.dim(), index.dim());
                assert!(id_map.windows(2).all(|w| w[0] < w[1]), "ids ascending");
                shard.partition().validate().unwrap();
                for (local, &gid) in id_map.iter().enumerate() {
                    assert!(!seen[gid as usize], "vector {gid} in two shards");
                    seen[gid as usize] = true;
                    // the shard stores the very same vector bits
                    assert_eq!(
                        shard.data().get(local),
                        index.data().get(gid as usize)
                    );
                }
            }
            assert!(seen.iter().all(|&s| s), "{strategy}: not a cover");
        }
    }

    #[test]
    fn routing_super_memory_is_sum_of_class_memories() {
        let (index, _) = build(2, 180, 9);
        let plan =
            ShardPlan::for_index(&index, 3, ShardStrategy::Contiguous).unwrap();
        let table = routing_table(&index, &plan).unwrap();
        assert_eq!(table.n_shards(), 3);
        assert_eq!(table.n_vectors(), 180);
        let d = index.dim();
        for si in 0..3 {
            let sw = table.bank().class_weights(si);
            let mut sum = vec![0f32; d * d];
            for &c in &plan.classes_of[si] {
                for (a, b) in
                    sum.iter_mut().zip(index.bank().class_weights(c as usize))
                {
                    *a += b;
                }
            }
            for (a, b) in sw.iter().zip(&sum) {
                assert!((a - b).abs() < 1e-3);
            }
        }
        // scoring a query against the table equals summing its class
        // scores shard-wise (the additivity the router relies on)
        let mut ops = OpsCounter::new();
        let probe: Vec<f32> = (0..d).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let class_scores = index.score_classes(&probe, &mut ops);
        let shard_scores = table.score(&probe);
        for si in 0..3 {
            let want: f32 = plan.classes_of[si]
                .iter()
                .map(|&c| class_scores[c as usize])
                .sum();
            assert!(
                (shard_scores[si] - want).abs() < want.abs().max(1.0) * 1e-3,
                "shard {si}: {} vs {}",
                shard_scores[si],
                want
            );
        }
    }

    #[test]
    fn max_rule_rejected_for_routing() {
        let mut rng = Rng::new(3);
        let wl = synthetic::dense_workload(16, 60, 5, QueryModel::Exact, &mut rng);
        let params = IndexParams {
            n_classes: 6,
            rule: StorageRule::Max,
            ..Default::default()
        };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let plan =
            ShardPlan::for_index(&index, 2, ShardStrategy::Contiguous).unwrap();
        assert!(routing_table(&index, &plan).is_err());
    }

    #[test]
    fn write_then_load_cluster_roundtrips() {
        let (index, wl) = build(4, 200, 10);
        let plan =
            ShardPlan::for_index(&index, 3, ShardStrategy::BalancedMembers).unwrap();
        let dir = tmp("roundtrip");
        std::fs::remove_dir_all(&dir).ok();
        let files = write_cluster(&index, &plan, &dir).unwrap();
        assert_eq!(files.len(), 3);
        let loaded = load_cluster(&dir).unwrap();
        assert_eq!(loaded.strategy, ShardStrategy::BalancedMembers);
        assert_eq!(loaded.shard_files, files);
        assert_eq!(loaded.table.n_shards(), 3);
        assert_eq!(loaded.table.n_vectors(), 200);
        assert_eq!(loaded.table.default_top_k(), 3);
        let fresh = routing_table(&index, &plan).unwrap();
        for si in 0..3 {
            assert_eq!(loaded.table.id_maps[si], fresh.id_maps[si]);
            assert_eq!(loaded.table.class_maps[si], fresh.class_maps[si]);
            // super-memories survive bit-exactly
            for (a, b) in loaded
                .table
                .bank()
                .class_weights(si)
                .iter()
                .zip(fresh.bank().class_weights(si))
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // the shard artifacts load as ordinary indices and answer
        // queries (full poll finds the shard-local NN of any member)
        let (shard0, id_map0) = build_shard_index(&index, &plan, 0).unwrap();
        let reloaded = crate::index::persist::load(&files[0]).unwrap();
        assert_eq!(reloaded.len(), shard0.len());
        let mut ops = OpsCounter::new();
        let probe = wl.queries.get(0);
        let a = shard0.query_k(probe, shard0.params().n_classes, 2, &mut ops);
        let b = reloaded.query_k(probe, reloaded.params().n_classes, 2, &mut ops);
        assert_eq!(a, b);
        assert!(!id_map0.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quantized_plan_writes_quantized_shard_artifacts() {
        use crate::quant::ScanPrecision;
        let mut rng = Rng::new(6);
        let wl = synthetic::dense_workload(32, 200, 10, QueryModel::Exact, &mut rng);
        let params = IndexParams {
            n_classes: 10,
            top_p: 2,
            precision: ScanPrecision::Sq8 { rerank: 0 },
            ..Default::default()
        };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let plan =
            ShardPlan::for_index(&index, 3, ShardStrategy::BalancedMembers).unwrap();
        let dir = tmp("quant_plan");
        std::fs::remove_dir_all(&dir).ok();
        let files = write_cluster(&index, &plan, &dir).unwrap();
        for (si, file) in files.iter().enumerate() {
            let shard = crate::index::persist::load(file).unwrap();
            assert_eq!(
                shard.params().precision,
                ScanPrecision::Sq8 { rerank: 0 },
                "shard {si} lost the quantization config"
            );
            let q = shard.quant().expect("shard scans compressed");
            assert_eq!(q.len(), shard.len());
            assert!(shard.footprint().ratio() <= 0.35, "shard {si}");
        }
        // a shard's full-poll answer still matches the in-memory build
        let (shard0, _) = build_shard_index(&index, &plan, 0).unwrap();
        let reloaded = crate::index::persist::load(&files[0]).unwrap();
        let mut ops = OpsCounter::new();
        let probe = wl.queries.get(0);
        let a = shard0.query_k(probe, shard0.params().n_classes, 3, &mut ops);
        let b = reloaded.query_k(probe, reloaded.params().n_classes, 3, &mut ops);
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_corruption_detected() {
        let (index, _) = build(5, 120, 6);
        let plan =
            ShardPlan::for_index(&index, 2, ShardStrategy::Contiguous).unwrap();
        let dir = tmp("corrupt");
        std::fs::remove_dir_all(&dir).ok();
        write_cluster(&index, &plan, &dir).unwrap();
        let path = dir.join(MANIFEST_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_cluster(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn strategy_strings_roundtrip() {
        for s in [
            ShardStrategy::Contiguous,
            ShardStrategy::RoundRobin,
            ShardStrategy::BalancedMembers,
        ] {
            assert_eq!(s.to_string().parse::<ShardStrategy>().unwrap(), s);
            assert_eq!(ShardStrategy::from_byte(s.to_byte()).unwrap(), s);
        }
        assert!("nope".parse::<ShardStrategy>().is_err());
    }
}
