//! Single-binary cluster harness: spawn N in-process shard servers
//! (each a full coordinator + TCP front door on an ephemeral loopback
//! port) plus the scatter-gather router in front of them — a real
//! cluster topology over real TCP, with no orchestration tooling.
//!
//! ```text
//! clients ──TCP──► router front door (NetServer)
//!                    └─ ClusterRouter: score super-memories,
//!                       contact top-s shards over pooled NetClients
//!                         ├──TCP──► shard 0: NetServer + SearchServer
//!                         ├──TCP──► shard 1: NetServer + SearchServer
//!                         └──TCP──► ...        (ephemeral ports)
//! ```
//!
//! Tests, benches, and CI exercise the exact production wire path; the
//! `serve-cluster` CLI subcommand is a thin wrapper over
//! [`ClusterHarness::launch`] / [`ClusterHarness::launch_from_dir`].

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use crate::error::Result;
use crate::index::AmIndex;
use crate::net::{NetConfig, NetServer};
use crate::obs::TraceSink;
use crate::runtime::Backend;

use super::plan::{build_shard_index, load_cluster, routing_table, ShardPlan, ShardStrategy};
use super::router::{ClusterRouter, RouterConfig};

/// Everything needed to launch a cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of shards `N` (ignored by
    /// [`ClusterHarness::launch_from_dir`], which takes it from the
    /// manifest).
    pub n_shards: usize,
    /// Class→shard assignment strategy.
    pub strategy: ShardStrategy,
    /// Router tuning (fan-out, workers, retry policy).
    pub router: RouterConfig,
    /// Per-shard coordinator tuning.
    pub coordinator: CoordinatorConfig,
    /// Front-door tuning, shared by the router and the shards (shard
    /// front doors are relabeled `role = "shard"` in STATS).
    pub net: NetConfig,
    /// Scoring backend for the shard engines.
    pub backend: Backend,
    /// Artifacts directory (PJRT backend only).
    pub artifacts_dir: Option<PathBuf>,
    /// Vector-store choice for the shard engines: `Resident` (default)
    /// loads member matrices into RAM; `Paged` keeps each shard's
    /// `.amdat` extent file on disk behind an LRU cache.  Paged shards
    /// require a plan directory ([`ClusterHarness::launch_from_dir`]):
    /// the in-process [`ClusterHarness::launch`] path has no on-disk
    /// artifacts to page from and rejects the combination.
    pub store: crate::store::StoreOptions,
    /// Shared trace sink for the whole cluster: the router and every
    /// shard coordinator emit into the same JSON-lines destination, so
    /// one `--trace-out` file carries complete stitched request trees.
    pub trace: Option<Arc<TraceSink>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_shards: 2,
            strategy: ShardStrategy::Contiguous,
            router: RouterConfig::default(),
            coordinator: CoordinatorConfig::default(),
            net: NetConfig::default(),
            backend: Backend::Native,
            artifacts_dir: None,
            store: crate::store::StoreOptions::default(),
            trace: None,
        }
    }
}

/// One running shard: its coordinator and its TCP front door.
struct ShardNode {
    search: Arc<SearchServer>,
    net: NetServer,
}

/// A running in-process cluster: N shard servers + router, all on
/// loopback TCP.
pub struct ClusterHarness {
    shards: Vec<ShardNode>,
    router: Arc<ClusterRouter>,
    router_net: NetServer,
}

impl ClusterHarness {
    /// Plan `index` across `cfg.n_shards` shards and launch the whole
    /// cluster, with the router's front door bound to `listen`
    /// (`"127.0.0.1:0"` for an ephemeral port).
    pub fn launch(index: &AmIndex, listen: &str, cfg: &ClusterConfig) -> Result<Self> {
        if matches!(cfg.store.mode, crate::store::StoreMode::Paged) {
            return Err(crate::error::Error::Config(
                "paged shards need on-disk artifacts: write a plan \
                 directory with shard-plan and launch from it"
                    .into(),
            ));
        }
        let plan = ShardPlan::for_index(index, cfg.n_shards, cfg.strategy)?;
        let table = routing_table(index, &plan)?;
        let mut factories = Vec::with_capacity(plan.n_shards);
        for si in 0..plan.n_shards {
            let (shard, _ids) = build_shard_index(index, &plan, si)?;
            factories.push(EngineFactory {
                index: Arc::new(shard),
                backend: cfg.backend,
                artifacts_dir: cfg.artifacts_dir.clone(),
            });
        }
        Self::launch_shards(table, factories, listen, cfg)
    }

    /// Launch from a plan directory written by `shard-plan`
    /// ([`super::plan::write_cluster`]): shard artifacts are loaded
    /// from disk, the routing table from the v3 manifest.  Every shard
    /// artifact is validated against the manifest (dimension and vector
    /// count) — a stale or half-written plan directory must fail here,
    /// not panic a router worker at query time when a shard-local id
    /// falls outside the manifest's id map.
    pub fn launch_from_dir(dir: &Path, listen: &str, cfg: &ClusterConfig) -> Result<Self> {
        let loaded = load_cluster(dir)?;
        let mut factories = Vec::with_capacity(loaded.shard_files.len());
        for (si, file) in loaded.shard_files.iter().enumerate() {
            let factory = EngineFactory::from_index_file_with_store(
                file,
                cfg.backend,
                cfg.artifacts_dir.clone(),
                &cfg.store,
            )?;
            if factory.index.dim() != loaded.table.dim()
                || factory.index.len() != loaded.table.shard_len(si)
            {
                return Err(crate::error::Error::Data(format!(
                    "shard artifact {} (n={}, d={}) does not match the \
                     manifest (n={}, d={}): stale or half-written plan \
                     directory — rerun shard-plan",
                    file.display(),
                    factory.index.len(),
                    factory.index.dim(),
                    loaded.table.shard_len(si),
                    loaded.table.dim()
                )));
            }
            factories.push(factory);
        }
        Self::launch_shards(loaded.table, factories, listen, cfg)
    }

    fn launch_shards(
        table: super::plan::RoutingTable,
        factories: Vec<EngineFactory>,
        listen: &str,
        cfg: &ClusterConfig,
    ) -> Result<Self> {
        // cluster-wide index summary (footprint + quant mode), captured
        // while the shard indices are still in hand so the router's
        // STATS can report compression like a single node does
        let index_info = super::router::ClusterIndexInfo::from_indices(
            factories.iter().map(|f| f.index.as_ref()),
        );
        let shard_net = NetConfig { role: Some("shard"), ..cfg.net };
        let mut shards = Vec::with_capacity(factories.len());
        let mut addrs = Vec::with_capacity(factories.len());
        for factory in factories {
            let search = Arc::new(SearchServer::start_traced(
                factory,
                cfg.coordinator,
                cfg.trace.clone(),
            )?);
            let net = NetServer::bind(search.clone(), "127.0.0.1:0", shard_net)?;
            addrs.push(net.local_addr().to_string());
            shards.push(ShardNode { search, net });
        }
        let router = Arc::new(ClusterRouter::start_traced(
            table,
            addrs,
            cfg.router,
            cfg.trace.clone(),
        )?);
        router.set_index_info(index_info);
        let router_net = NetServer::bind(router.clone(), listen, cfg.net)?;
        Ok(ClusterHarness { shards, router, router_net })
    }

    /// The router front door's address (what clients and `loadgen`
    /// connect to).
    pub fn router_addr(&self) -> std::net::SocketAddr {
        self.router_net.local_addr()
    }

    /// Address of shard `si`'s front door.
    pub fn shard_addr(&self, si: usize) -> std::net::SocketAddr {
        self.shards[si].net.local_addr()
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The scatter-gather router (fan-out knob, metrics, in-process
    /// `search`).
    pub fn router(&self) -> &Arc<ClusterRouter> {
        &self.router
    }

    /// Shard `si`'s coordinator (metrics inspection in tests).
    pub fn shard_server(&self, si: usize) -> &Arc<SearchServer> {
        &self.shards[si].search
    }

    /// Block until the router's front door has drained — i.e. until a
    /// client sent a SHUTDOWN frame (`loadgen --shutdown`).
    pub fn join(&self) {
        self.router_net.join();
    }

    /// Orderly full-cluster shutdown: router front door first (drains
    /// in-flight client requests), then the router workers, then each
    /// shard's front door and coordinator — no layer is torn down while
    /// a layer above it still holds in-flight work.
    pub fn shutdown(&self) {
        self.router_net.shutdown();
        self.router.shutdown();
        for shard in &self.shards {
            shard.net.shutdown();
            shard.search.shutdown();
        }
    }
}

impl Drop for ClusterHarness {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::index::IndexParams;

    #[test]
    fn harness_launches_and_serves_through_the_router() {
        let mut rng = Rng::new(31);
        let wl = synthetic::dense_workload(24, 192, 12, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 6, top_p: 2, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let cfg = ClusterConfig {
            n_shards: 3,
            net: NetConfig { poll_ms: 10, ..Default::default() },
            ..Default::default()
        };
        let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
        assert_eq!(cluster.n_shards(), 3);
        // full poll + full fan-out: every query finds its stored copy
        for (qi, &gt) in wl.ground_truth.iter().enumerate().take(6) {
            let resp = cluster
                .router()
                .search(wl.queries.get(qi).to_vec(), 6, 1)
                .unwrap();
            assert_eq!(resp.neighbor(), Some(gt), "query {qi}");
            assert_eq!(resp.candidates, 192, "full poll scans everything");
            assert_eq!(resp.polled.len(), 6, "all classes polled across shards");
        }
        let m = cluster.router().metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.errors, 0);
        assert_eq!(m.fanout.per_shard, vec![6, 6, 6]);
        cluster.shutdown();
    }
}
