//! Associative memories: the paper's class summaries.
//!
//! * [`outer::OuterProductMemory`] — the sum rule `W = Σ x xᵀ` analyzed in
//!   §3/§4.
//! * [`cooccurrence::CooccurrenceMemory`] — the max rule of [19],
//!   the §5.1.1 ablation.
//! * [`bank::MemoryBank`] — `q` memories stacked `[q, d, d]`, the operand
//!   of both the native and the PJRT scorer.
//! * [`score`] — the optimized batched native scorer.

pub mod bank;
pub mod cooccurrence;
pub mod higher_order;
pub mod outer;
pub mod retrieval;
pub mod score;

pub use bank::MemoryBank;
pub use cooccurrence::CooccurrenceMemory;
pub use higher_order::HigherOrderScorer;
pub use outer::OuterProductMemory;

/// Which storage rule a memory bank uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageRule {
    /// Sum of outer products (the paper's analyzed rule).
    Sum,
    /// Cooccurrence / max rule ([19], §5.1.1 ablation).
    Max,
}

impl std::str::FromStr for StorageRule {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sum" => Ok(StorageRule::Sum),
            "max" => Ok(StorageRule::Max),
            other => Err(crate::error::Error::Config(format!(
                "unknown storage rule '{other}' (sum|max)"
            ))),
        }
    }
}

impl std::fmt::Display for StorageRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageRule::Sum => write!(f, "sum"),
            StorageRule::Max => write!(f, "max"),
        }
    }
}
