//! Higher-order class scores — the paper's Remark 4.3.
//!
//! Replacing the order-2 score `Σ_μ ⟨x, x^μ⟩²` with an order-2m score
//! `Σ_μ ⟨x, x^μ⟩^{2m}` sharpens the signal term (`d^{2m}` vs crosstalk
//! concentration) and, by analogy with the n-spin Hopfield capacity
//! `N^{p-1}` (Newman '88), conjecturally admits class sizes `k ≪ d^m`.
//! There is no d×d-sized sufficient statistic for m > 1 (the memory would
//! be an order-2m tensor), so this scorer keeps the raw class members and
//! pays `k·d` per class per query — exactly the trade-off the Remark
//! points out ("the computational complexity of our algorithm would also
//! increase").  The `ablation_higher_order` figure measures the error
//! rate side of the conjecture.

use crate::data::dataset::Dataset;

/// Direct-evaluation higher-order scorer over stored class members.
#[derive(Debug, Clone)]
pub struct HigherOrderScorer {
    /// Raw members of each class (flat row-major).
    classes: Vec<Dataset>,
    /// Half-order m (score uses exponent 2m); m = 1 reproduces the
    /// standard associative-memory score.
    order: u32,
}

impl HigherOrderScorer {
    /// Build from per-class member datasets.
    pub fn new(classes: Vec<Dataset>, order: u32) -> Self {
        assert!(order >= 1, "order must be >= 1");
        HigherOrderScorer { classes, order }
    }

    /// Number of classes.
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Half-order m.
    pub fn order(&self) -> u32 {
        self.order
    }

    /// Score one class: `Σ_μ ⟨x, x^μ⟩^{2m}`.
    pub fn score_class(&self, i: usize, x: &[f32]) -> f64 {
        let mut total = 0f64;
        for member in self.classes[i].iter() {
            let mut dot = 0f64;
            for (a, b) in member.iter().zip(x) {
                dot += (*a as f64) * (*b as f64);
            }
            total += dot.powi(2 * self.order as i32);
        }
        total
    }

    /// Scores for all classes.
    pub fn score_all(&self, x: &[f32]) -> Vec<f64> {
        (0..self.classes.len()).map(|i| self.score_class(i, x)).collect()
    }

    /// Per-query scoring cost in elementary ops: `Σ_i k_i · d` (member
    /// dot products dominate; the power is O(1)).
    pub fn scoring_cost(&self, dim: usize) -> u64 {
        self.classes.iter().map(|c| (c.len() * dim) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic;
    use crate::memory::OuterProductMemory;

    fn classes(rng: &mut Rng, q: usize, k: usize, d: usize) -> Vec<Dataset> {
        (0..q).map(|_| synthetic::dense_patterns(d, k, rng)).collect()
    }

    #[test]
    fn order_one_matches_outer_product_memory() {
        let mut rng = Rng::new(1);
        let cls = classes(&mut rng, 3, 8, 16);
        let scorer = HigherOrderScorer::new(cls.clone(), 1);
        let x = synthetic::dense_patterns(16, 1, &mut rng);
        let x = x.get(0);
        for (i, c) in cls.iter().enumerate() {
            let mut mem = OuterProductMemory::new(16);
            for v in c.iter() {
                mem.add(v);
            }
            let want = mem.score(x) as f64;
            let got = scorer.score_class(i, x);
            assert!((got - want).abs() / want.abs().max(1.0) < 1e-4);
        }
    }

    #[test]
    fn own_class_dominates_more_at_higher_order() {
        // signal/crosstalk ratio grows with the order: measure the margin
        // (target score / best other score) for m=1 vs m=2
        let mut rng = Rng::new(2);
        let (q, k, d) = (4, 64, 32);
        let cls = classes(&mut rng, q, k, d);
        let x = cls[1].get(0).to_vec(); // stored pattern of class 1
        let margin = |order: u32| -> f64 {
            let s = HigherOrderScorer::new(cls.clone(), order);
            let scores = s.score_all(&x);
            let target = scores[1];
            let best_other = scores
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != 1)
                .map(|(_, &v)| v)
                .fold(f64::MIN, f64::max);
            target / best_other
        };
        let m1 = margin(1);
        let m2 = margin(2);
        assert!(m2 > m1, "m1={m1} m2={m2}");
    }

    #[test]
    fn scoring_cost_counts_members() {
        let mut rng = Rng::new(3);
        let cls = classes(&mut rng, 2, 10, 8);
        let s = HigherOrderScorer::new(cls, 2);
        assert_eq!(s.scoring_cost(8), 2 * 10 * 8);
    }

    #[test]
    fn higher_order_survives_larger_k() {
        // the conjecture's direction: at a k where order-1 argmax starts
        // failing, order-2 still succeeds (statistical test, fixed seed)
        let mut rng = Rng::new(4);
        let (q, k, d) = (2usize, 2048usize, 24usize); // k >> d² = 576
        let cls = classes(&mut rng, q, k, d);
        let s1 = HigherOrderScorer::new(cls.clone(), 1);
        let s2 = HigherOrderScorer::new(cls.clone(), 2);
        let trials = 40;
        let mut wins1 = 0;
        let mut wins2 = 0;
        for t in 0..trials {
            let x = cls[0].get(t).to_vec();
            let sc1 = s1.score_all(&x);
            let sc2 = s2.score_all(&x);
            if sc1[0] > sc1[1] {
                wins1 += 1;
            }
            if sc2[0] > sc2[1] {
                wins2 += 1;
            }
        }
        assert!(wins2 >= wins1, "order1={wins1} order2={wins2} / {trials}");
        assert!(
            wins2 >= 32,
            "order-2 should be clearly better than chance, got {wins2}/{trials}"
        );
    }
}
