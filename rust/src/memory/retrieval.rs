//! Associative retrieval ("smart pooling") — the paper's conclusion
//! suggests "using smart pooling to directly identify the nearest
//! neighbor without need to perform an exhaustive search".  A class
//! memory `W = Σ x^μ (x^μ)ᵀ` is exactly a Hopfield weight matrix, so the
//! natural pooling is one Hopfield readout step:
//!
//! * dense ±1 patterns:  `x̂ = sign(W x⁰)`
//! * sparse 0/1 patterns: `x̂ = top-c(W x⁰)` (winner-take-all, the
//!   Willshaw/Gripon-Berrou readout)
//!
//! In the theorems' regime the readout recovers the stored pattern from a
//! corrupted probe at cost `d²` — *independent of k* — replacing the
//! `k·d` in-class scan.  The recovered pattern is mapped back to a
//! database id by exact-match lookup (hash of the stored vectors);
//! readout failures fall back to the scan.  `ablation_pooling` measures
//! the trade-off.

use std::collections::HashMap;

use crate::data::dataset::Dataset;
use crate::search::topk::TopK;

/// Exact-match lookup from pattern bytes to database id.
#[derive(Debug, Clone, Default)]
pub struct PatternLookup {
    map: HashMap<Vec<u32>, u32>,
}

fn key_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

impl PatternLookup {
    /// Index every vector of `data` (first occurrence wins on duplicates,
    /// matching the scan's smaller-id tie-break).
    pub fn build(data: &Dataset) -> Self {
        let mut map = HashMap::with_capacity(data.len());
        for (i, v) in data.iter().enumerate() {
            map.entry(key_of(v)).or_insert(i as u32);
        }
        PatternLookup { map }
    }

    /// Database id of an exact pattern, if stored.
    pub fn find(&self, v: &[f32]) -> Option<u32> {
        self.map.get(&key_of(v)).copied()
    }

    /// Number of distinct stored patterns.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// One Hopfield readout step for dense ±1 patterns: `sign(W x)`
/// (ties, i.e. exact zeros, resolve to +1).  Cost: d².
pub fn readout_dense(w: &[f32], x: &[f32], dim: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), dim * dim);
    debug_assert_eq!(x.len(), dim);
    let mut out = Vec::with_capacity(dim);
    for l in 0..dim {
        let row = &w[l * dim..(l + 1) * dim];
        let mut acc = 0f32;
        for (wm, &xm) in row.iter().zip(x) {
            acc += wm * xm;
        }
        out.push(if acc >= 0.0 { 1.0 } else { -1.0 });
    }
    out
}

/// Winner-take-all readout for sparse 0/1 patterns: activate the `c`
/// coordinates with the largest field `W x` (ties by smaller index).
/// Cost: d² (+ d log c for the selection).
pub fn readout_sparse(w: &[f32], x: &[f32], dim: usize, c: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), dim * dim);
    let mut heap = TopK::new(c.max(1));
    for l in 0..dim {
        let row = &w[l * dim..(l + 1) * dim];
        let mut acc = 0f32;
        for (wm, &xm) in row.iter().zip(x) {
            if xm != 0.0 {
                acc += wm * xm;
            }
        }
        heap.push(-acc, l as u32); // keep largest fields
    }
    let mut out = vec![0f32; dim];
    for (_, l) in heap.into_sorted() {
        out[l as usize] = 1.0;
    }
    out
}

/// Iterated readout (dense): applies `sign(W ·)` up to `iters` times or
/// until a fixed point.  One step suffices in the theorems' regime;
/// iteration extends the basin at low load.
pub fn readout_dense_iterated(
    w: &[f32],
    x: &[f32],
    dim: usize,
    iters: usize,
) -> Vec<f32> {
    let mut cur = x.to_vec();
    for _ in 0..iters.max(1) {
        let next = readout_dense(w, &cur, dim);
        if next == cur {
            return next;
        }
        cur = next;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, corrupt_dense, corrupt_sparse, SparseSpec};
    use crate::memory::OuterProductMemory;

    #[test]
    fn lookup_roundtrip_and_tiebreak() {
        let ds = Dataset::from_flat(2, vec![1., 2., 3., 4., 1., 2.]).unwrap();
        let lk = PatternLookup::build(&ds);
        assert_eq!(lk.find(&[3., 4.]), Some(1));
        assert_eq!(lk.find(&[1., 2.]), Some(0)); // duplicate -> smaller id
        assert_eq!(lk.find(&[9., 9.]), None);
        assert_eq!(lk.len(), 2);
    }

    #[test]
    fn dense_readout_recovers_stored_pattern() {
        // low load: k = 8 patterns in d = 256 -> exact one-step recovery
        let mut rng = Rng::new(1);
        let d = 256;
        let pats = synthetic::dense_patterns(d, 8, &mut rng);
        let mut mem = OuterProductMemory::new(d);
        for p in pats.iter() {
            mem.add(p);
        }
        for (i, p) in pats.iter().enumerate() {
            let probe = corrupt_dense(p, 0.8, &mut rng);
            let got = readout_dense(mem.weights(), &probe, d);
            assert_eq!(got, p, "pattern {i} not recovered");
        }
    }

    #[test]
    fn sparse_readout_recovers_stored_pattern() {
        let mut rng = Rng::new(2);
        let d = 256;
        let spec = SparseSpec { dim: d, ones: 12.0 };
        let pats = synthetic::sparse_patterns(spec, 6, &mut rng);
        let mut mem = OuterProductMemory::new(d);
        for p in pats.iter() {
            mem.add(p);
        }
        for (i, p) in pats.iter().enumerate() {
            let c = p.iter().filter(|&&v| v != 0.0).count();
            if c == 0 {
                continue;
            }
            let probe = corrupt_sparse(p, 0.75, &mut rng);
            let got = readout_sparse(mem.weights(), &probe, d, c);
            assert_eq!(got, p, "pattern {i} not recovered");
        }
    }

    #[test]
    fn iterated_readout_reaches_fixed_point() {
        let mut rng = Rng::new(3);
        let d = 128;
        let pats = synthetic::dense_patterns(d, 4, &mut rng);
        let mut mem = OuterProductMemory::new(d);
        for p in pats.iter() {
            mem.add(p);
        }
        let probe = corrupt_dense(pats.get(0), 0.6, &mut rng);
        let got = readout_dense_iterated(mem.weights(), &probe, d, 5);
        // fixed point: applying once more changes nothing
        let again = readout_dense(mem.weights(), &got, d);
        assert_eq!(got, again);
        assert_eq!(got, pats.get(0));
    }

    #[test]
    fn readout_fails_gracefully_at_overload() {
        // way past capacity: readout produces *some* ±1 vector (likely
        // not stored); caller detects via lookup miss
        let mut rng = Rng::new(4);
        let d = 16;
        let pats = synthetic::dense_patterns(d, 200, &mut rng);
        let mut mem = OuterProductMemory::new(d);
        for p in pats.iter() {
            mem.add(p);
        }
        let probe = corrupt_dense(pats.get(0), 0.9, &mut rng);
        let got = readout_dense(mem.weights(), &probe, d);
        assert!(got.iter().all(|&v| v == 1.0 || v == -1.0));
        let lk = PatternLookup::build(&pats);
        // may or may not be found; the API contract is Option, not panic
        let _ = lk.find(&got);
    }
}
