//! Sum-of-outer-products associative memory — the paper's core object.
//!
//! `W = Σ_μ x^μ (x^μ)ᵀ` stored dense row-major; the class score for a
//! query is the bilinear form `s = xᵀ W x = Σ_μ ⟨x, x^μ⟩²`.

/// Dense d×d sum-of-outer-products memory.
#[derive(Debug, Clone, PartialEq)]
pub struct OuterProductMemory {
    dim: usize,
    w: Vec<f32>,
    count: usize,
}

impl OuterProductMemory {
    /// Empty memory of dimension `d`.
    pub fn new(dim: usize) -> Self {
        OuterProductMemory { dim, w: vec![0.0; dim * dim], count: 0 }
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of stored patterns.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Row-major `d*d` weight buffer (the layout the PJRT scorer stacks).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Store a pattern: `W += x xᵀ`.
    pub fn add(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim, "pattern dim mismatch");
        for (l, &xl) in x.iter().enumerate() {
            if xl == 0.0 {
                continue; // sparse patterns touch only c rows
            }
            let row = &mut self.w[l * self.dim..(l + 1) * self.dim];
            for (wm, &xm) in row.iter_mut().zip(x) {
                *wm += xl * xm;
            }
        }
        self.count += 1;
    }

    /// Remove a previously stored pattern: `W -= x xᵀ` (supports online
    /// re-allocation; caller must guarantee the pattern was stored).
    pub fn remove(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim, "pattern dim mismatch");
        assert!(self.count > 0, "remove from empty memory");
        for (l, &xl) in x.iter().enumerate() {
            if xl == 0.0 {
                continue;
            }
            let row = &mut self.w[l * self.dim..(l + 1) * self.dim];
            for (wm, &xm) in row.iter_mut().zip(x) {
                *wm -= xl * xm;
            }
        }
        self.count -= 1;
    }

    /// Bilinear score `xᵀ W x`, the paper's s(X^i, x⁰).
    /// Cost: d² multiply-adds (dense query).
    pub fn score(&self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.dim);
        let mut total = 0f32;
        for (l, &xl) in x.iter().enumerate() {
            if xl == 0.0 {
                continue;
            }
            let row = &self.w[l * self.dim..(l + 1) * self.dim];
            let mut acc = 0f32;
            for (wm, &xm) in row.iter().zip(x) {
                acc += wm * xm;
            }
            total += xl * acc;
        }
        total
    }

    /// Score from the query's support only (binary sparse queries):
    /// `s = Σ_{l,m ∈ supp(x)} W[l,m]` — the paper's c² cost path.
    pub fn score_support(&self, support: &[u32]) -> f32 {
        let mut total = 0f32;
        for &l in support {
            let row = &self.w[l as usize * self.dim..(l as usize + 1) * self.dim];
            for &m in support {
                total += row[m as usize];
            }
        }
        total
    }

    /// Merge another memory into this one (class union).
    pub fn merge(&mut self, other: &OuterProductMemory) {
        assert_eq!(self.dim, other.dim, "dim mismatch");
        for (a, b) in self.w.iter_mut().zip(&other.w) {
            *a += b;
        }
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn naive_score(patterns: &[Vec<f32>], x: &[f32]) -> f32 {
        patterns
            .iter()
            .map(|p| {
                let d: f32 = p.iter().zip(x).map(|(a, b)| a * b).sum();
                d * d
            })
            .sum()
    }

    #[test]
    fn score_equals_sum_of_squared_dots() {
        let mut rng = Rng::new(1);
        let d = 24;
        let mut mem = OuterProductMemory::new(d);
        let patterns: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect())
            .collect();
        for p in &patterns {
            mem.add(p);
        }
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let got = mem.score(&x);
        let want = naive_score(&patterns, &x);
        assert!((got - want).abs() < 1e-3, "got={got} want={want}");
    }

    #[test]
    fn add_then_remove_is_identity() {
        let mut rng = Rng::new(2);
        let d = 16;
        let mut mem = OuterProductMemory::new(d);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        mem.add(&a);
        let snapshot = mem.clone();
        mem.add(&b);
        mem.remove(&b);
        assert_eq!(mem.count(), 1);
        for (x, y) in mem.weights().iter().zip(snapshot.weights()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn score_support_matches_dense_for_binary() {
        let mut rng = Rng::new(3);
        let d = 64;
        let mut mem = OuterProductMemory::new(d);
        for _ in 0..20 {
            let p: Vec<f32> =
                (0..d).map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 }).collect();
            mem.add(&p);
        }
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 }).collect();
        let support: Vec<u32> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == 1.0)
            .map(|(i, _)| i as u32)
            .collect();
        let dense = mem.score(&x);
        let sparse = mem.score_support(&support);
        assert!((dense - sparse).abs() < 1e-3, "dense={dense} sparse={sparse}");
    }

    #[test]
    fn stored_pattern_scores_at_least_norm4() {
        // s(X, x) >= <x,x>^2 when x is stored (crosstalk is nonnegative
        // only in expectation, so check against the dominant term for a
        // singleton class).
        let mut mem = OuterProductMemory::new(4);
        let x = [1.0f32, -1.0, 1.0, 1.0];
        mem.add(&x);
        let s = mem.score(&x);
        assert!((s - 16.0).abs() < 1e-5); // (||x||^2)^2 = 4^2
    }

    #[test]
    fn merge_equals_joint_build() {
        let mut rng = Rng::new(4);
        let d = 8;
        let ps: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut a = OuterProductMemory::new(d);
        let mut b = OuterProductMemory::new(d);
        let mut joint = OuterProductMemory::new(d);
        for (i, p) in ps.iter().enumerate() {
            if i < 3 {
                a.add(p);
            } else {
                b.add(p);
            }
            joint.add(p);
        }
        a.merge(&b);
        assert_eq!(a.count(), 6);
        for (x, y) in a.weights().iter().zip(joint.weights()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn zero_query_scores_zero() {
        let mut mem = OuterProductMemory::new(4);
        mem.add(&[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(mem.score(&[0.0; 4]), 0.0);
        assert_eq!(mem.score_support(&[]), 0.0);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let mut mem = OuterProductMemory::new(4);
        mem.add(&[1.0; 5]);
    }
}
