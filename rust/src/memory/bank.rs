//! A bank of class memories stacked contiguously — the unit the scorers
//! (native and PJRT) operate on.
//!
//! Layout: `q` row-major `d×d` matrices back to back, i.e. exactly the
//! `[q, d, d]` f32 operand of the AOT `class_scores` artifact.  The bank
//! is built once at index-build time and is immutable on the query path.

use crate::error::{Error, Result};
use crate::memory::cooccurrence::CooccurrenceMemory;
use crate::memory::outer::OuterProductMemory;
use crate::memory::StorageRule;

/// Immutable stacked class memories.
#[derive(Debug, Clone)]
pub struct MemoryBank {
    dim: usize,
    n_classes: usize,
    /// `[q * d * d]` row-major stacked weights.
    weights: Vec<f32>,
    /// Patterns stored per class.
    counts: Vec<usize>,
    rule: StorageRule,
}

impl MemoryBank {
    /// Build a bank from per-class pattern lists.
    ///
    /// `classes[i]` is the flat row-major member matrix of class `i`
    /// (`len = k_i * dim`).
    pub fn build(
        dim: usize,
        classes: &[&[f32]],
        rule: StorageRule,
    ) -> Result<Self> {
        let n_classes = classes.len();
        if n_classes == 0 {
            return Err(Error::Config("memory bank needs >= 1 class".into()));
        }
        let mut weights = Vec::with_capacity(n_classes * dim * dim);
        let mut counts = Vec::with_capacity(n_classes);
        for members in classes {
            if members.len() % dim != 0 {
                return Err(Error::Shape(format!(
                    "class member buffer len {} not a multiple of dim {dim}",
                    members.len()
                )));
            }
            match rule {
                StorageRule::Sum => {
                    let mut mem = OuterProductMemory::new(dim);
                    for row in members.chunks_exact(dim) {
                        mem.add(row);
                    }
                    counts.push(mem.count());
                    weights.extend_from_slice(mem.weights());
                }
                StorageRule::Max => {
                    let mut mem = CooccurrenceMemory::new(dim);
                    for row in members.chunks_exact(dim) {
                        mem.add(row);
                    }
                    counts.push(mem.count());
                    weights.extend(mem.weights());
                }
            }
        }
        Ok(MemoryBank { dim, n_classes, weights, counts, rule })
    }

    /// Reassemble a bank from persisted parts (see `index::persist`).
    pub fn from_parts(
        dim: usize,
        weights: Vec<f32>,
        counts: Vec<usize>,
        rule: StorageRule,
    ) -> Result<Self> {
        let n_classes = counts.len();
        if n_classes == 0 {
            return Err(Error::Config("memory bank needs >= 1 class".into()));
        }
        if weights.len() != n_classes * dim * dim {
            return Err(Error::Shape(format!(
                "weights len {} != q*d*d = {}",
                weights.len(),
                n_classes * dim * dim
            )));
        }
        Ok(MemoryBank { dim, n_classes, weights, counts, rule })
    }

    /// Online insert: fold `x` into class `i`'s memory in place.
    pub fn add_to_class(&mut self, i: usize, x: &[f32]) {
        assert_eq!(x.len(), self.dim, "pattern dim mismatch");
        let sz = self.dim * self.dim;
        let w = &mut self.weights[i * sz..(i + 1) * sz];
        match self.rule {
            StorageRule::Sum => {
                for (l, &xl) in x.iter().enumerate() {
                    if xl == 0.0 {
                        continue;
                    }
                    let row = &mut w[l * self.dim..(l + 1) * self.dim];
                    for (wm, &xm) in row.iter_mut().zip(x) {
                        *wm += xl * xm;
                    }
                }
            }
            StorageRule::Max => {
                for (l, &xl) in x.iter().enumerate() {
                    let row = &mut w[l * self.dim..(l + 1) * self.dim];
                    for (wm, &xm) in row.iter_mut().zip(x) {
                        let v = xl * xm;
                        if v > *wm {
                            *wm = v;
                        }
                    }
                }
            }
        }
        self.counts[i] += 1;
    }

    /// Online remove (sum rule only — the max rule is not invertible).
    pub fn remove_from_class(&mut self, i: usize, x: &[f32]) -> Result<()> {
        if self.rule != StorageRule::Sum {
            return Err(Error::Config(
                "online removal requires the sum rule (max rule is not invertible)"
                    .into(),
            ));
        }
        assert_eq!(x.len(), self.dim, "pattern dim mismatch");
        if self.counts[i] == 0 {
            return Err(Error::Config(format!("class {i} is empty")));
        }
        let sz = self.dim * self.dim;
        let w = &mut self.weights[i * sz..(i + 1) * sz];
        for (l, &xl) in x.iter().enumerate() {
            if xl == 0.0 {
                continue;
            }
            let row = &mut w[l * self.dim..(l + 1) * self.dim];
            for (wm, &xm) in row.iter_mut().zip(x) {
                *wm -= xl * xm;
            }
        }
        self.counts[i] -= 1;
        Ok(())
    }

    /// Vector dimensionality `d`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of classes `q`.
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Storage rule used to build the bank.
    pub fn rule(&self) -> StorageRule {
        self.rule
    }

    /// Patterns stored in class `i`.
    pub fn count(&self, i: usize) -> usize {
        self.counts[i]
    }

    /// The full `[q, d, d]` stacked buffer (PJRT operand).
    pub fn stacked(&self) -> &[f32] {
        &self.weights
    }

    /// Weight matrix of class `i`.
    pub fn class_weights(&self, i: usize) -> &[f32] {
        let sz = self.dim * self.dim;
        &self.weights[i * sz..(i + 1) * sz]
    }

    /// Score one query against every class (reference scalar path;
    /// the optimized batched path lives in [`crate::memory::score`]).
    pub fn score_query(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dim, "query dim mismatch");
        (0..self.n_classes)
            .map(|i| {
                let w = self.class_weights(i);
                let mut total = 0f32;
                for (l, &xl) in x.iter().enumerate() {
                    if xl == 0.0 {
                        continue;
                    }
                    let row = &w[l * self.dim..(l + 1) * self.dim];
                    let mut acc = 0f32;
                    for (wm, &xm) in row.iter().zip(x) {
                        acc += wm * xm;
                    }
                    total += xl * acc;
                }
                total
            })
            .collect()
    }

    /// Support-only scores for a binary sparse query (c²·q cost path).
    pub fn score_query_support(&self, support: &[u32]) -> Vec<f32> {
        (0..self.n_classes)
            .map(|i| {
                let w = self.class_weights(i);
                let mut total = 0f32;
                for &l in support {
                    let row = &w[l as usize * self.dim..(l as usize + 1) * self.dim];
                    for &m in support {
                        total += row[m as usize];
                    }
                }
                total
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn members(rng: &mut Rng, k: usize, d: usize) -> Vec<f32> {
        (0..k * d)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect()
    }

    #[test]
    fn build_and_shapes() {
        let mut rng = Rng::new(1);
        let d = 8;
        let c0 = members(&mut rng, 3, d);
        let c1 = members(&mut rng, 5, d);
        let bank =
            MemoryBank::build(d, &[&c0, &c1], StorageRule::Sum).unwrap();
        assert_eq!(bank.n_classes(), 2);
        assert_eq!(bank.stacked().len(), 2 * d * d);
        assert_eq!(bank.count(0), 3);
        assert_eq!(bank.count(1), 5);
    }

    #[test]
    fn score_query_matches_naive() {
        let mut rng = Rng::new(2);
        let d = 16;
        let c0 = members(&mut rng, 4, d);
        let c1 = members(&mut rng, 4, d);
        let bank = MemoryBank::build(d, &[&c0, &c1], StorageRule::Sum).unwrap();
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let scores = bank.score_query(&x);
        for (ci, class) in [&c0, &c1].iter().enumerate() {
            let want: f32 = class
                .chunks_exact(d)
                .map(|p| {
                    let dot: f32 = p.iter().zip(&x).map(|(a, b)| a * b).sum();
                    dot * dot
                })
                .sum();
            assert!((scores[ci] - want).abs() < 1e-2, "class {ci}");
        }
    }

    #[test]
    fn own_class_wins_for_stored_query() {
        let mut rng = Rng::new(3);
        let d = 64;
        let cls: Vec<Vec<f32>> = (0..6).map(|_| members(&mut rng, 4, d)).collect();
        let refs: Vec<&[f32]> = cls.iter().map(|c| c.as_slice()).collect();
        let bank = MemoryBank::build(d, &refs, StorageRule::Sum).unwrap();
        let x = &cls[4][0..d]; // first member of class 4
        let scores = bank.score_query(x);
        let argmax = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 4);
    }

    #[test]
    fn max_rule_bank_builds() {
        let mut rng = Rng::new(4);
        let d = 8;
        let c0: Vec<f32> =
            (0..3 * d).map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 }).collect();
        let bank = MemoryBank::build(d, &[&c0], StorageRule::Max).unwrap();
        assert_eq!(bank.rule(), StorageRule::Max);
        // all weights finite (sentinel mapped to 0)
        assert!(bank.stacked().iter().all(|w| w.is_finite()));
    }

    #[test]
    fn support_scores_match_dense_binary() {
        let mut rng = Rng::new(5);
        let d = 32;
        let c0: Vec<f32> =
            (0..6 * d).map(|_| if rng.bernoulli(0.15) { 1.0 } else { 0.0 }).collect();
        let c1: Vec<f32> =
            (0..6 * d).map(|_| if rng.bernoulli(0.15) { 1.0 } else { 0.0 }).collect();
        let bank = MemoryBank::build(d, &[&c0, &c1], StorageRule::Sum).unwrap();
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.15) { 1.0 } else { 0.0 }).collect();
        let support: Vec<u32> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == 1.0)
            .map(|(i, _)| i as u32)
            .collect();
        let dense = bank.score_query(&x);
        let sparse = bank.score_query_support(&support);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_bank_rejected() {
        assert!(MemoryBank::build(4, &[], StorageRule::Sum).is_err());
    }
}
