//! Cooccurrence (max-rule) associative memory — the variant of [19]
//! (Yu, Gripon, Jiang, Jégou 2015) evaluated in the paper's §5.1.1
//! ablation: instead of *adding* contributions from distinct messages,
//! take the *maximum*:
//!
//! `W[l,m] = max_μ x^μ_l x^μ_m`
//!
//! For binary 0/1 patterns this is the OR of the outer products (the
//! classic Willshaw/Gripon-Berrou storage rule).  The paper reports
//! "small improvements in every case, even though they are not
//! significant"; our ablation bench reproduces that comparison.

/// Dense d×d max-rule memory.
#[derive(Debug, Clone, PartialEq)]
pub struct CooccurrenceMemory {
    dim: usize,
    w: Vec<f32>,
    count: usize,
}

impl CooccurrenceMemory {
    /// Empty memory of dimension `d`.
    pub fn new(dim: usize) -> Self {
        CooccurrenceMemory { dim, w: vec![f32::NEG_INFINITY; dim * dim], count: 0 }
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of stored patterns.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Raw weights; entries never touched by a pattern are 0 after
    /// the first `add` normalization (see `weights`).
    pub fn weights(&self) -> Vec<f32> {
        self.w
            .iter()
            .map(|&v| if v == f32::NEG_INFINITY { 0.0 } else { v })
            .collect()
    }

    /// Store a pattern: `W[l,m] = max(W[l,m], x_l x_m)`.
    pub fn add(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim, "pattern dim mismatch");
        for (l, &xl) in x.iter().enumerate() {
            let row = &mut self.w[l * self.dim..(l + 1) * self.dim];
            for (wm, &xm) in row.iter_mut().zip(x) {
                let v = xl * xm;
                if v > *wm {
                    *wm = v;
                }
            }
        }
        self.count += 1;
    }

    /// Bilinear score against the max-rule weights (entries never written
    /// count as 0).
    pub fn score(&self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.dim);
        let mut total = 0f32;
        for (l, &xl) in x.iter().enumerate() {
            if xl == 0.0 {
                continue;
            }
            let row = &self.w[l * self.dim..(l + 1) * self.dim];
            let mut acc = 0f32;
            for (wm, &xm) in row.iter().zip(x) {
                if xm != 0.0 && *wm != f32::NEG_INFINITY {
                    acc += *wm * xm;
                }
            }
            total += xl * acc;
        }
        total
    }

    /// Support-only score for binary sparse queries (c² cost).
    pub fn score_support(&self, support: &[u32]) -> f32 {
        let mut total = 0f32;
        for &l in support {
            let row = &self.w[l as usize * self.dim..(l as usize + 1) * self.dim];
            for &m in support {
                let v = row[m as usize];
                if v != f32::NEG_INFINITY {
                    total += v;
                }
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn binary_max_rule_is_or() {
        let mut mem = CooccurrenceMemory::new(3);
        mem.add(&[1.0, 1.0, 0.0]);
        mem.add(&[0.0, 1.0, 1.0]);
        let w = mem.weights();
        // union of the two outer products, entries in {0,1}
        let want = [
            1.0, 1.0, 0.0, //
            1.0, 1.0, 1.0, //
            0.0, 1.0, 1.0,
        ];
        assert_eq!(w, want);
    }

    #[test]
    fn stored_sparse_pattern_scores_c_squared() {
        // for binary OR weights, a stored pattern with c ones scores c²
        let mut mem = CooccurrenceMemory::new(8);
        let x = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        mem.add(&x);
        assert_eq!(mem.score(&x), 9.0); // c=3 -> 9
    }

    #[test]
    fn max_rule_bounded_by_sum_rule_for_binary() {
        use crate::memory::outer::OuterProductMemory;
        let mut rng = Rng::new(5);
        let d = 32;
        let mut max_mem = CooccurrenceMemory::new(d);
        let mut sum_mem = OuterProductMemory::new(d);
        for _ in 0..15 {
            let p: Vec<f32> =
                (0..d).map(|_| if rng.bernoulli(0.15) { 1.0 } else { 0.0 }).collect();
            max_mem.add(&p);
            sum_mem.add(&p);
        }
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.15) { 1.0 } else { 0.0 }).collect();
        assert!(max_mem.score(&x) <= sum_mem.score(&x) + 1e-4);
    }

    #[test]
    fn score_support_matches_dense_binary() {
        let mut rng = Rng::new(6);
        let d = 40;
        let mut mem = CooccurrenceMemory::new(d);
        for _ in 0..10 {
            let p: Vec<f32> =
                (0..d).map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 }).collect();
            mem.add(&p);
        }
        let x: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 }).collect();
        let support: Vec<u32> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == 1.0)
            .map(|(i, _)| i as u32)
            .collect();
        assert!((mem.score(&x) - mem.score_support(&support)).abs() < 1e-4);
    }

    #[test]
    fn idempotent_storage() {
        let mut a = CooccurrenceMemory::new(4);
        let mut b = CooccurrenceMemory::new(4);
        let p = [1.0f32, 0.0, 1.0, 0.0];
        a.add(&p);
        b.add(&p);
        b.add(&p);
        b.add(&p);
        assert_eq!(a.weights(), b.weights()); // max rule saturates
        assert_eq!(b.count(), 3);
    }
}
