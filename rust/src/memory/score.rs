//! Optimized native batched scorer — the CPU mirror of the Pallas kernel.
//!
//! Computes `S[b, i] = x_bᵀ W_i x_b` for a `[q, d, d]` stacked bank and a
//! `[B, d]` query batch.  The kernel is restructured the same way as the
//! L1 pallas kernel: per class, one `W_i · x_b` mat-vec fused over the
//! query batch (so each cache line of `W_i` is read once per batch, not
//! once per query), then a dot against the query.  Classes are
//! rayon-parallel: each class's `d²` weight slab is touched by exactly
//! one thread (no false sharing).

use crate::search::Kernels;
use crate::util::par::parallel_map;

/// Batched bilinear scores: `S[b, i] = x_bᵀ W_i x_b`.
///
/// * `stacked`: `[q * d * d]` row-major class memories
/// * `queries`: `[batch * d]` row-major query block
/// * `kernels`: the dispatch handle whose wide-dot backend computes the
///   per-row `W_i[l] · x_b` products (see [`Kernels::dot_wide`])
///
/// Returns `[batch * q]` row-major scores.
pub fn score_batch(
    stacked: &[f32],
    queries: &[f32],
    dim: usize,
    q: usize,
    kernels: Kernels,
) -> Vec<f32> {
    assert_eq!(stacked.len(), q * dim * dim, "stacked bank shape");
    assert_eq!(queries.len() % dim, 0, "query buffer shape");
    let batch = queries.len() / dim;
    let mut out = vec![0f32; batch * q];
    // parallel over classes; each worker fills column i of the output
    let cols: Vec<Vec<f32>> = parallel_map(q, |i| {
        let w = &stacked[i * dim * dim..(i + 1) * dim * dim];
        let mut col = vec![0f32; batch];
        score_one_class(w, queries, dim, &mut col, kernels);
        col
    });
    for (i, col) in cols.iter().enumerate() {
        for b in 0..batch {
            out[b * q + i] = col[b];
        }
    }
    out
}

/// Scores of every query against a single class memory.
/// `col[b] = x_bᵀ W x_b`; one pass over `W` rows, all queries updated per
/// row (the batch-fusion that makes this bandwidth-optimal: each cache
/// line of `W` is touched once per batch, not once per query).
#[inline]
fn score_one_class(
    w: &[f32],
    queries: &[f32],
    dim: usize,
    col: &mut [f32],
    kernels: Kernels,
) {
    let batch = col.len();
    for (l, row) in w.chunks_exact(dim).enumerate() {
        for b in 0..batch {
            let x = &queries[b * dim..(b + 1) * dim];
            let xl = x[l];
            if xl == 0.0 {
                continue;
            }
            col[b] += xl * kernels.dot_wide(row, x);
        }
    }
}

/// Support-only batched scoring for binary sparse queries: cost `c²` per
/// (query, class), the paper's sparse fast path.
pub fn score_batch_support(
    stacked: &[f32],
    supports: &[Vec<u32>],
    dim: usize,
    q: usize,
) -> Vec<f32> {
    assert_eq!(stacked.len(), q * dim * dim, "stacked bank shape");
    let batch = supports.len();
    let avg_c = supports.iter().map(|s| s.len()).sum::<usize>() / batch.max(1);
    if avg_c >= 16 {
        // large supports: class-outer, so each class's d² slab is brought
        // into cache once and scored against the whole batch (measured
        // ~1.4x on the Santander shape c=33, d=369)
        let cols: Vec<Vec<f32>> = parallel_map(q, |i| {
            let w = &stacked[i * dim * dim..(i + 1) * dim * dim];
            let mut col = vec![0f32; batch];
            for (b, support) in supports.iter().enumerate() {
                let mut total = 0f32;
                for &l in support {
                    let row = &w[l as usize * dim..(l as usize + 1) * dim];
                    for &m in support {
                        total += row[m as usize];
                    }
                }
                col[b] = total;
            }
            col
        });
        let mut out = vec![0f32; batch * q];
        for (i, col) in cols.iter().enumerate() {
            for b in 0..batch {
                out[b * q + i] = col[b];
            }
        }
        out
    } else {
        // tiny supports (e.g. the paper's c=8): per-query iteration wins
        // (the touched lines fit cache either way; fewer loop-nest
        // overheads per score)
        let rows: Vec<Vec<f32>> = parallel_map(batch, |b| {
            let support = &supports[b];
            let mut row_out = vec![0f32; q];
            for (i, slot) in row_out.iter_mut().enumerate() {
                let w = &stacked[i * dim * dim..(i + 1) * dim * dim];
                let mut total = 0f32;
                for &l in support {
                    let row = &w[l as usize * dim..(l as usize + 1) * dim];
                    for &m in support {
                        total += row[m as usize];
                    }
                }
                *slot = total;
            }
            row_out
        });
        rows.concat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::memory::bank::MemoryBank;
    use crate::memory::StorageRule;

    fn random_bank(rng: &mut Rng, q: usize, k: usize, d: usize) -> MemoryBank {
        let classes: Vec<Vec<f32>> = (0..q)
            .map(|_| {
                (0..k * d)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = classes.iter().map(|c| c.as_slice()).collect();
        MemoryBank::build(d, &refs, StorageRule::Sum).unwrap()
    }

    #[test]
    fn batch_matches_scalar_path() {
        let mut rng = Rng::new(1);
        let (q, k, d, b) = (6, 4, 32, 5);
        let bank = random_bank(&mut rng, q, k, d);
        let queries: Vec<f32> = (0..b * d)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let got = score_batch(bank.stacked(), &queries, d, q, Kernels::select());
        for bi in 0..b {
            let want = bank.score_query(&queries[bi * d..(bi + 1) * d]);
            for i in 0..q {
                assert!(
                    (got[bi * q + i] - want[i]).abs() < 1e-2,
                    "b={bi} i={i} got={} want={}",
                    got[bi * q + i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn batch_handles_odd_dims() {
        let mut rng = Rng::new(2);
        for d in [3, 7, 17, 33] {
            let bank = random_bank(&mut rng, 3, 2, d);
            let queries: Vec<f32> = (0..2 * d).map(|_| rng.normal() as f32).collect();
            let got = score_batch(bank.stacked(), &queries, d, 3, Kernels::select());
            for bi in 0..2 {
                let want = bank.score_query(&queries[bi * d..(bi + 1) * d]);
                for i in 0..3 {
                    assert!((got[bi * 3 + i] - want[i]).abs() < 1e-2);
                }
            }
        }
    }

    #[test]
    fn support_batch_matches_dense() {
        let mut rng = Rng::new(3);
        let (q, d) = (4, 48);
        let classes: Vec<Vec<f32>> = (0..q)
            .map(|_| {
                (0..5 * d)
                    .map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = classes.iter().map(|c| c.as_slice()).collect();
        let bank = MemoryBank::build(d, &refs, StorageRule::Sum).unwrap();
        let queries: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                (0..d)
                    .map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        let supports: Vec<Vec<u32>> = queries
            .iter()
            .map(|x| {
                x.iter()
                    .enumerate()
                    .filter(|(_, &v)| v == 1.0)
                    .map(|(i, _)| i as u32)
                    .collect()
            })
            .collect();
        let flat: Vec<f32> = queries.concat();
        let dense = score_batch(bank.stacked(), &flat, d, q, Kernels::select());
        let sparse = score_batch_support(bank.stacked(), &supports, d, q);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn single_query_single_class() {
        let bank_w = vec![1.0f32, 0.0, 0.0, 2.0]; // W = diag(1,2), d=2
        let queries = vec![3.0f32, 4.0];
        let s = score_batch(&bank_w, &queries, 2, 1, Kernels::select());
        assert_eq!(s, vec![9.0 + 32.0]); // 1*9 + 2*16
    }

    #[test]
    #[should_panic]
    fn wrong_stack_size_panics() {
        score_batch(&[0.0; 10], &[0.0; 4], 2, 2, Kernels::select());
    }
}
