//! Poison-tolerant lock helpers for the serving hot paths.
//!
//! `Mutex::lock().unwrap()` turns one panicked handler thread into a
//! permanent panic for every subsequent request: the first panic
//! poisons the mutex, and every later `.unwrap()` on the poison error
//! re-panics, cascading a single bad request into a dead server.  The
//! serving stack instead recovers the guard with
//! [`PoisonError::into_inner`]: all the state these mutexes protect
//! (channel handles, join handles, counters, cached snapshots) stays
//! internally consistent even if a holder panicked mid-critical-section
//! — each critical section either moves a value atomically or updates a
//! counter, so "last write before the panic" is always a valid state.
//!
//! Kept deliberately tiny: two free functions, so every call site reads
//! as what it is and `amlint`'s lock rules can recognise the receivers.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`], recovering the guard if a previous holder
/// panicked.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    fn poisoned_mutex() -> Arc<Mutex<u32>> {
        let m = Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let _ = std::panic::catch_unwind(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the mutex");
        });
        assert!(m.is_poisoned());
        m
    }

    #[test]
    fn lock_unpoisoned_recovers_the_value() {
        let m = poisoned_mutex();
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn lock_unpoisoned_is_a_plain_lock_when_healthy() {
        let m = Mutex::new(1);
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 2);
    }

    #[test]
    fn wait_timeout_unpoisoned_survives_poisoning() {
        let m = poisoned_mutex();
        let cv = Condvar::new();
        let guard = lock_unpoisoned(&m);
        let (guard, timed_out) =
            wait_timeout_unpoisoned(&cv, guard, Duration::from_millis(1));
        assert!(timed_out.timed_out());
        assert_eq!(*guard, 7);
    }
}
