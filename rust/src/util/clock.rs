//! Process-wide monotonic clock: nanoseconds since the first call in
//! this process.  One shared origin means timestamps taken anywhere in
//! the serving stack (coordinator workers, router workers, trace
//! emission) are directly comparable, which the windowed histograms and
//! span records rely on.

use std::sync::OnceLock;
use std::time::Instant;

static ORIGIN: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds elapsed since the process clock origin (the first call
/// to this function).  Monotonic and cheap — one atomic load plus an
/// `Instant::elapsed` after initialization.
pub fn monotonic_ns() -> u64 {
    ORIGIN.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_and_shared_origin() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a, "clock went backwards: {a} -> {b}");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let c = monotonic_ns();
        assert!(c > b, "clock did not advance across a sleep");
    }
}
