//! Minimal JSON parser/serializer (no external deps are available in the
//! offline build, so this is one of the substrates we build ourselves).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for the AOT manifest, config files, and result reports — all formats
//! this repo itself produces.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (stored as f64, like JS).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Data(format!(
                "json: trailing garbage at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As u64.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Data(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line\n\"quoted\"\ttab\\".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn serialize_roundtrip_object() {
        let mut m = BTreeMap::new();
        m.insert("n".to_string(), Json::Num(3.0));
        m.insert("s".to_string(), Json::Str("v".into()));
        m.insert("a".to_string(), Json::Arr(vec![Json::Bool(true), Json::Null]));
        let v = Json::Obj(m);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn accessor_types() {
        let v = Json::parse(r#"{"i": 7, "f": 1.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("i").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
    }
}
