//! Tiny CLI argument parser: `--key value`, `--flag`, and positional
//! arguments.  (The offline build has no clap.)

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (without argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = iter.next().ok_or_else(|| {
                        Error::Config(format!("--{name} expects a value"))
                    })?;
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Positional argument `i`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(text) => text.parse::<T>().map_err(|_| {
                Error::Config(format!("--{key}: cannot parse '{text}'"))
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_args() {
        let a = Args::parse(argv("eval --figure 3 --all --scale 2.5"), &["all"]).unwrap();
        assert_eq!(a.pos(0), Some("eval"));
        assert_eq!(a.get("figure"), Some("3"));
        assert!(a.flag("all"));
        assert_eq!(a.get_parse::<f64>("scale", 1.0).unwrap(), 2.5);
        assert_eq!(a.get_parse::<u64>("seed", 42).unwrap(), 42);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("--k=128 --label=x=y"), &[]).unwrap();
        assert_eq!(a.get("k"), Some("128"));
        assert_eq!(a.get("label"), Some("x=y"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("--figure"), &[]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(argv("--n xyz"), &[]).unwrap();
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }
}
