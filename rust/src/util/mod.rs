//! In-tree utility substrates (the offline build has no serde/rayon/clap,
//! so these are built from scratch): JSON, scoped-thread parallelism,
//! poison-tolerant locking, and CLI argument parsing.

pub mod args;
pub mod clock;
pub mod json;
pub mod par;
pub mod sha256;
pub mod sync;

pub use args::Args;
pub use json::Json;
pub use par::{concurrent_map, parallel_map, parallel_map_items};
pub use sync::{lock_unpoisoned, wait_timeout_unpoisoned};
