//! In-tree utility substrates (the offline build has no serde/rayon/clap,
//! so these are built from scratch): JSON, scoped-thread parallelism,
//! and CLI argument parsing.

pub mod args;
pub mod json;
pub mod par;
pub mod sha256;

pub use args::Args;
pub use json::Json;
pub use par::{concurrent_map, parallel_map, parallel_map_items};
