//! Scoped-thread data parallelism (the offline build has no rayon).
//!
//! [`parallel_map`] splits the index range over `min(n, cores)` scoped
//! threads; work items should be coarse enough (≥ ~10µs) that the spawn
//! cost amortizes — exactly the granularity of this crate's uses
//! (per-class scoring slabs, per-query searches, per-database Monte-Carlo
//! batches).

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f` to every index in `0..n` in parallel; results are returned
/// in index order.  `f` must be `Sync` (called from many threads).
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let fref = &f;
            let nref = &next;
            let optr = &out_ptr;
            scope.spawn(move || loop {
                let i = nref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed by exactly one thread
                // via the atomic counter; slots are disjoint.
                unsafe {
                    *optr.0.add(i) = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("all slots filled")).collect()
}

/// Like [`parallel_map`] over a slice of items.
pub fn parallel_map_items<I, T, F>(items: &[I], f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    parallel_map(items.len(), |i| f(&items[i]))
}

/// Like [`parallel_map`] but with an explicit thread count that ignores
/// the core count.  Use for *latency-bound* work (e.g. clients blocking
/// on a server channel): even on a single-core machine, `threads`
/// concurrent requests must be in flight for batching/backpressure to be
/// exercised.  For CPU-bound work prefer [`parallel_map`].
pub fn concurrent_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.clamp(1, n);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let fref = &f;
            let nref = &next;
            let optr = &out_ptr;
            scope.spawn(move || loop {
                let i = nref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed by exactly one thread
                // via the atomic counter; slots are disjoint.
                unsafe {
                    *optr.0.add(i) = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("all slots filled")).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: the pointer is only dereferenced at disjoint indices, each by a
// single thread, within the scope that owns the Vec, so moving the
// wrapper across threads cannot create aliased writes.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared references to the wrapper only ever `.add(i)` with
// indices claimed through the atomic counter — one writer per slot —
// so concurrent `&SendPtr` access is race-free.
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let got = parallel_map(1000, |i| i * 2);
        let want: Vec<usize> = (0..1000).map(|i| i * 2).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn items_variant() {
        let items = vec!["a", "bb", "ccc"];
        assert_eq!(parallel_map_items(&items, |s| s.len()), vec![1, 2, 3]);
    }

    #[test]
    fn actually_parallel_under_contention() {
        // all threads increment a shared atomic; total must be exact
        let counter = std::sync::atomic::AtomicUsize::new(0);
        parallel_map(10_000, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 10_000);
    }

    #[test]
    fn concurrent_map_runs_all_even_on_one_core() {
        // blocking-style rendezvous: with 4 threads, two tasks that wait
        // for each other can both make progress regardless of core count
        let barrier = std::sync::Barrier::new(4);
        let got = concurrent_map(4, 4, |i| {
            barrier.wait();
            i * 3
        });
        assert_eq!(got, vec![0, 3, 6, 9]);
    }

    #[test]
    fn heavy_items_balance() {
        // uneven work: correctness only (no timing assertion)
        let got = parallel_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..(i * 1000) as u64 {
                acc = acc.wrapping_add(j * j);
            }
            (i, acc)
        });
        for (i, (gi, _)) in got.iter().enumerate() {
            assert_eq!(i, *gi);
        }
    }
}
