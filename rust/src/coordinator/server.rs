//! The search server: front door + dynamic batcher + worker pool, all on
//! std threads (the offline build has no async runtime; channels provide
//! identical structure).
//!
//! Topology (vLLM-router-like, scaled to this system):
//!
//! ```text
//! clients --> sync_channel (bounded, backpressure) --> batcher thread
//!         --> batch channel --> N worker threads (each owns an Engine;
//!             PJRT clients are Rc-based and must stay on one thread)
//!         --> per-request rendezvous channel --> clients
//! ```
//!
//! Metrics (latency histograms, ops counters) are aggregated centrally
//! behind a mutex touched once per *batch*, not per request.

use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::{BatchScanStats, LatencyHistogram, OpsCounter, WindowedHistogram};
use crate::obs::quality::{
    sample_hit, QualityStats, RankHistogram, ShadowQueue, SurvivalStats,
};
use crate::obs::{prom, Registry, Trace, TraceSink};
use crate::search::Neighbor;
use crate::util::sync::lock_unpoisoned;

use super::batcher::run_batcher;
use super::engine::EngineFactory;
use super::protocol::{CoordinatorConfig, SearchRequest, SearchResponse};

/// Bound of the shadow-scan queue: sampled requests pending exact
/// re-execution.  Under load the oldest pending sample is dropped (and
/// counted) — the estimate degrades, the serving path never does.
const SHADOW_QUEUE_DEPTH: usize = 256;

/// One sampled request awaiting its shadow exact scan: the query, the
/// answer that was served, and the requested `k` (0 = index default).
struct ShadowSample {
    vector: Vec<f32>,
    served: Vec<Neighbor>,
    top_k: usize,
}

/// Shared sampling state for the shadow path: the deterministic
/// served-request counter (request `n` is sampled iff `n % every == 0`)
/// and the bounded queue to the shadow worker.
struct ShadowContext {
    every: u64,
    served: std::sync::atomic::AtomicU64,
    queue: Arc<ShadowQueue<ShadowSample>>,
}

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    /// End-to-end request latency (enqueue -> response ready).
    pub latency: LatencyHistogram,
    /// Scorer+scan batch service time.
    pub service: LatencyHistogram,
    /// Aggregated paper-model operation counts, split per stage
    /// (score/scan/aux) as reported by the engine.
    pub ops: OpsCounter,
    /// Class-grouped scan accounting: polls vs distinct class passes
    /// (the batching win of the class-major candidate scan).
    pub scan: BatchScanStats,
    /// Batches executed.
    pub batches: u64,
    /// Requests served.
    pub requests: u64,
    /// Requests answered with an explicit error response.
    pub errors: u64,
    /// Rolling-window view of the end-to-end latency: same samples as
    /// `latency`, but only the last ~10 s of them, so operators see
    /// current tail latency instead of a lifetime average.
    pub window: WindowedHistogram,
    /// Online recall estimate fed by the shadow exact-scan worker
    /// (all-zero when `quality_sample` is 0).
    pub quality: QualityStats,
    /// Always-on poll-selectivity telemetry: the polled rank of the
    /// class that produced each request's top-1 neighbor.
    pub served_from: RankHistogram,
    /// Always-on candidate-survival funnel (scanned → returned).
    pub survival: SurvivalStats,
}

impl ServerMetrics {
    /// Mean requests per batch (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Handle to a running search server.  `search` blocks the calling
/// thread; use one client thread per in-flight request (see the serve
/// command / benches for the load-generation pattern).
pub struct SearchServer {
    tx: Mutex<Option<SyncSender<SearchRequest>>>,
    metrics: Arc<Mutex<ServerMetrics>>,
    next_id: std::sync::atomic::AtomicU64,
    dim: usize,
    /// Database size, for clamping per-request `top_k` at the boundary.
    n_vectors: usize,
    /// Scan-representation footprint of the served index (STATS:
    /// `index.bytes` / `index.compressed_bytes`).
    footprint: crate::quant::IndexFootprint,
    /// Candidate-scan mode of the served index (STATS: `quant.mode`).
    quant_mode: &'static str,
    /// Rerank budget of the served index (0 = all; STATS: `quant.rerank`).
    quant_rerank: usize,
    /// Distance-kernel backend of the served index (STATS:
    /// `kernel.backend`).
    kernel_backend: &'static str,
    /// Trace sink shared with the worker threads; consulted at
    /// admission for sampling decisions.  `None` = tracing disabled.
    trace: Option<Arc<TraceSink>>,
    /// Engine recipe, kept for the EXPLAIN admin path (each explain
    /// builds a short-lived engine on the handler thread — the serving
    /// engines are thread-local to their workers and not shareable).
    factory: EngineFactory,
    /// `quality_sample` knob (0 = shadow sampling off).
    quality_sample: u64,
    /// Shadow-scan handoff shared with the worker threads (present iff
    /// `quality_sample > 0`).
    shadow: Option<Arc<ShadowQueue<ShadowSample>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    batcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    shadow_worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SearchServer {
    /// Start the server: one batcher thread + `config.workers` engine
    /// threads built from `factory`.
    pub fn start(factory: EngineFactory, config: CoordinatorConfig) -> Result<Self> {
        Self::start_traced(factory, config, None)
    }

    /// [`Self::start`] with an optional trace sink: sampled (or
    /// propagated) requests emit one per-stage span record per tier as
    /// JSON lines.  `None` is exactly [`Self::start`] — the request
    /// path does no tracing work at all.
    pub fn start_traced(
        factory: EngineFactory,
        config: CoordinatorConfig,
        trace: Option<Arc<TraceSink>>,
    ) -> Result<Self> {
        config.validate()?;
        let dim = factory.index.dim();
        let n_vectors = factory.index.len();
        let footprint = factory.index.footprint();
        let quant_mode = factory.index.quant_mode();
        let quant_rerank = factory.index.params().precision.rerank();
        let kernel_backend = factory.index.kernel_backend();
        let (req_tx, req_rx) = mpsc::sync_channel::<SearchRequest>(config.queue_depth);
        let (batch_tx, batch_rx) =
            mpsc::sync_channel::<Vec<SearchRequest>>(config.workers * 2);
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));

        let max_batch = config.max_batch;
        let max_wait = Duration::from_micros(config.max_wait_us);
        let batcher = std::thread::Builder::new()
            .name("amsearch-batcher".into())
            .spawn(move || run_batcher(req_rx, batch_tx, max_batch, max_wait))
            .map_err(|e| Error::Coordinator(format!("spawn batcher: {e}")))?;

        // shadow path: a dedicated worker re-executes sampled requests
        // as exhaustive exact scans, off the hot path, behind a bounded
        // drop-oldest queue (it competes for CPU only when samples
        // arrive; starving it costs estimate samples, not latency)
        let shadow_ctx = if config.quality_sample > 0 {
            let queue = Arc::new(ShadowQueue::<ShadowSample>::new(SHADOW_QUEUE_DEPTH));
            Some(Arc::new(ShadowContext {
                every: config.quality_sample,
                served: std::sync::atomic::AtomicU64::new(0),
                queue,
            }))
        } else {
            None
        };
        let shadow_worker = match &shadow_ctx {
            None => None,
            Some(ctx) => {
                let queue = ctx.queue.clone();
                let factory = factory.clone();
                let metrics = metrics.clone();
                let handle = std::thread::Builder::new()
                    .name("amsearch-shadow".into())
                    .spawn(move || {
                        let engine = match factory.build() {
                            Ok(e) => e,
                            Err(e) => {
                                eprintln!("shadow worker: engine build failed: {e}");
                                queue.close();
                                return;
                            }
                        };
                        while let Some(sample) = queue.pop() {
                            let k = if sample.top_k == 0 {
                                engine.index().params().top_k
                            } else {
                                sample.top_k
                            };
                            let truth = engine.exact_scan(&sample.vector, k);
                            let mut m = lock_unpoisoned(&metrics);
                            m.quality.record_comparison(&sample.served, &truth);
                        }
                    })
                    .map_err(|e| Error::Coordinator(format!("spawn shadow: {e}")))?;
                Some(handle)
            }
        };

        // single consumer side shared by worker threads
        let batch_rx: Arc<Mutex<Receiver<Vec<SearchRequest>>>> =
            Arc::new(Mutex::new(batch_rx));
        let mut workers = Vec::with_capacity(config.workers);
        for wi in 0..config.workers {
            let factory = factory.clone();
            let batch_rx = batch_rx.clone();
            let metrics = metrics.clone();
            let trace = trace.clone();
            let shadow_ctx = shadow_ctx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("amsearch-worker-{wi}"))
                .spawn(move || {
                    let engine = match factory.build() {
                        Ok(e) => e,
                        Err(e) => {
                            eprintln!("worker {wi}: engine build failed: {e}");
                            return;
                        }
                    };
                    loop {
                        // take one batch under the lock, release before work
                        let batch = {
                            let rx = lock_unpoisoned(&batch_rx);
                            // amlint: allow(lock_blocking, reason = "the guard IS the hand-off: idle workers queue on this lock until a batch arrives")
                            match rx.recv() {
                                Ok(b) => b,
                                Err(_) => return,
                            }
                        };
                        serve_one_batch(
                            &engine,
                            batch,
                            &metrics,
                            trace.as_deref(),
                            shadow_ctx.as_deref(),
                        );
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?;
            workers.push(handle);
        }

        Ok(SearchServer {
            tx: Mutex::new(Some(req_tx)),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(0),
            dim,
            n_vectors,
            footprint,
            quant_mode,
            quant_rerank,
            kernel_backend,
            trace,
            factory,
            quality_sample: config.quality_sample,
            shadow: shadow_ctx.map(|ctx| ctx.queue.clone()),
            workers: Mutex::new(workers),
            batcher: Mutex::new(Some(batcher)),
            shadow_worker: Mutex::new(shadow_worker),
        })
    }

    /// Submit a k-NN query without blocking for its result: the
    /// response (success *or* explicit error — the pipeline never drops
    /// an accepted request) is delivered on `resp` with `id` echoed in
    /// `SearchResponse::id`.  Many submissions may share one `resp`
    /// channel and be matched by id — this is how the TCP front door
    /// pipelines a whole connection into a single response funnel.
    /// `resp` must have capacity for the caller's in-flight window, so
    /// a slow consumer can never block a worker thread.
    ///
    /// Boundary validation: the vector dimension must match the index;
    /// `top_p = 0` / `top_k = 0` mean "use the index default"; `top_k`
    /// larger than the database is clamped to it (the response simply
    /// carries every vector, nearest first).  Blocks only while the
    /// bounded request queue is full (backpressure).
    ///
    /// `trace_id` = 0 means "untraced": when a trace sink is attached
    /// the admission sampler may still pick the request.  A non-zero id
    /// (propagated by a cluster router) is kept as-is so shard spans
    /// stitch into the router's trace.
    pub fn submit(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        id: u64,
        trace_id: u64,
        resp: SyncSender<SearchResponse>,
    ) -> Result<()> {
        if vector.len() != self.dim {
            return Err(Error::Shape(format!(
                "query dim {} != index dim {}",
                vector.len(),
                self.dim
            )));
        }
        let trace_id = match &self.trace {
            Some(sink) if trace_id == 0 => sink.sample_id(),
            _ => trace_id,
        };
        // clamp here so an absurd k never reaches the scan accumulators
        // (0 passes through: it selects the index default downstream)
        let top_k = top_k.min(self.n_vectors);
        let req = SearchRequest {
            id,
            vector,
            top_p,
            top_k,
            trace_id,
            enqueued: Instant::now(),
            resp,
        };
        let guard = lock_unpoisoned(&self.tx);
        let tx = guard
            .as_ref()
            .ok_or_else(|| Error::Coordinator("server shutting down".into()))?;
        // amlint: allow(lock_blocking, reason = "bounded-queue backpressure by design; holding the guard keeps shutdown from closing the channel mid-send")
        tx.send(req)
            .map_err(|_| Error::Coordinator("server shutting down".into()))
    }

    /// Submit a k-NN query and block until its response arrives.  See
    /// [`Self::submit`] for the boundary validation rules.
    pub fn search(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
    ) -> Result<SearchResponse> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.submit(vector, top_p, top_k, id, 0, resp_tx)?;
        let resp = resp_rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped request".into()))?;
        match resp.error {
            Some(msg) => Err(Error::Coordinator(msg)),
            None => Ok(resp),
        }
    }

    /// Dimension of the served index.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of vectors in the served index.
    pub fn n_vectors(&self) -> usize {
        self.n_vectors
    }

    /// Snapshot the serving metrics as a JSON document — the payload of
    /// the network STATS admin op, also reusable by load generators and
    /// bench artifacts (latency histograms via
    /// [`LatencyHistogram::to_json`]).
    pub fn stats_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let m = self.metrics();
        let mut o = std::collections::BTreeMap::new();
        // the net layer may relabel this (e.g. "shard" in a cluster)
        o.insert("role".to_string(), Json::Str("search".to_string()));
        o.insert("dim".to_string(), Json::Num(self.dim as f64));
        o.insert("n_vectors".to_string(), Json::Num(self.n_vectors as f64));
        o.insert("requests".to_string(), Json::Num(m.requests as f64));
        o.insert("batches".to_string(), Json::Num(m.batches as f64));
        o.insert(
            "mean_batch_size".to_string(),
            Json::Num(m.mean_batch_size()),
        );
        o.insert("ops_per_search".to_string(), Json::Num(m.ops.per_search()));
        o.insert(
            "scan_fusion".to_string(),
            Json::Num(m.scan.fusion_factor()),
        );
        // compressed-scan vs rerank op split (0/0 on an exact index)
        o.insert(
            "compressed_ops".to_string(),
            Json::Num(m.ops.compressed_ops as f64),
        );
        o.insert("rerank_ops".to_string(), Json::Num(m.ops.rerank_ops as f64));
        o.insert("index".to_string(), footprint_json(&self.footprint));
        o.insert(
            "quant".to_string(),
            quant_json(self.quant_mode, self.quant_rerank),
        );
        o.insert("kernel".to_string(), kernel_json(self.kernel_backend));
        o.insert(
            "store".to_string(),
            store_json(&self.factory.index.store_stats()),
        );
        o.insert("errors".to_string(), Json::Num(m.errors as f64));
        o.insert("latency".to_string(), m.latency.to_json());
        o.insert("service".to_string(), m.service.to_json());
        o.insert("window".to_string(), m.window.to_json());
        o.insert("selectivity".to_string(), selectivity_json(&m.served_from, &m.survival));
        // present iff sampling is configured, even before any sample
        // lands — scrapers can rely on the key's presence
        if self.quality_sample > 0 {
            o.insert("quality".to_string(), m.quality.to_json());
        }
        Json::Obj(o)
    }

    /// Replay one query through a fresh engine with full introspection —
    /// the EXPLAIN admin op (see [`super::engine::Engine::explain`]).
    pub fn explain(
        &self,
        vector: Vec<f32>,
        top_p: usize,
        top_k: usize,
        exact: bool,
    ) -> Result<crate::util::Json> {
        let engine = self.factory.build()?;
        engine.explain(&vector, top_p, top_k, exact)
    }

    /// Render the serving metrics as a Prometheus-style [`Registry`] —
    /// the payload of the network METRICS admin op.  Derived from the
    /// same single-lock snapshot as [`Self::stats_json`], so the two
    /// export surfaces can never disagree about whether a request has
    /// been counted.
    pub fn metrics_registry(&self) -> Registry {
        let m = self.metrics();
        let mut reg = Registry::default();
        let role = [("role", "search")];
        reg.counter(prom::M_REQUESTS, &role, m.requests);
        reg.counter(prom::M_BATCHES, &role, m.batches);
        reg.counter(prom::M_ERRORS, &role, m.errors);
        for (stage, v) in [
            ("score", m.ops.score_ops),
            ("scan", m.ops.scan_ops),
            ("compressed", m.ops.compressed_ops),
            ("rerank", m.ops.rerank_ops),
            ("aux", m.ops.aux_ops),
        ] {
            reg.counter(prom::M_OPS, &[("role", "search"), ("stage", stage)], v);
        }
        // vector-store I/O accounting; the counters stay at zero (and
        // residency equals the index footprint) on a resident store
        let st = self.factory.index.store_stats();
        reg.counter(prom::M_STORE_BYTES_READ, &role, st.bytes_read);
        reg.counter(prom::M_STORE_EXTENT_READS, &role, st.extent_reads);
        reg.counter(prom::M_STORE_CACHE_HITS, &role, st.cache_hits);
        reg.counter(prom::M_STORE_CACHE_MISSES, &role, st.cache_misses);
        reg.counter(prom::M_STORE_CACHE_EVICTIONS, &role, st.cache_evictions);
        reg.gauge(prom::M_STORE_RESIDENT_BYTES, &role, st.bytes_resident as f64);
        reg.histogram(prom::M_LATENCY, &role, &m.latency);
        reg.histogram(prom::M_SERVICE, &role, &m.service);
        reg.histogram(prom::M_WINDOW_LATENCY, &role, &m.window.windowed());
        // always-on poll-selectivity gauges
        reg.gauge(prom::M_QUALITY_TOP1_FRACTION, &role, m.served_from.top1_fraction());
        reg.gauge(prom::M_QUALITY_SURVIVAL, &role, m.survival.ratio());
        // sampled-quality families, exported (possibly at zero) whenever
        // sampling is configured so scrapes can assert their presence
        if self.quality_sample > 0 {
            reg.counter(prom::M_QUALITY_SAMPLES, &role, m.quality.samples);
            reg.counter(prom::M_QUALITY_DROPPED, &role, m.quality.dropped);
            reg.gauge(prom::M_QUALITY_RECALL, &role, m.quality.recall());
            reg.gauge(
                prom::M_QUALITY_RANK_DISPLACEMENT,
                &role,
                m.quality.mean_displacement(),
            );
            reg.gauge(
                prom::M_QUALITY_DISTANCE_ERROR,
                &role,
                m.quality.mean_distance_error(),
            );
        }
        reg
    }

    /// Snapshot the metrics — one lock acquisition, so every field of
    /// the returned struct describes the same instant (a STATS reply
    /// can never show a request counted in `requests` but missing from
    /// `latency`).
    pub fn metrics(&self) -> ServerMetrics {
        let m = lock_unpoisoned(&self.metrics);
        let mut quality = m.quality.clone();
        // the queue's drop counter lives outside the metrics lock (the
        // hot path must not take it); fold it in at snapshot time
        if let Some(shadow) = &self.shadow {
            quality.dropped = shadow.dropped();
        }
        ServerMetrics {
            latency: m.latency.clone(),
            service: m.service.clone(),
            ops: m.ops,
            scan: m.scan,
            batches: m.batches,
            requests: m.requests,
            errors: m.errors,
            window: m.window.clone(),
            quality,
            served_from: m.served_from.clone(),
            survival: m.survival,
        }
    }

    /// Graceful shutdown: stop accepting, drain, join threads.
    pub fn shutdown(&self) {
        // drop the sender -> batcher drains & exits -> workers exit
        *lock_unpoisoned(&self.tx) = None;
        if let Some(b) = lock_unpoisoned(&self.batcher).take() {
            let _ = b.join();
        }
        let mut workers = lock_unpoisoned(&self.workers);
        for w in workers.drain(..) {
            let _ = w.join();
        }
        drop(workers);
        // every worker has exited, so no further samples can arrive:
        // close the shadow queue (pop drains, then returns None)
        if let Some(shadow) = &self.shadow {
            shadow.close();
        }
        if let Some(s) = lock_unpoisoned(&self.shadow_worker).take() {
            let _ = s.join();
        }
        // flush the tail of buffered trace records before the process
        // (or test) inspects the trace file
        if let Some(trace) = &self.trace {
            trace.flush();
        }
    }
}

impl Drop for SearchServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The STATS `index` object: scan-representation footprint.  One shape
/// shared by the single-node server and the cluster router (which sums
/// its shards' footprints).
pub fn footprint_json(fp: &crate::quant::IndexFootprint) -> crate::util::Json {
    use crate::util::Json;
    let mut o = std::collections::BTreeMap::new();
    o.insert("bytes".to_string(), Json::Num(fp.bytes as f64));
    o.insert(
        "compressed_bytes".to_string(),
        Json::Num(fp.compressed_bytes as f64),
    );
    o.insert("compression_ratio".to_string(), Json::Num(fp.ratio()));
    Json::Obj(o)
}

/// The STATS `quant` object: scan mode + rerank budget.
pub fn quant_json(mode: &str, rerank: usize) -> crate::util::Json {
    use crate::util::Json;
    let mut o = std::collections::BTreeMap::new();
    o.insert("mode".to_string(), Json::Str(mode.to_string()));
    o.insert("rerank".to_string(), Json::Num(rerank as f64));
    Json::Obj(o)
}

/// The STATS `kernel` object: the distance-kernel backend selected at
/// index build/load ("scalar" | "sse2" | "avx2" | "neon"; the cluster
/// router reports "mixed" when its shards disagree).
pub fn kernel_json(backend: &str) -> crate::util::Json {
    use crate::util::Json;
    let mut o = std::collections::BTreeMap::new();
    o.insert("backend".to_string(), Json::Str(backend.to_string()));
    Json::Obj(o)
}

/// The STATS `store` object: where the exact member matrices live
/// (`resident` = RAM slabs, `paged` = the `.amdat` extent file) and the
/// I/O the paged path has done — bytes *read* from disk vs bytes held
/// *resident* in the extent cache, plus the cache hit/miss/eviction
/// counters behind that split.
pub fn store_json(st: &crate::store::StoreStats) -> crate::util::Json {
    use crate::util::Json;
    let mut o = std::collections::BTreeMap::new();
    o.insert("kind".to_string(), Json::Str(st.kind.to_string()));
    o.insert(
        "bytes_resident".to_string(),
        Json::Num(st.bytes_resident as f64),
    );
    o.insert("bytes_disk".to_string(), Json::Num(st.bytes_disk as f64));
    o.insert("bytes_read".to_string(), Json::Num(st.bytes_read as f64));
    o.insert(
        "extent_reads".to_string(),
        Json::Num(st.extent_reads as f64),
    );
    o.insert("cache_hits".to_string(), Json::Num(st.cache_hits as f64));
    o.insert(
        "cache_misses".to_string(),
        Json::Num(st.cache_misses as f64),
    );
    o.insert(
        "cache_evictions".to_string(),
        Json::Num(st.cache_evictions as f64),
    );
    o.insert(
        "cache_budget".to_string(),
        Json::Num(st.cache_budget as f64),
    );
    let lookups = st.cache_hits + st.cache_misses;
    o.insert(
        "cache_hit_rate".to_string(),
        Json::Num(if lookups == 0 {
            0.0
        } else {
            st.cache_hits as f64 / lookups as f64
        }),
    );
    Json::Obj(o)
}

/// The STATS `selectivity` object: always-on poll-selectivity telemetry.
/// One shape shared by the single-node server (`served_from` ranks are
/// polled-class ranks) and the cluster router (contacted-shard ranks).
pub fn selectivity_json(
    served_from: &RankHistogram,
    survival: &SurvivalStats,
) -> crate::util::Json {
    use crate::util::Json;
    let mut o = std::collections::BTreeMap::new();
    o.insert("served_from".to_string(), served_from.to_json());
    o.insert("survival".to_string(), survival.to_json());
    Json::Obj(o)
}

/// Execute one batch on an engine and complete every request.
///
/// When `trace` is attached, every request whose `trace_id` is non-zero
/// (or that crosses the sink's slow threshold) emits one span record:
/// `queue` (enqueue → youngest batch arrival), `batch` (youngest
/// arrival → execution start), `score`/`select`/`scan` (per-request
/// share of the engine stage timings), `respond` (response hand-off).
/// The spans sum to at most the end-to-end latency by construction.
fn serve_one_batch(
    engine: &super::engine::Engine,
    batch: Vec<SearchRequest>,
    metrics: &Arc<Mutex<ServerMetrics>>,
    trace: Option<&TraceSink>,
    shadow: Option<&ShadowContext>,
) {
    let started = Instant::now();
    let queries: Vec<(&[f32], usize, usize)> = batch
        .iter()
        .map(|r| (r.vector.as_slice(), r.top_p, r.top_k))
        .collect();
    match engine.serve_batch_detailed(&queries) {
        Ok(output) => {
            let super::engine::BatchOutput { mut responses, ops, scan, timings } =
                output;
            let service_ns = started.elapsed().as_nanos() as u64;
            let b = batch.len().max(1) as u64;
            let per_req_ns = service_ns / b;
            let requests = batch.len() as u64;
            // the youngest arrival separates queue wait (request-specific)
            // from batch formation (shared straggler wait)
            let youngest = batch
                .iter()
                .map(|r| r.enqueued)
                .max()
                .unwrap_or(started);
            let mut latency = LatencyHistogram::new();
            let mut lat_ns = Vec::with_capacity(batch.len());
            let mut completed = Vec::with_capacity(batch.len());
            // always-on poll-selectivity telemetry, folded into the
            // metrics lock below; computed outside it
            let mut served_from = RankHistogram::default();
            let mut survival = SurvivalStats::default();
            for (req, resp) in batch.into_iter().zip(responses.drain(..)) {
                let mut resp = resp;
                resp.id = req.id;
                resp.service_ns = per_req_ns;
                survival.record(resp.candidates, resp.neighbors.len());
                served_from.record(resp.neighbors.first().and_then(|n| {
                    let ci = engine.index().partition().class_of(n.id as usize);
                    resp.polled.iter().position(|&c| c == ci)
                }));
                // shadow sampling: clone the sampled request's inputs
                // and served answer into the bounded queue — the
                // response itself is delivered untouched (quality-
                // sampled serving stays bitwise-identical)
                if let Some(ctx) = shadow {
                    let n = 1 + ctx
                        .served
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if sample_hit(n, ctx.every) {
                        ctx.queue.push(ShadowSample {
                            vector: req.vector.clone(),
                            served: resp.neighbors.clone(),
                            top_k: req.top_k,
                        });
                    }
                }
                let ns = req.enqueued.elapsed().as_nanos() as u64;
                latency.record_ns(ns);
                lat_ns.push(ns);
                completed.push((req.resp, resp, req.trace_id, req.enqueued));
            }
            // metrics BEFORE completing requests: a client must never
            // observe its response while its own request is uncounted.
            // op counts merge with their per-stage split intact (the old
            // path lumped the per-request totals into score_ops).
            {
                let mut m = lock_unpoisoned(metrics);
                m.batches += 1;
                m.requests += requests;
                m.ops.merge(&ops);
                m.scan.merge(&scan);
                m.service.record_ns(service_ns);
                m.latency.merge(&latency);
                for &ns in &lat_ns {
                    m.window.record_ns(ns);
                }
                m.served_from.merge(&served_from);
                m.survival.merge(&survival);
            }
            for (tx, resp, trace_id, enqueued) in completed {
                let Some(sink) = trace else {
                    let _ = tx.send(resp); // receiver may have timed out
                    continue;
                };
                // slow outliers are force-sampled even when the sampler
                // skipped them at admission
                let tid = if trace_id != 0 {
                    trace_id
                } else if sink.slow_ns() > 0
                    && enqueued.elapsed().as_nanos() as u64 >= sink.slow_ns()
                {
                    sink.force_id()
                } else {
                    0
                };
                if tid == 0 {
                    let _ = tx.send(resp);
                    continue;
                }
                let req_id = resp.id;
                let mut t = Trace::start(tid, "search", req_id);
                t.span_ns(
                    "queue",
                    youngest.duration_since(enqueued).as_nanos() as u64,
                );
                t.span_ns(
                    "batch",
                    started.duration_since(youngest).as_nanos() as u64,
                );
                t.span_ns("score", timings.score_ns / b);
                t.span_ns("select", timings.select_ns / b);
                t.span_ns("scan", timings.scan_ns / b);
                let send_started = Instant::now();
                let _ = tx.send(resp);
                t.span_ns("respond", send_started.elapsed().as_nanos() as u64);
                let rec =
                    t.finish_with_total(enqueued.elapsed().as_nanos() as u64);
                sink.emit(&rec);
            }
        }
        Err(e) => {
            // deliver an explicit error response to every request: the
            // pipeline guarantees exactly one response per accepted
            // request (a silent drop would hang remote clients whose
            // responses funnel through a shared per-connection channel)
            eprintln!("batch failed: {e}; failing {} requests", batch.len());
            let reason = format!("batch execution failed: {e}");
            {
                let mut m = lock_unpoisoned(metrics);
                m.errors += batch.len() as u64;
            }
            for req in batch {
                let resp = SearchResponse::failed(req.id, reason.clone());
                let _ = req.resp.send(resp);
            }
        }
    }
}
