//! Dynamic batcher: groups incoming requests into batches of at most
//! `max_batch`, waiting at most `max_wait` for stragglers — the standard
//! serving trade-off between batch efficiency (the class-grouped scan
//! and the AOT scorer both want full batches) and tail latency.
//!
//! Under sustained load the queue already holds a full batch when the
//! first request is taken, so the loop drains with non-blocking
//! `try_recv` first and only arms the deadline timer when the batch is
//! still short — the hot path forms a batch without a single clock read
//! or timed wait.

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

use super::protocol::{SearchRequest, SearchResponse};

/// Outcome of one fill attempt (internal).
enum Fill {
    /// Batch ready (full or deadline hit); keep looping.
    Ready,
    /// Producer side disconnected; flush and exit.
    Disconnected,
}

/// Drain immediately-available requests without blocking.
fn drain_ready(
    rx: &Receiver<SearchRequest>,
    batch: &mut Vec<SearchRequest>,
    max_batch: usize,
) -> Fill {
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(r) => batch.push(r),
            Err(TryRecvError::Empty) => return Fill::Ready,
            Err(TryRecvError::Disconnected) => return Fill::Disconnected,
        }
    }
    Fill::Ready
}

/// Wait out the batching window for stragglers.
fn wait_for_stragglers(
    rx: &Receiver<SearchRequest>,
    batch: &mut Vec<SearchRequest>,
    max_batch: usize,
    max_wait: Duration,
) -> Fill {
    let deadline = Instant::now() + max_wait;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return Fill::Disconnected,
        }
    }
    Fill::Ready
}

/// Deliver an explicit error response to every request in `batch`.
/// Part of the serving pipeline's "exactly one response per accepted
/// request" guarantee: a request must never be silently dropped, or a
/// remote client whose responses funnel through a shared channel would
/// hang forever waiting for an id that never arrives.
fn fail_batch(batch: Vec<SearchRequest>, reason: &str) {
    for req in batch {
        let resp = SearchResponse::failed(req.id, reason);
        let _ = req.resp.send(resp); // receiver may be gone; best effort
    }
}

/// Run the batching loop: read requests from `rx`, emit batches on `tx`.
/// Returns when `rx` disconnects (all pending requests flushed) or `tx`
/// disconnects (worker pool gone — every queued and future request is
/// answered with an error response until the producers disconnect).
pub fn run_batcher(
    rx: Receiver<SearchRequest>,
    tx: SyncSender<Vec<SearchRequest>>,
    max_batch: usize,
    max_wait: Duration,
) {
    loop {
        // block for the first request of the next batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // producers gone, nothing pending
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        // fast path: everything already queued, no timer involved
        let mut fill = drain_ready(&rx, &mut batch, max_batch);
        if matches!(fill, Fill::Ready) && batch.len() < max_batch {
            fill = wait_for_stragglers(&rx, &mut batch, max_batch, max_wait);
        }
        let disconnected = matches!(fill, Fill::Disconnected);
        if let Err(send_err) = tx.send(batch) {
            // workers gone: error-respond this batch, then keep draining
            // so no producer ever blocks on a queue nobody reads — every
            // request still receives a response, just a failed one
            fail_batch(send_err.0, "worker pool unavailable");
            while let Ok(req) = rx.recv() {
                fail_batch(vec![req], "worker pool unavailable");
            }
            return;
        }
        if disconnected {
            return; // producers gone, final batch flushed
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> (SearchRequest, mpsc::Receiver<super::super::SearchResponse>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (
            SearchRequest {
                id,
                vector: vec![0.0; 4],
                top_p: 1,
                top_k: 1,
                trace_id: 0,
                enqueued: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let h = std::thread::spawn(move || {
            run_batcher(in_rx, out_tx, 3, Duration::from_millis(50))
        });
        let mut keep = Vec::new();
        for i in 0..7 {
            let (r, rx) = req(i);
            keep.push(rx);
            in_tx.send(r).unwrap();
        }
        drop(in_tx);
        let mut sizes = Vec::new();
        let mut ids = Vec::new();
        while let Ok(batch) = out_rx.recv() {
            sizes.push(batch.len());
            ids.extend(batch.iter().map(|r| r.id));
        }
        h.join().unwrap();
        assert_eq!(ids, (0..7).collect::<Vec<u64>>()); // order preserved
        assert!(sizes.iter().all(|&s| s <= 3));
        assert_eq!(sizes.iter().sum::<usize>(), 7);
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        std::thread::spawn(move || {
            run_batcher(in_rx, out_tx, 8, Duration::from_millis(10))
        });
        let (r, _keep) = req(0);
        in_tx.send(r).unwrap();
        // no further traffic: the single request must come out anyway
        let batch = out_rx
            .recv_timeout(Duration::from_millis(500))
            .expect("deadline flush");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 0);
        drop(in_tx);
    }

    #[test]
    fn worker_loss_fails_requests_instead_of_dropping() {
        // the consumer side (worker pool) is gone before any batch is
        // sent: every request must still receive a response — an
        // explicit error one — and the batcher must keep draining
        // until the producers disconnect
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(1);
        drop(out_rx); // workers dead
        let h = std::thread::spawn(move || {
            run_batcher(in_rx, out_tx, 4, Duration::from_millis(5))
        });
        let mut receivers = Vec::new();
        for i in 0..6 {
            let (r, rx) = req(i);
            receivers.push(rx);
            in_tx.send(r).unwrap();
        }
        drop(in_tx);
        h.join().unwrap();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx
                .recv_timeout(Duration::from_secs(2))
                .unwrap_or_else(|_| panic!("request {i} got no response"));
            assert_eq!(resp.id, i as u64);
            let msg = resp.error.expect("must be an error response");
            assert!(msg.contains("worker pool"), "unexpected reason: {msg}");
        }
    }

    #[test]
    fn no_requests_lost_or_duplicated_under_load() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(8);
        std::thread::spawn(move || {
            run_batcher(in_rx, out_tx, 4, Duration::from_micros(200))
        });
        let n = 500u64;
        let sender = std::thread::spawn(move || {
            let mut keep = Vec::new();
            for i in 0..n {
                let (r, rx) = req(i);
                keep.push(rx);
                in_tx.send(r).unwrap();
                if i % 97 == 0 {
                    std::thread::sleep(Duration::from_micros(300));
                }
            }
            keep
        });
        let mut seen = Vec::new();
        while seen.len() < n as usize {
            let batch = out_rx
                .recv_timeout(Duration::from_secs(5))
                .expect("batches keep flowing");
            seen.extend(batch.iter().map(|r| r.id));
        }
        let _keep = sender.join().unwrap();
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), n as usize, "lost/duplicated requests");
        assert_eq!(seen, (0..n).collect::<Vec<u64>>(), "order broken");
    }
}
