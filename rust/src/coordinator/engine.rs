//! The per-worker search engine: an [`AmIndex`] plus a pluggable
//! [`ClassScorer`] backend (native or PJRT).
//!
//! Every request path is the **batched, class-grouped pipeline** —
//! single queries are a batch of one:
//!
//! 1. **score** — one scorer call for the whole batch (`[B, d]` in,
//!    `[B, q]` out);
//! 2. **select** — top-`p` classes per query from the score matrix;
//! 3. **scan** — the (query → polled classes) map is inverted and the
//!    candidate scan runs class-major: each polled class's member matrix
//!    is brought into cache once per *batch* (native:
//!    [`AmIndex::finish_batch`]; PJRT: one `class_distances` GEMM per
//!    class covering every query that polled it).
//!
//! The engine is deliberately *not* `Send`: the PJRT client is
//! `Rc`-based, so each worker thread constructs its own engine via an
//! [`EngineFactory`] and keeps it thread-local for its lifetime.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::index::{AmIndex, QueryResult};
use crate::metrics::{BatchScanStats, OpsCounter};
use crate::obs::quality::QualityStats;
use crate::runtime::{
    Backend, ClassScorer, Manifest, NativeScorer, PjrtDistances, PjrtScorer,
};
use crate::search::{invert_polled, top_p_largest, Neighbor, TopK};
use crate::util::Json;

use super::protocol::SearchResponse;

/// Wall-clock time spent in each pipeline stage of one batch, measured
/// around the stage boundaries of [`Engine::serve_batch_detailed`].  The
/// server divides these by the batch size to attribute per-request span
/// durations (`score`/`select`/`scan`) to sampled traces.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimings {
    /// Scorer call (`[B, d]` -> `[B, q]`).
    pub score_ns: u64,
    /// Top-`p` class selection.  `0` on the native path, where selection
    /// fuses into [`AmIndex::finish_batch`] and is accounted under
    /// `scan_ns`.
    pub select_ns: u64,
    /// Class-major candidate scan (including any quantized rerank).
    pub scan_ns: u64,
}

impl StageTimings {
    /// Sum of all stage durations (bounded above by the batch service
    /// time that wraps the engine call).
    pub fn total_ns(&self) -> u64 {
        self.score_ns
            .saturating_add(self.select_ns)
            .saturating_add(self.scan_ns)
    }
}

/// Everything one executed batch produced: per-request responses plus
/// the batch-level accounting the server aggregates per *batch*, not per
/// request.
#[derive(Debug)]
pub struct BatchOutput {
    /// One response skeleton per query (id/service time filled by the
    /// caller).
    pub responses: Vec<SearchResponse>,
    /// Per-stage operation counts summed over the batch.
    pub ops: OpsCounter,
    /// Class-grouped scan accounting (polls vs distinct class passes).
    pub scan: BatchScanStats,
    /// Per-stage wall-clock split of this batch.
    pub timings: StageTimings,
}

/// A ready-to-serve engine (one per worker thread).
pub struct Engine {
    index: Arc<AmIndex>,
    scorer: Box<dyn ClassScorer>,
    /// Optional PJRT candidate scanner (the AOT `class_distances` GEMM).
    /// When present and every class fits its capacity, the scan stage
    /// also runs through the compiled artifact; otherwise the native
    /// scan is used.
    scanner: Option<PjrtDistances>,
    /// Per-class member matrices (flat row-major), precomputed so the
    /// PJRT scan needs no per-query gather.
    class_members: Vec<Vec<f32>>,
}

impl Engine {
    /// Build with the native scorer.
    pub fn native(index: Arc<AmIndex>) -> Result<Self> {
        let scorer = NativeScorer::new(
            index.bank().stacked().to_vec(),
            index.dim(),
            index.params().n_classes,
        )?;
        Ok(Engine { index, scorer: Box::new(scorer), scanner: None, class_members: Vec::new() })
    }

    /// Build with the PJRT scorer (and, when an artifact matches, the
    /// PJRT candidate scanner) from an artifacts directory.
    pub fn pjrt(index: Arc<AmIndex>, artifacts_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = crate::runtime::cpu_client()?;
        let scorer = PjrtScorer::from_manifest(
            &client,
            &manifest,
            index.bank().stacked(),
            index.dim(),
            index.params().n_classes,
        )?;
        // candidate-scan artifact: usable when the largest class fits
        let max_class = (0..index.params().n_classes)
            .map(|i| index.partition().members(i).len())
            .max()
            .unwrap_or(0);
        let mut scanner = None;
        let mut class_members = Vec::new();
        // a quantized index scans codes through the two-stage compressed
        // pipeline; the f32 GEMM artifact would bypass it, so the native
        // scan path is used instead (the scorer still runs on PJRT).
        // a paged index keeps no member matrices in RAM to precompute
        // GEMM operands from, so its scan stays native too
        let scan_entries = if index.quant().is_none() && !index.is_paged() {
            manifest.entries()
        } else {
            &[]
        };
        for entry in scan_entries {
            if entry.kind == "class_distances" && entry.d == index.dim() {
                let Some(entry_k) = entry.k.filter(|&k| k >= max_class) else {
                    continue;
                };
                if let Ok(d) = PjrtDistances::from_manifest(
                    &client,
                    &manifest,
                    index.dim(),
                    entry_k,
                ) {
                    scanner = Some(d);
                    class_members = (0..index.params().n_classes)
                        .map(|i| {
                            index
                                .data()
                                .gather(index.partition().members(i))
                                .as_flat()
                                .to_vec()
                        })
                        .collect();
                    break;
                }
            }
        }
        Ok(Engine { index, scorer: Box::new(scorer), scanner, class_members })
    }

    /// True when the candidate scan also runs through PJRT.
    pub fn has_pjrt_scan(&self) -> bool {
        self.scanner.is_some()
    }

    /// Class-grouped PJRT candidate scan for a whole batch: inverts the
    /// (query → polled classes) map and submits **one `class_distances`
    /// GEMM per polled class per batch** (chunked by the artifact's
    /// fixed batch size), instead of one GEMM per (query, class) pair.
    /// Each query folds the streamed distances into its fused `TopK(k)`
    /// accumulator; empty polled sets simply leave the accumulator empty,
    /// which the protocol reports as `neighbors: []` ("no candidates").
    fn scan_pjrt_batch(
        &self,
        scanner: &PjrtDistances,
        queries: &[&[f32]],
        polled: Vec<Vec<u32>>,
        ks: &[usize],
        ops: &mut [OpsCounter],
    ) -> Result<Vec<QueryResult>> {
        let d = self.index.dim();
        let q = self.index.params().n_classes;
        let b = queries.len();
        let by_class = invert_polled(&polled, q);
        let mut best: Vec<TopK> =
            ks.iter().map(|&k| TopK::new(k.max(1))).collect();
        let mut candidates = vec![0usize; b];
        for (ci, queriers) in by_class.iter().enumerate() {
            if queriers.is_empty() {
                continue;
            }
            let members = &self.class_members[ci];
            let n_members = members.len() / d;
            if n_members == 0 {
                continue;
            }
            let ids = self.index.partition().members(ci);
            let mut flat = Vec::with_capacity(queriers.len() * d);
            for &bi in queriers {
                flat.extend_from_slice(queries[bi as usize]);
            }
            let dists = scanner.distances_chunked(members, n_members, &flat)?;
            for (row, &bi) in queriers.iter().enumerate() {
                let acc = &mut best[bi as usize];
                let row_dists = &dists[row * n_members..(row + 1) * n_members];
                for (j, &dist) in row_dists.iter().enumerate() {
                    acc.push(dist, ids[j]);
                }
                candidates[bi as usize] += n_members;
            }
        }
        let mut out = Vec::with_capacity(b);
        for ((bi, pol), acc) in polled.into_iter().enumerate().zip(best) {
            ops[bi].scan_ops += (candidates[bi] * d) as u64;
            ops[bi].searches += 1;
            out.push(QueryResult {
                neighbors: acc.into_neighbors(),
                polled: pol,
                candidates: candidates[bi],
            });
        }
        Ok(out)
    }

    /// The scorer backend in use.
    pub fn backend(&self) -> &'static str {
        self.scorer.backend()
    }

    /// The index served by this engine.
    pub fn index(&self) -> &AmIndex {
        &self.index
    }

    /// Ground-truth top-`k` for one query: an exhaustive exact scan over
    /// every stored vector, bypassing the poll *and* any quantized
    /// codes.  Distances go through the same pruned kernel dispatch as
    /// the exact serving scan, so on an exact-precision index a served
    /// answer that covered the whole database is bitwise-identical to
    /// this one.  This is the shadow worker's reference answer and the
    /// `explain --exact` baseline — never part of the serving path.  On
    /// a paged index this streams class extents class-major instead of
    /// the vid-order dataset walk; the top-`k` is identical either way
    /// ([`AmIndex::exhaustive_exact`]).
    pub fn exact_scan(&self, x: &[f32], k: usize) -> Vec<Neighbor> {
        let k = k.min(self.index.len()).max(1);
        self.index.exhaustive_exact(x, k)
    }

    /// Replay one query with full introspection: the class scores and
    /// poll decision (with its margin), per-class candidate counts, the
    /// candidate→neighbor funnel, final neighbors annotated with the
    /// polled rank of their source class, per-stage timings, and — with
    /// `exact` — the ground-truth diff against [`Self::exact_scan`].
    /// Admin path (the EXPLAIN frame): one pipeline call plus one extra
    /// scoring call, never used by serving.
    pub fn explain(&self, x: &[f32], top_p: usize, top_k: usize, exact: bool) -> Result<Json> {
        let d = self.index.dim();
        if x.len() != d {
            return Err(Error::Shape(format!(
                "explain: query dim {} != index dim {d}",
                x.len()
            )));
        }
        let q = self.index.params().n_classes;
        let store_before = self.index.store_stats();
        let out = self.serve_batch_detailed(&[(x, top_p, top_k)])?;
        let store_after = self.index.store_stats();
        let Some(resp) = out.responses.first() else {
            return Err(Error::Coordinator("explain: empty batch output".into()));
        };
        // the pipeline call doesn't expose its score matrix; re-score
        // the single query for introspection
        let scores = self.scorer.score(x)?;
        let p = if top_p == 0 { self.index.params().top_p } else { top_p }.min(q);
        let k = if top_k == 0 { self.index.params().top_k } else { top_k }
            .min(self.index.len())
            .max(1);
        let ranked = top_p_largest(&scores, q);

        let mut root = BTreeMap::new();
        root.insert("backend".to_string(), Json::Str(self.backend().to_string()));
        root.insert(
            "quant_mode".to_string(),
            Json::Str(self.index.quant_mode().to_string()),
        );
        if let Some(quant) = self.index.quant() {
            root.insert("rerank".to_string(), Json::Num(quant.rerank() as f64));
        }
        let mut requested = BTreeMap::new();
        requested.insert("top_p".to_string(), Json::Num(top_p as f64));
        requested.insert("top_k".to_string(), Json::Num(top_k as f64));
        root.insert("requested".to_string(), Json::Obj(requested));
        let mut resolved = BTreeMap::new();
        resolved.insert("p".to_string(), Json::Num(p as f64));
        resolved.insert("k".to_string(), Json::Num(k as f64));
        resolved.insert("n_classes".to_string(), Json::Num(q as f64));
        root.insert("resolved".to_string(), Json::Obj(resolved));

        // the poll decision: every polled class plus the next few
        // runners-up, so the margin is visible in context
        let shown = (p + 8).min(q);
        let mut classes = Vec::with_capacity(shown);
        for (rank, &ci) in ranked.iter().take(shown).enumerate() {
            let mut c = BTreeMap::new();
            c.insert("class".to_string(), Json::Num(ci as f64));
            c.insert("rank".to_string(), Json::Num(rank as f64));
            c.insert("score".to_string(), Json::Num(scores[ci as usize] as f64));
            c.insert(
                "members".to_string(),
                Json::Num(self.index.partition().members(ci as usize).len() as f64),
            );
            c.insert("polled".to_string(), Json::Bool(rank < p));
            classes.push(Json::Obj(c));
        }
        let mut poll = BTreeMap::new();
        poll.insert(
            "polled".to_string(),
            Json::Arr(resp.polled.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        // margin between the last polled class and the best unpolled
        // one — how close the poll came to a different decision
        if p > 0 && p < q {
            let last_in = scores[ranked[p - 1] as usize];
            let first_out = scores[ranked[p] as usize];
            poll.insert(
                "margin".to_string(),
                Json::Num((last_in - first_out) as f64),
            );
        }
        poll.insert("classes".to_string(), Json::Arr(classes));
        root.insert("poll".to_string(), Json::Obj(poll));

        let mut neighbors = Vec::with_capacity(resp.neighbors.len());
        for n in &resp.neighbors {
            let ci = self.index.partition().class_of(n.id as usize);
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Num(n.id as f64));
            o.insert("distance".to_string(), Json::Num(n.distance as f64));
            o.insert("class".to_string(), Json::Num(ci as f64));
            match resp.polled.iter().position(|&c| c == ci) {
                Some(rank) => {
                    o.insert("class_rank".to_string(), Json::Num(rank as f64));
                }
                None => {
                    o.insert("class_rank".to_string(), Json::Null);
                }
            }
            neighbors.push(Json::Obj(o));
        }
        root.insert("neighbors".to_string(), Json::Arr(neighbors));

        let mut funnel = BTreeMap::new();
        funnel.insert("candidates".to_string(), Json::Num(resp.candidates as f64));
        funnel.insert(
            "survivors".to_string(),
            Json::Num(resp.neighbors.len() as f64),
        );
        root.insert("funnel".to_string(), Json::Obj(funnel));
        root.insert("ops".to_string(), Json::Num(resp.ops as f64));

        let mut timings = BTreeMap::new();
        timings.insert("score_ns".to_string(), Json::Num(out.timings.score_ns as f64));
        timings.insert(
            "select_ns".to_string(),
            Json::Num(out.timings.select_ns as f64),
        );
        timings.insert("scan_ns".to_string(), Json::Num(out.timings.scan_ns as f64));
        root.insert("timings".to_string(), Json::Obj(timings));

        // store I/O attributable to this query's scan: counter deltas
        // across the pipeline call (all zero on a resident store)
        let mut store = BTreeMap::new();
        store.insert("kind".to_string(), Json::Str(store_after.kind.to_string()));
        let delta = |a: u64, b: u64| Json::Num(a.saturating_sub(b) as f64);
        store.insert(
            "bytes_read".to_string(),
            delta(store_after.bytes_read, store_before.bytes_read),
        );
        store.insert(
            "extent_reads".to_string(),
            delta(store_after.extent_reads, store_before.extent_reads),
        );
        store.insert(
            "cache_hits".to_string(),
            delta(store_after.cache_hits, store_before.cache_hits),
        );
        store.insert(
            "cache_misses".to_string(),
            delta(store_after.cache_misses, store_before.cache_misses),
        );
        store.insert(
            "bytes_resident".to_string(),
            Json::Num(store_after.bytes_resident as f64),
        );
        store.insert(
            "bytes_disk".to_string(),
            Json::Num(store_after.bytes_disk as f64),
        );
        root.insert("store".to_string(), Json::Obj(store));

        if exact {
            let truth = self.exact_scan(x, k);
            let mut quality = QualityStats::default();
            quality.record_comparison(&resp.neighbors, &truth);
            let mut ex = BTreeMap::new();
            ex.insert(
                "neighbors".to_string(),
                Json::Arr(
                    truth
                        .iter()
                        .map(|n| {
                            let mut o = BTreeMap::new();
                            o.insert("id".to_string(), Json::Num(n.id as f64));
                            o.insert(
                                "distance".to_string(),
                                Json::Num(n.distance as f64),
                            );
                            Json::Obj(o)
                        })
                        .collect(),
                ),
            );
            ex.insert("recall".to_string(), Json::Num(quality.recall()));
            ex.insert(
                "matches_exactly".to_string(),
                Json::Bool(quality.exact_matches == 1),
            );
            ex.insert(
                "mean_rank_displacement".to_string(),
                Json::Num(quality.mean_displacement()),
            );
            ex.insert(
                "mean_distance_error".to_string(),
                Json::Num(quality.mean_distance_error()),
            );
            root.insert("exact".to_string(), Json::Obj(ex));
        }
        Ok(Json::Obj(root))
    }

    /// Serve one batch through the class-grouped pipeline (see the
    /// module docs): one scoring call, batched top-p selection, then a
    /// class-major candidate scan touching each polled class's member
    /// matrix once for the whole batch.
    ///
    /// `queries` is a slice of `(vector, top_p, top_k)` triples (`0` =
    /// the index default for either knob; `top_k` is clamped to the
    /// database size); returns one response skeleton per query
    /// (id/service time filled by caller).
    pub fn serve_batch(
        &self,
        queries: &[(&[f32], usize, usize)],
    ) -> Result<Vec<SearchResponse>> {
        Ok(self.serve_batch_detailed(queries)?.responses)
    }

    /// [`Self::serve_batch`] plus the per-batch accounting the server
    /// aggregates (per-stage op counts, scan fusion statistics).
    pub fn serve_batch_detailed(
        &self,
        queries: &[(&[f32], usize, usize)],
    ) -> Result<BatchOutput> {
        let d = self.index.dim();
        let q = self.index.params().n_classes;
        let b = queries.len();
        if b == 0 {
            return Ok(BatchOutput {
                responses: Vec::new(),
                ops: OpsCounter::new(),
                scan: BatchScanStats::new(),
                timings: StageTimings::default(),
            });
        }
        let mut timings = StageTimings::default();
        // stage 1: score the whole batch in one scorer call
        let mut flat = Vec::with_capacity(b * d);
        for (v, _, _) in queries {
            flat.extend_from_slice(v);
        }
        let stage = std::time::Instant::now();
        let scores = self.scorer.score(&flat)?;
        timings.score_ns = stage.elapsed().as_nanos() as u64;
        // per-query accounting; scoring cost per the paper's model
        // (d²q dense); per-request p and k resolved against the index
        // defaults and clamped to what exists
        let mut ops: Vec<OpsCounter> = vec![OpsCounter::new(); b];
        let mut ps = Vec::with_capacity(b);
        let mut ks = Vec::with_capacity(b);
        for (bi, (_, top_p, top_k)) in queries.iter().enumerate() {
            ops[bi].score_ops += (d * d * q) as u64;
            let p = if *top_p == 0 { self.index.params().top_p } else { *top_p };
            ps.push(p.min(q));
            let k = if *top_k == 0 { self.index.params().top_k } else { *top_k };
            ks.push(k.min(self.index.len()).max(1));
        }
        let qrefs: Vec<&[f32]> = queries.iter().map(|(v, _, _)| *v).collect();
        // stages 2+3: top-p selection for the whole batch, then the
        // class-major scan (native or PJRT GEMM); the native path fuses
        // selection into the scan, so its select_ns stays 0 by design
        let results = if let Some(scanner) = &self.scanner {
            let stage = std::time::Instant::now();
            let polled: Vec<Vec<u32>> = (0..b)
                .map(|bi| top_p_largest(&scores[bi * q..(bi + 1) * q], ps[bi]))
                .collect();
            timings.select_ns = stage.elapsed().as_nanos() as u64;
            let stage = std::time::Instant::now();
            let r = self.scan_pjrt_batch(scanner, &qrefs, polled, &ks, &mut ops)?;
            timings.scan_ns = stage.elapsed().as_nanos() as u64;
            r
        } else {
            let stage = std::time::Instant::now();
            let r = self.index.finish_batch(&qrefs, &scores, &ps, &ks, &mut ops);
            timings.scan_ns = stage.elapsed().as_nanos() as u64;
            r
        };
        // the scan paths are infallible by design: a paged-store read or
        // checksum failure poisons the store and the failed class yields
        // zero candidates.  Check the poison slot here so the batch
        // fails loudly instead of a silently partial answer escaping
        if let Some(msg) = self.index.store_error() {
            return Err(Error::Data(format!("vector store failed: {msg}")));
        }
        // assemble responses + batch-level accounting
        let mut agg = OpsCounter::new();
        let mut scan = BatchScanStats { batches: 1, ..BatchScanStats::new() };
        let mut touched = vec![false; q];
        let mut responses = Vec::with_capacity(b);
        for (bi, r) in results.into_iter().enumerate() {
            scan.polls += r.polled.len() as u64;
            for &ci in &r.polled {
                // a pass is a member-matrix stream: polled-but-empty
                // classes execute nothing and must not count
                touched[ci as usize] |=
                    !self.index.partition().members(ci as usize).is_empty();
            }
            agg.merge(&ops[bi]);
            responses.push(SearchResponse {
                id: 0,
                // empty = no candidate scanned (or all candidates had
                // NaN distances): the "no candidates" protocol
                neighbors: r.neighbors,
                polled: r.polled,
                candidates: r.candidates,
                ops: ops[bi].total(),
                service_ns: 0,
                error: None,
            });
        }
        scan.class_passes = touched.iter().filter(|&&t| t).count() as u64;
        Ok(BatchOutput { responses, ops: agg, scan, timings })
    }
}

/// How worker threads construct their engines.
#[derive(Debug, Clone)]
pub struct EngineFactory {
    /// Shared immutable index.
    pub index: Arc<AmIndex>,
    /// Scoring backend.
    pub backend: Backend,
    /// Artifacts directory (PJRT backend only).
    pub artifacts_dir: Option<PathBuf>,
}

impl EngineFactory {
    /// Build a factory from a persisted index artifact — how a shard
    /// server constructs its engine from a `shard-<i>.amidx` file
    /// written by the cluster planner (any index file works; shard
    /// artifacts are ordinary index files).
    pub fn from_index_file(
        path: &std::path::Path,
        backend: Backend,
        artifacts_dir: Option<PathBuf>,
    ) -> Result<Self> {
        let index = crate::index::persist::load(path)?;
        Ok(EngineFactory { index: Arc::new(index), backend, artifacts_dir })
    }

    /// [`Self::from_index_file`] with an explicit vector-store choice:
    /// `Resident` loads the member matrices into RAM (the default path
    /// above), `Paged` keeps them on disk behind the extent cache
    /// (v5 artifacts only; a v4 file fails with a migration hint).
    pub fn from_index_file_with_store(
        path: &std::path::Path,
        backend: Backend,
        artifacts_dir: Option<PathBuf>,
        store: &crate::store::StoreOptions,
    ) -> Result<Self> {
        let index = match store.mode {
            crate::store::StoreMode::Resident => crate::index::persist::load(path)?,
            crate::store::StoreMode::Paged => {
                crate::index::persist::load_paged(path, store.cache_bytes)?
            }
        };
        Ok(EngineFactory { index: Arc::new(index), backend, artifacts_dir })
    }

    /// Construct an engine on the calling thread.
    pub fn build(&self) -> Result<Engine> {
        match self.backend {
            Backend::Native => Engine::native(self.index.clone()),
            Backend::Pjrt => {
                let dir = self.artifacts_dir.clone().unwrap_or_else(|| "artifacts".into());
                Engine::pjrt(self.index.clone(), &dir)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::index::IndexParams;

    fn test_index() -> (Arc<AmIndex>, crate::data::Workload) {
        let mut rng = Rng::new(1);
        let wl = synthetic::dense_workload(32, 256, 10, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        (Arc::new(idx), wl)
    }

    #[test]
    fn native_engine_serves_batch() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx.clone()).unwrap();
        assert_eq!(engine.backend(), "native");
        let queries: Vec<(&[f32], usize, usize)> =
            (0..4).map(|i| (wl.queries.get(i), 8usize, 1usize)).collect();
        let rs = engine.serve_batch(&queries).unwrap();
        assert_eq!(rs.len(), 4);
        for (i, r) in rs.iter().enumerate() {
            // p = q = full scan: exact answer guaranteed
            assert_eq!(r.neighbor(), Some(wl.ground_truth[i]));
            assert_eq!(r.neighbors.len(), 1);
            assert_eq!(r.candidates, 256);
            assert!(r.ops > 0);
        }
    }

    #[test]
    fn zero_top_p_and_top_k_use_index_defaults() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx.clone()).unwrap();
        let rs = engine
            .serve_batch(&[(wl.queries.get(0), 0usize, 0usize)])
            .unwrap();
        // default top_p = 1 -> exactly one class polled; default
        // top_k = 1 -> exactly one neighbor
        assert_eq!(rs[0].polled.len(), 1);
        assert_eq!(rs[0].neighbors.len(), 1);
    }

    #[test]
    fn top_k_returns_sorted_neighbors_and_clamps_to_n() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx.clone()).unwrap();
        let rs = engine
            .serve_batch(&[(wl.queries.get(0), 8usize, 10usize)])
            .unwrap();
        assert_eq!(rs[0].neighbors.len(), 10);
        assert_eq!(rs[0].neighbors[0].id, wl.ground_truth[0]);
        for w in rs[0].neighbors.windows(2) {
            assert!(
                w[0].distance < w[1].distance
                    || (w[0].distance == w[1].distance && w[0].id < w[1].id),
                "neighbors not (distance, id)-ascending"
            );
        }
        // k > n clamps to the database size (n = 256)
        let rs = engine
            .serve_batch(&[(wl.queries.get(0), 8usize, 100_000usize)])
            .unwrap();
        assert_eq!(rs[0].neighbors.len(), 256);
    }

    #[test]
    fn batch_equals_batches_of_one() {
        // the batched pipeline IS the single-query pipeline: a batch of
        // B must reproduce B batches of one bitwise, at every (p, k)
        let (idx, wl) = test_index();
        let engine = Engine::native(idx).unwrap();
        let queries: Vec<(&[f32], usize, usize)> = (0..6)
            .map(|i| {
                (
                    wl.queries.get(i),
                    [1usize, 2, 3, 8, 5, 8][i],
                    [1usize, 5, 10, 1, 300, 7][i],
                )
            })
            .collect();
        let batched = engine.serve_batch(&queries).unwrap();
        for (i, query) in queries.iter().enumerate() {
            let single = engine.serve_batch(&[*query]).unwrap();
            assert_eq!(batched[i], single[0], "query {i}");
        }
    }

    #[test]
    fn batch_accounting_reports_scan_fusion() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx).unwrap();
        // every query polls all 8 classes -> 32 polls over 8 passes
        let queries: Vec<(&[f32], usize, usize)> =
            (0..4).map(|i| (wl.queries.get(i), 8usize, 1usize)).collect();
        let out = engine.serve_batch_detailed(&queries).unwrap();
        assert_eq!(out.scan.batches, 1);
        assert_eq!(out.scan.polls, 32);
        assert_eq!(out.scan.class_passes, 8);
        assert!((out.scan.fusion_factor() - 4.0).abs() < 1e-12);
        assert_eq!(out.ops.searches, 4);
        // per-stage split is preserved (not lumped into one counter)
        assert!(out.ops.score_ops > 0);
        assert!(out.ops.scan_ops > 0);
        let total: u64 = out.responses.iter().map(|r| r.ops).sum();
        assert_eq!(total, out.ops.total());
        // stage timings: scoring and scanning both ran; the native path
        // fuses selection into the scan so select_ns stays 0
        assert!(out.timings.score_ns > 0);
        assert!(out.timings.scan_ns > 0);
        assert_eq!(out.timings.select_ns, 0);
        assert_eq!(
            out.timings.total_ns(),
            out.timings.score_ns + out.timings.scan_ns
        );
    }

    #[test]
    fn empty_polled_classes_yield_no_candidates_response() {
        // classes 0 and 1 empty; the probe ties all class scores at 0,
        // so top-2 polls exactly the two empty classes -> the protocol
        // must say "no candidates" (empty neighbors), at every k
        let idx = crate::index::am_index::two_empty_classes_fixture();
        let engine = Engine::native(Arc::new(idx)).unwrap();
        let probe: Vec<f32> = vec![0., 0., 1.];
        for k in [1usize, 3] {
            let rs = engine.serve_batch(&[(probe.as_slice(), 2usize, k)]).unwrap();
            assert!(rs[0].neighbors.is_empty(), "k={k}");
            assert_eq!(rs[0].neighbor(), None);
            assert_eq!(rs[0].candidates, 0);
            assert!(rs[0].distance().is_infinite());
            assert_eq!(rs[0].polled, vec![0, 1]);
        }
        // polling wider reaches the stored vectors again
        let rs = engine.serve_batch(&[(probe.as_slice(), 4usize, 1usize)]).unwrap();
        assert_eq!(rs[0].neighbor(), Some(0));
        assert_eq!(rs[0].candidates, 4);
        // ... and k > the 4 stored vectors returns all of them
        let rs = engine.serve_batch(&[(probe.as_slice(), 4usize, 9usize)]).unwrap();
        assert_eq!(rs[0].neighbors.len(), 4);
    }

    #[test]
    fn empty_batch_is_ok() {
        let (idx, _) = test_index();
        let engine = Engine::native(idx).unwrap();
        let out = engine.serve_batch_detailed(&[]).unwrap();
        assert!(out.responses.is_empty());
        assert_eq!(out.scan.batches, 0);
    }

    #[test]
    fn quantized_engine_at_full_rerank_matches_exact_engine() {
        use crate::quant::ScanPrecision;
        let mut rng = Rng::new(2);
        let wl = synthetic::dense_workload(32, 256, 10, QueryModel::Exact, &mut rng);
        let exact = AmIndex::build(
            wl.base.clone(),
            IndexParams { n_classes: 8, ..Default::default() },
            &mut Rng::new(77),
        )
        .unwrap();
        let quantized = AmIndex::build(
            wl.base.clone(),
            IndexParams {
                n_classes: 8,
                precision: ScanPrecision::Sq8 { rerank: 0 },
                ..Default::default()
            },
            &mut Rng::new(77),
        )
        .unwrap();
        let e_exact = Engine::native(Arc::new(exact)).unwrap();
        let e_quant = Engine::native(Arc::new(quantized)).unwrap();
        let queries: Vec<(&[f32], usize, usize)> = (0..6)
            .map(|i| (wl.queries.get(i), [1usize, 2, 8, 8, 4, 3][i], [1usize, 5, 300, 1, 7, 2][i]))
            .collect();
        let a = e_exact.serve_batch(&queries).unwrap();
        let b = e_quant.serve_batch(&queries).unwrap();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.polled, rb.polled);
            assert_eq!(ra.candidates, rb.candidates);
            assert_eq!(ra.neighbors.len(), rb.neighbors.len());
            for (na, nb) in ra.neighbors.iter().zip(&rb.neighbors) {
                assert_eq!(na.id, nb.id);
                assert_eq!(na.distance.to_bits(), nb.distance.to_bits());
            }
        }
        // the op split is visible at the engine level
        let out = e_quant.serve_batch_detailed(&queries).unwrap();
        assert!(out.ops.compressed_ops > 0);
        assert!(out.ops.rerank_ops > 0);
        assert_eq!(out.ops.scan_ops, 0);
    }

    #[test]
    fn factory_builds_native() {
        let (idx, _) = test_index();
        let f = EngineFactory { index: idx, backend: Backend::Native, artifacts_dir: None };
        let e = f.build().unwrap();
        assert_eq!(e.backend(), "native");
    }

    #[cfg(unix)]
    #[test]
    fn paged_engine_matches_resident_engine_bitwise() {
        let (idx, wl) = test_index();
        let path = std::env::temp_dir().join(format!(
            "amsearch_engine_paged_{}.amidx",
            std::process::id()
        ));
        crate::index::persist::save(&idx, &path).unwrap();
        let opts = crate::store::StoreOptions {
            mode: crate::store::StoreMode::Paged,
            cache_bytes: 1 << 20,
        };
        let factory = EngineFactory::from_index_file_with_store(
            &path,
            Backend::Native,
            None,
            &opts,
        )
        .unwrap();
        assert!(factory.index.is_paged());
        let paged = factory.build().unwrap();
        let resident = Engine::native(idx).unwrap();
        let queries: Vec<(&[f32], usize, usize)> = (0..6)
            .map(|i| (wl.queries.get(i), [1usize, 2, 8, 8, 4, 3][i], [1usize, 5, 300, 1, 7, 2][i]))
            .collect();
        let a = resident.serve_batch(&queries).unwrap();
        let b = paged.serve_batch(&queries).unwrap();
        assert_eq!(a, b, "paged serving must be bitwise-identical");
        // the exhaustive shadow scan agrees bitwise too
        for i in 0..4 {
            let ra = resident.exact_scan(wl.queries.get(i), 5);
            let rb = paged.exact_scan(wl.queries.get(i), 5);
            assert_eq!(ra.len(), rb.len());
            for (na, nb) in ra.iter().zip(&rb) {
                assert_eq!(na.id, nb.id);
                assert_eq!(na.distance.to_bits(), nb.distance.to_bits());
            }
        }
        // explain surfaces the paged store's I/O accounting
        let j = paged.explain(wl.queries.get(0), 8, 3, false).unwrap();
        let st = j.get("store").unwrap();
        assert_eq!(st.get("kind").and_then(|v| v.as_str()), Some("paged"));
        let stats = paged.index().store_stats();
        assert!(stats.bytes_read > 0);
        assert!(stats.bytes_disk > 0);
        // on a resident engine the same section reports zero I/O
        let j = resident.explain(wl.queries.get(0), 8, 3, false).unwrap();
        let st = j.get("store").unwrap();
        assert_eq!(st.get("kind").and_then(|v| v.as_str()), Some("resident"));
        assert_eq!(st.get("bytes_read").and_then(|v| v.as_f64()), Some(0.0));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(crate::index::persist::data_path(&path)).ok();
    }

    #[test]
    fn exact_scan_matches_full_poll_serving_bitwise() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx).unwrap();
        for i in 0..4 {
            let served = engine
                .serve_batch(&[(wl.queries.get(i), 8usize, 5usize)])
                .unwrap();
            let truth = engine.exact_scan(wl.queries.get(i), 5);
            assert_eq!(served[0].neighbors.len(), truth.len());
            for (a, b) in served[0].neighbors.iter().zip(&truth) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.distance.to_bits(), b.distance.to_bits());
            }
        }
        // k clamps to the database size like the serving path
        assert_eq!(engine.exact_scan(wl.queries.get(0), 100_000).len(), 256);
    }

    #[test]
    fn explain_reports_poll_decision_and_exact_diff() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx).unwrap();
        let j = engine.explain(wl.queries.get(0), 2, 3, true).unwrap();
        let p = j.get("resolved").and_then(|r| r.get("p")).and_then(|v| v.as_usize());
        assert_eq!(p, Some(2));
        let polled = j
            .get("poll")
            .and_then(|o| o.get("polled"))
            .and_then(|v| v.as_arr())
            .unwrap();
        assert_eq!(polled.len(), 2);
        // p < q: the decision margin is reported and non-negative
        let margin =
            j.get("poll").and_then(|o| o.get("margin")).and_then(|v| v.as_f64());
        assert!(margin.unwrap() >= 0.0);
        let neighbors = j.get("neighbors").and_then(|v| v.as_arr()).unwrap();
        assert!(!neighbors.is_empty());
        for n in neighbors {
            // every served neighbor's source class must be a polled one
            assert!(n.get("class_rank").and_then(|v| v.as_usize()).is_some());
        }
        let recall =
            j.get("exact").and_then(|e| e.get("recall")).and_then(|v| v.as_f64());
        assert!((0.0..=1.0).contains(&recall.unwrap()));

        // full poll IS exact: the diff must report a perfect answer
        let j = engine.explain(wl.queries.get(0), 8, 3, true).unwrap();
        let ex = j.get("exact").unwrap();
        assert_eq!(ex.get("recall").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(ex.get("matches_exactly").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            ex.get("mean_distance_error").and_then(|v| v.as_f64()),
            Some(0.0)
        );
        // without --exact the diff section is absent
        let j = engine.explain(wl.queries.get(0), 2, 3, false).unwrap();
        assert!(j.get("exact").is_none());
        // a wrong-dimension query is a typed error, not a panic
        assert!(engine.explain(&[0.0; 3], 1, 1, false).is_err());
    }
}
