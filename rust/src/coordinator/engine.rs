//! The per-worker search engine: an [`AmIndex`] plus a pluggable
//! [`ClassScorer`] backend (native or PJRT).
//!
//! The engine is deliberately *not* `Send`: the PJRT client is
//! `Rc`-based, so each worker thread constructs its own engine via an
//! [`EngineFactory`] and keeps it thread-local for its lifetime.

use std::path::PathBuf;
use std::sync::Arc;

use crate::error::Result;
use crate::index::AmIndex;
use crate::metrics::OpsCounter;
use crate::runtime::{
    Backend, ClassScorer, Manifest, NativeScorer, PjrtDistances, PjrtScorer,
};
use crate::search::top_p_largest;

use super::protocol::SearchResponse;

/// A ready-to-serve engine (one per worker thread).
pub struct Engine {
    index: Arc<AmIndex>,
    scorer: Box<dyn ClassScorer>,
    /// Optional PJRT candidate scanner (the AOT `class_distances` GEMM).
    /// When present and every class fits its capacity, the scan stage
    /// also runs through the compiled artifact; otherwise the native
    /// scan is used.
    scanner: Option<PjrtDistances>,
    /// Per-class member matrices (flat row-major), precomputed so the
    /// PJRT scan needs no per-query gather.
    class_members: Vec<Vec<f32>>,
}

impl Engine {
    /// Build with the native scorer.
    pub fn native(index: Arc<AmIndex>) -> Result<Self> {
        let scorer = NativeScorer::new(
            index.bank().stacked().to_vec(),
            index.dim(),
            index.params().n_classes,
        )?;
        Ok(Engine { index, scorer: Box::new(scorer), scanner: None, class_members: Vec::new() })
    }

    /// Build with the PJRT scorer (and, when an artifact matches, the
    /// PJRT candidate scanner) from an artifacts directory.
    pub fn pjrt(index: Arc<AmIndex>, artifacts_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = crate::runtime::cpu_client()?;
        let scorer = PjrtScorer::from_manifest(
            &client,
            &manifest,
            index.bank().stacked(),
            index.dim(),
            index.params().n_classes,
        )?;
        // candidate-scan artifact: usable when the largest class fits
        let max_class = (0..index.params().n_classes)
            .map(|i| index.partition().members(i).len())
            .max()
            .unwrap_or(0);
        let mut scanner = None;
        let mut class_members = Vec::new();
        for entry in manifest.entries() {
            if entry.kind == "class_distances"
                && entry.d == index.dim()
                && entry.k.is_some_and(|k| k >= max_class)
            {
                if let Ok(d) = PjrtDistances::from_manifest(
                    &client,
                    &manifest,
                    index.dim(),
                    entry.k.expect("checked"),
                ) {
                    scanner = Some(d);
                    class_members = (0..index.params().n_classes)
                        .map(|i| {
                            index
                                .data()
                                .gather(index.partition().members(i))
                                .as_flat()
                                .to_vec()
                        })
                        .collect();
                    break;
                }
            }
        }
        Ok(Engine { index, scorer: Box::new(scorer), scanner, class_members })
    }

    /// True when the candidate scan also runs through PJRT.
    pub fn has_pjrt_scan(&self) -> bool {
        self.scanner.is_some()
    }

    /// PJRT candidate scan over the polled classes for one query.
    fn scan_pjrt(
        &self,
        scanner: &PjrtDistances,
        x: &[f32],
        polled: &[u32],
        ops: &mut OpsCounter,
    ) -> Result<(u32, f32, usize)> {
        let d = self.index.dim();
        let mut best = f32::INFINITY;
        let mut best_id = u32::MAX;
        let mut candidates = 0usize;
        for &ci in polled {
            let members = &self.class_members[ci as usize];
            let n_members = members.len() / d;
            if n_members == 0 {
                continue;
            }
            let dists = scanner.distances(members, n_members, x)?;
            candidates += n_members;
            for (j, &dist) in dists.iter().enumerate() {
                let vid = self.index.partition().members(ci as usize)[j];
                if dist < best || (dist == best && vid < best_id) {
                    best = dist;
                    best_id = vid;
                }
            }
        }
        ops.scan_ops += (candidates * d) as u64;
        Ok((best_id, best, candidates))
    }

    /// The scorer backend in use.
    pub fn backend(&self) -> &'static str {
        self.scorer.backend()
    }

    /// The index served by this engine.
    pub fn index(&self) -> &AmIndex {
        &self.index
    }

    /// Serve one batch: score all queries in one scorer call, then finish
    /// each request (top-p select + candidate scan) individually.
    ///
    /// `queries` is a slice of (vector, top_p) pairs; returns one
    /// response skeleton per query (id/service time filled by caller).
    pub fn serve_batch(&self, queries: &[(&[f32], usize)]) -> Result<Vec<SearchResponse>> {
        let d = self.index.dim();
        let q = self.index.params().n_classes;
        let mut flat = Vec::with_capacity(queries.len() * d);
        for (v, _) in queries {
            flat.extend_from_slice(v);
        }
        let scores = self.scorer.score(&flat)?;
        let mut out = Vec::with_capacity(queries.len());
        for (bi, (v, top_p)) in queries.iter().enumerate() {
            let mut ops = OpsCounter::new();
            // account scoring cost per the paper's model (d²q dense)
            ops.score_ops += (d * d * q) as u64;
            let p = if *top_p == 0 { self.index.params().top_p } else { *top_p };
            let p = p.min(q);
            let resp = if let Some(scanner) = &self.scanner {
                // all-PJRT request path: top-p select in rust, scan GEMM
                // through the AOT artifact
                let polled = top_p_largest(&scores[bi * q..(bi + 1) * q], p);
                let (id, distance, candidates) =
                    self.scan_pjrt(scanner, v, &polled, &mut ops)?;
                ops.searches += 1;
                SearchResponse {
                    id: 0,
                    neighbor: id,
                    distance,
                    polled,
                    candidates,
                    ops: ops.total(),
                    service_ns: 0,
                }
            } else {
                let r = self.index.finish_query(
                    v,
                    &scores[bi * q..(bi + 1) * q],
                    p,
                    &mut ops,
                );
                SearchResponse {
                    id: 0,
                    neighbor: r.id,
                    distance: r.distance,
                    polled: r.polled,
                    candidates: r.candidates,
                    ops: ops.total(),
                    service_ns: 0,
                }
            };
            out.push(resp);
        }
        Ok(out)
    }
}

/// How worker threads construct their engines.
#[derive(Debug, Clone)]
pub struct EngineFactory {
    /// Shared immutable index.
    pub index: Arc<AmIndex>,
    /// Scoring backend.
    pub backend: Backend,
    /// Artifacts directory (PJRT backend only).
    pub artifacts_dir: Option<PathBuf>,
}

impl EngineFactory {
    /// Construct an engine on the calling thread.
    pub fn build(&self) -> Result<Engine> {
        match self.backend {
            Backend::Native => Engine::native(self.index.clone()),
            Backend::Pjrt => {
                let dir = self.artifacts_dir.clone().unwrap_or_else(|| "artifacts".into());
                Engine::pjrt(self.index.clone(), &dir)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::index::IndexParams;

    fn test_index() -> (Arc<AmIndex>, crate::data::Workload) {
        let mut rng = Rng::new(1);
        let wl = synthetic::dense_workload(32, 256, 10, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        (Arc::new(idx), wl)
    }

    #[test]
    fn native_engine_serves_batch() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx.clone()).unwrap();
        assert_eq!(engine.backend(), "native");
        let queries: Vec<(&[f32], usize)> =
            (0..4).map(|i| (wl.queries.get(i), 8usize)).collect();
        let rs = engine.serve_batch(&queries).unwrap();
        assert_eq!(rs.len(), 4);
        for (i, r) in rs.iter().enumerate() {
            // p = q = full scan: exact answer guaranteed
            assert_eq!(r.neighbor, wl.ground_truth[i]);
            assert_eq!(r.candidates, 256);
            assert!(r.ops > 0);
        }
    }

    #[test]
    fn zero_top_p_uses_index_default() {
        let (idx, wl) = test_index();
        let engine = Engine::native(idx.clone()).unwrap();
        let rs = engine.serve_batch(&[(wl.queries.get(0), 0usize)]).unwrap();
        // default top_p = 1 -> exactly one class polled
        assert_eq!(rs[0].polled.len(), 1);
    }

    #[test]
    fn factory_builds_native() {
        let (idx, _) = test_index();
        let f = EngineFactory { index: idx, backend: Backend::Native, artifacts_dir: None };
        let e = f.build().unwrap();
        assert_eq!(e.backend(), "native");
    }
}
