//! Layer-3 coordinator: the serving system around the index.
//!
//! * [`protocol`] — request/response types + config
//! * [`batcher`] — dynamic batching (size + deadline)
//! * [`engine`] — per-worker index + scorer (native or PJRT)
//! * [`server`] — async front door, worker pool, metrics

pub mod batcher;
pub mod engine;
pub mod protocol;
pub mod server;

pub use engine::{BatchOutput, Engine, EngineFactory};
pub use protocol::{CoordinatorConfig, SearchRequest, SearchResponse};
pub use server::{
    footprint_json, kernel_json, quant_json, selectivity_json, SearchServer,
    ServerMetrics,
};
