//! Request/response types flowing through the coordinator.
//!
//! The offline build has no async runtime; the coordinator is built on
//! std threads and channels.  Each request carries a rendezvous
//! (`SyncSender` of capacity 1) on which exactly one response is
//! delivered.

use std::sync::mpsc::SyncSender;

use crate::search::Neighbor;

/// A k-nearest-neighbor search request.
#[derive(Debug)]
pub struct SearchRequest {
    /// Monotonic request id (assigned by the server).
    pub id: u64,
    /// Query vector (dim must match the index).
    pub vector: Vec<f32>,
    /// Number of classes to poll (`p`); 0 = the index default.
    pub top_p: usize,
    /// Number of neighbors to return (`k`); 0 = the index default.
    /// Clamped to the database size at the server boundary.
    pub top_k: usize,
    /// Trace id for per-stage span emission (`0` = untraced).  Non-zero
    /// ids either arrived on the wire (a router stitching shard spans
    /// into its own trace) or were assigned by the server's sampler at
    /// admission.
    pub trace_id: u64,
    /// Enqueue timestamp (for end-to-end latency).
    pub enqueued: std::time::Instant,
    /// Completion channel (capacity 1; dropped on worker failure, which
    /// surfaces as a recv error to the caller).
    pub resp: SyncSender<SearchResponse>,
}

/// The answer to one search request.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchResponse {
    /// Echo of the request id.
    pub id: u64,
    /// The `top_k` nearest candidates found, sorted ascending by
    /// `(distance, id)`.  Empty when no candidate was scanned (every
    /// polled class was empty); shorter than the requested `k` when fewer
    /// candidates exist.  The pre-k-NN protocol carried a single
    /// `neighbor: Option<u32>` here.
    pub neighbors: Vec<Neighbor>,
    /// Classes that were polled, best first.
    pub polled: Vec<u32>,
    /// Number of candidates scanned.
    pub candidates: usize,
    /// Elementary operations spent on this request (paper cost model).
    pub ops: u64,
    /// Service time (scoring + scan) attributed to this request.
    pub service_ns: u64,
    /// Set when the request failed (engine error, worker pool gone):
    /// the serving pipeline guarantees every accepted request receives
    /// exactly one response — an error is *delivered*, never signalled
    /// by silently dropping the rendezvous channel, so a remote client
    /// whose requests funnel into a shared response channel can never
    /// hang.  `SearchServer::search` converts this into `Err`.
    pub error: Option<String>,
}

impl SearchResponse {
    /// An error response for a request that could not be served.
    pub fn failed(id: u64, message: impl Into<String>) -> Self {
        SearchResponse {
            id,
            neighbors: Vec::new(),
            polled: Vec::new(),
            candidates: 0,
            ops: 0,
            service_ns: 0,
            error: Some(message.into()),
        }
    }
    /// Database id of the best candidate, `None` when no candidate was
    /// scanned — the 1-NN view of the k-NN protocol.
    pub fn neighbor(&self) -> Option<u32> {
        self.neighbors.first().map(|n| n.id)
    }

    /// Distance of the best candidate (`f32::INFINITY` when no candidate
    /// was scanned).
    pub fn distance(&self) -> f32 {
        self.neighbors
            .first()
            .map_or(f32::INFINITY, |n| n.distance)
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Maximum dynamic batch size (should match the AOT batch for the
    /// PJRT backend).
    pub max_batch: usize,
    /// Maximum time the batcher waits to fill a batch.
    pub max_wait_us: u64,
    /// Number of worker threads (each owns a scorer).
    pub workers: usize,
    /// Bound of the request queue (backpressure).
    pub queue_depth: usize,
    /// Shadow-execute an exact scan for every `quality_sample`-th
    /// request and fold the comparison into the online recall estimate
    /// (`0` = quality sampling off).  The shadow work runs on a
    /// dedicated worker behind a bounded drop-oldest queue; it never
    /// touches the serving path.
    pub quality_sample: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 8,
            max_wait_us: 200,
            workers: 2,
            queue_depth: 1024,
            quality_sample: 0,
        }
    }
}

impl CoordinatorConfig {
    /// Validate the configuration.
    pub fn validate(&self) -> crate::error::Result<()> {
        use crate::error::Error;
        if self.max_batch == 0 {
            return Err(Error::Config("max_batch must be > 0".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("queue_depth must be > 0".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        CoordinatorConfig::default().validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = CoordinatorConfig::default();
        c.max_batch = 0;
        assert!(c.validate().is_err());
        c = CoordinatorConfig::default();
        c.workers = 0;
        assert!(c.validate().is_err());
        c = CoordinatorConfig::default();
        c.queue_depth = 0;
        assert!(c.validate().is_err());
    }
}
